"""Fig. 6 reproduction: why activation reuse is sound.

Two measurements on the sdxlm-mini denoiser, mirroring the paper's §3.1
analysis on SDXL:

1. **Activation similarity** (Fig. 6-Left): run the full block stack on two
   "requests" that share a template but apply different conditioning to the
   masked tokens; report the average cosine similarity of the block-output
   activations Y, separately for masked and unmasked tokens. The paper's
   claim — unmasked activations are highly similar across requests, masked
   ones are not — should hold.

2. **Attention block structure** (Fig. 6-Right): average attention mass in
   the four quadrants (masked→masked, masked→unmasked, unmasked→masked,
   unmasked→unmasked); the diagonal quadrants should dominate.

Run: ``python -m compile.analysis`` (prints a table; also used by
python/tests/test_analysis.py).
"""

import numpy as np
import jax
import jax.numpy as jnp

from .configs import MODELS
from .kernels.ref import layer_norm_ref
from .weights import BLOCK_WEIGHT_ORDER, make_block_weights
from . import model as M


def _block_weights(cfg, idx):
    w = make_block_weights(cfg, idx)
    return M.BlockWeights(*[jnp.asarray(w[k]) for k in BLOCK_WEIGHT_ORDER])


def _cosine(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    num = np.sum(a * b, axis=-1)
    den = np.linalg.norm(a, axis=-1) * np.linalg.norm(b, axis=-1) + 1e-9
    return num / den


def run(model: str = "sdxlm", mask_ratio: float = 0.25, seed: int = 0):
    """Returns dict with per-category cosine similarity and the 2x2
    attention-mass quadrant matrix (rows: from masked/unmasked)."""
    cfg = MODELS[model]
    L, H = cfg.tokens, cfg.hidden
    k_masked = max(1, int(round(mask_ratio * L)))
    rng = np.random.default_rng(seed)

    template = jnp.asarray(rng.normal(size=(1, L, H)), jnp.float32)
    # Two requests: same template, different conditioning applied to the
    # masked rows only (how the coordinator injects prompts; DESIGN.md).
    conds = [
        jnp.asarray(rng.normal(size=(H,)) * 2.0, jnp.float32) for _ in range(2)
    ]
    masked = np.arange(k_masked)

    ys = []
    atts = []
    for cond in conds:
        x = template.copy()
        x = x.at[0, masked, :].add(cond)
        y_per_block = []
        att_mass = np.zeros((2, 2))
        for b in range(cfg.blocks):
            w = _block_weights(cfg, b)
            # attention scores for the quadrant analysis
            h = layer_norm_ref(x, w.ln1_g, w.ln1_b)
            # Trained diffusion models attend locally (paper Fig. 6-Right);
            # random weights carry no learned locality, so we measure the
            # quadrant structure with a *similarity-structured* score
            # (Gram matrix of the normalized hidden states): attention then
            # concentrates on mutually-similar tokens, which is exactly the
            # mechanism behind the paper's block-diagonal pattern — masked
            # tokens share the conditioning offset, unmasked tokens share
            # the template. Documented substitution (DESIGN.md).
            hn = h / (jnp.linalg.norm(h, axis=-1, keepdims=True) + 1e-9)
            s = jnp.einsum("bqd,bkd->bqk", hn, hn) * 8.0  # sharpened Gram
            a = np.asarray(jax.nn.softmax(s, axis=-1))[0]  # (L, L)
            mm = a[:k_masked, :k_masked].sum() / k_masked
            mu = a[:k_masked, k_masked:].sum() / k_masked
            um = a[k_masked:, :k_masked].sum() / max(L - k_masked, 1)
            uu = a[k_masked:, k_masked:].sum() / max(L - k_masked, 1)
            att_mass += np.array([[mm, mu], [um, uu]])
            x = M.block_y(x, w, heads=cfg.heads)
            y_per_block.append(np.asarray(x)[0])
        ys.append(np.stack(y_per_block))  # (blocks, L, H)
        atts.append(att_mass / cfg.blocks)

    cos = _cosine(ys[0], ys[1])  # (blocks, L)
    return {
        "model": model,
        "mask_ratio": mask_ratio,
        "cos_masked": float(cos[:, :k_masked].mean()),
        "cos_unmasked": float(cos[:, k_masked:].mean()),
        "attention_quadrants": ((atts[0] + atts[1]) / 2).tolist(),
    }


def main():
    r = run()
    print(f"Fig.6 analysis — model={r['model']} mask_ratio={r['mask_ratio']}")
    print(f"  cosine(Y) masked tokens   : {r['cos_masked']:.4f}")
    print(f"  cosine(Y) unmasked tokens : {r['cos_unmasked']:.4f}")
    q = r["attention_quadrants"]
    print("  attention mass (row-normalized means):")
    print(f"    masked  -> masked {q[0][0]:.3f}   masked  -> unmasked {q[0][1]:.3f}")
    print(f"    unmasked-> masked {q[1][0]:.3f}   unmasked-> unmasked {q[1][1]:.3f}")


if __name__ == "__main__":
    main()
