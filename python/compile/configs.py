"""Model and bucket configuration shared by the AOT pipeline and (via
artifacts/manifest.json) by the rust coordinator.

Three mini diffusion-transformer denoisers stand in for the paper's
SD2.1 / SDXL / Flux (see DESIGN.md "Substitutions"): they keep the same
*relative* compute intensities and the same systems behaviour (compute
scales with the mask ratio, cache size scales with ``(1-m)*L*H``) at a
CPU-PJRT-feasible scale.
"""

from dataclasses import dataclass, field
from typing import List


@dataclass(frozen=True)
class ModelConfig:
    """A mini DiT denoiser configuration.

    Attributes:
        name: preset id, referenced by the rust side.
        latent_hw: latent grid side; token count ``L = latent_hw ** 2``.
        hidden: transformer hidden size ``H``.
        heads: attention heads (``H % heads == 0``).
        blocks: number of transformer blocks ``N``.
        steps: denoising steps per request.
        paper_analogue: which production model this preset stands in for.
    """

    name: str
    latent_hw: int
    hidden: int
    heads: int
    blocks: int
    steps: int
    paper_analogue: str

    @property
    def tokens(self) -> int:
        """Token length L (latent pixels mapped to tokens, paper §2.1)."""
        return self.latent_hw * self.latent_hw

    @property
    def head_dim(self) -> int:
        return self.hidden // self.heads

    @property
    def ffn_dim(self) -> int:
        """Feed-forward inner size (4H, matching Table 1's analysis)."""
        return 4 * self.hidden

    def token_buckets(self) -> List[int]:
        """Masked-token shape buckets: L/16, L/8, L/4, L/2 (DESIGN.md).

        A request with k masked tokens is padded (with real unmasked
        tokens) to the smallest bucket >= k; the full block (n == L)
        covers the mask-agnostic path.
        """
        L = self.tokens
        return [L // 16, L // 8, L // 4, L // 2]

    def all_token_counts(self) -> List[int]:
        return self.token_buckets() + [self.tokens]


# Batch-size buckets. Paper serves max batch 4 (SD2.1 on A10) or 8
# (SDXL/Flux on H800); the grid covers both.
BATCH_BUCKETS: List[int] = [1, 2, 4, 8]

# Denoising-step count is the per-model default (paper: "default settings
# ... for the best image quality").
MODELS = {
    "sd21m": ModelConfig(
        name="sd21m",
        latent_hw=8,
        hidden=64,
        heads=4,
        blocks=4,
        steps=8,
        paper_analogue="SD2.1 on A10",
    ),
    "sdxlm": ModelConfig(
        name="sdxlm",
        latent_hw=12,
        hidden=96,
        heads=6,
        blocks=6,
        steps=10,
        paper_analogue="SDXL on H800",
    ),
    "fluxm": ModelConfig(
        name="fluxm",
        latent_hw=16,
        hidden=128,
        heads=8,
        blocks=8,
        steps=12,
        paper_analogue="Flux on H800",
    ),
}

# Channels per token of the decoded "image" (VAE-analogue patch size).
IMAGE_CHANNELS = 4

# Weight-initialization scale: small enough that the residual stream stays
# numerically tame over `steps` iterations of a random denoiser.
INIT_SCALE = 0.02


def model_by_name(name: str) -> ModelConfig:
    try:
        return MODELS[name]
    except KeyError:
        raise KeyError(
            f"unknown model {name!r}; available: {sorted(MODELS)}"
        ) from None
