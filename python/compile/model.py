"""L2 — the mini DiT denoiser, in the three mask-aware variants.

Each transformer block follows the paper's Fig. 5 decomposition:

    x ──ln1──▶ QKV proj ──▶ attention ──▶ out proj ──(+x)──▶
      ──ln2──▶ fused FFN ──(+residual)──▶ y

All token-wise operators (projections, LayerNorm, FFN) run over the
*compute set* only — the masked tokens plus bucket filler — which is where
Table 1's 1/m FLOP reduction comes from. The attention kernel is L1
(``kernels.masked_attention``); the FFN is L1 (``kernels.fused_ffn``).

Variants (one AOT executable per (variant, token bucket, batch bucket)):

- ``block_y``      cache-Y mode (Fig. 5-Bottom, the default): attention is
                   restricted to the compute set; the cached Y of unmasked
                   tokens is replenished host-side by the rust coordinator.
                   At n == L this *is* the standard full block.
- ``block_kv``     cache-KV mode (Fig. 7, the ablation): Q from the compute
                   set attends over computed K/V ++ cached unmasked K/V.
- ``block_reg``    template registration: full block that additionally
                   returns the K/V projections so the coordinator can
                   populate the activation cache in one pass.

Weights are positional arguments (see weights.BLOCK_WEIGHT_ORDER), so one
lowered executable serves every block index.
"""

from typing import List, NamedTuple

import jax
import jax.numpy as jnp

from . import kernels
from .configs import ModelConfig
from .kernels.ref import layer_norm_ref as _layer_norm


class BlockWeights(NamedTuple):
    """Positional weight bundle; order must match weights.BLOCK_WEIGHT_ORDER."""

    ln1_g: jax.Array
    ln1_b: jax.Array
    wq: jax.Array
    wk: jax.Array
    wv: jax.Array
    wo: jax.Array
    ln2_g: jax.Array
    ln2_b: jax.Array
    w1: jax.Array
    b1: jax.Array
    w2: jax.Array
    b2: jax.Array


def _split_heads(x: jax.Array, heads: int) -> jax.Array:
    """(B, n, H) -> (B, heads, n, dh)."""
    B, n, H = x.shape
    return x.reshape(B, n, heads, H // heads).transpose(0, 2, 1, 3)


def _merge_heads(x: jax.Array) -> jax.Array:
    """(B, heads, n, dh) -> (B, n, H)."""
    B, h, n, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(B, n, h * dh)


def _qkv(h: jax.Array, w: BlockWeights, heads: int):
    q = _split_heads(h @ w.wq, heads)
    k = _split_heads(h @ w.wk, heads)
    v = _split_heads(h @ w.wv, heads)
    return q, k, v


def _ffn_rows(h2: jax.Array, w: BlockWeights) -> jax.Array:
    B, n, H = h2.shape
    y = kernels.fused_ffn(h2.reshape(B * n, H), w.w1, w.b1, w.w2, w.b2)
    return y.reshape(B, n, H)


def block_y(x: jax.Array, w: BlockWeights, *, heads: int) -> jax.Array:
    """Cache-Y block: everything restricted to the compute set.

    Args:
        x: (B, n, H) compute-set hidden states (masked tokens first, then
           bucket filler — the masked-first permutation is host-side).
        w: block weights.

    Returns:
        (B, n, H) block output for the compute set. The unmasked rows of
        the full (B, L, H) output are replenished from the activation
        cache by the coordinator (paper Fig. 5-Bottom).
    """
    h = _layer_norm(x, w.ln1_g, w.ln1_b)
    q, k, v = _qkv(h, w, heads)
    att = _merge_heads(kernels.masked_attention(q, k, v))
    x = x + att @ w.wo
    h2 = _layer_norm(x, w.ln2_g, w.ln2_b)
    return x + _ffn_rows(h2, w)


def block_kv(
    x: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    w: BlockWeights,
    *,
    heads: int,
) -> jax.Array:
    """Cache-KV block (Fig. 7): masked Q attends over the full sequence.

    Args:
        x: (B, n, H) compute-set hidden states.
        k_cache: (B, L - n, H) cached K projections of the unmasked rows
            (template activations, gathered into the request's permutation
            by the cache engine).
        v_cache: (B, L - n, H) cached V projections.

    Returns:
        (B, n, H) block output for the compute set.
    """
    h = _layer_norm(x, w.ln1_g, w.ln1_b)
    q, k, v = _qkv(h, w, heads)
    heads_n = q.shape[1]
    kc = _split_heads(k_cache, heads_n)
    vc = _split_heads(v_cache, heads_n)
    k_all = jnp.concatenate([k, kc], axis=2)
    v_all = jnp.concatenate([v, vc], axis=2)
    att = _merge_heads(kernels.masked_attention(q, k_all, v_all))
    x = x + att @ w.wo
    h2 = _layer_norm(x, w.ln2_g, w.ln2_b)
    return x + _ffn_rows(h2, w)


def block_reg(x: jax.Array, w: BlockWeights, *, heads: int):
    """Registration block: full computation + K/V taps for cache building.

    Returns:
        (y, k, v): y is the (B, L, H) block output; k and v are the
        (B, L, H) post-projection K/V (canonical token order) that the
        cache engine stores for cache-KV mode.
    """
    h = _layer_norm(x, w.ln1_g, w.ln1_b)
    k_flat = h @ w.wk
    v_flat = h @ w.wv
    q = _split_heads(h @ w.wq, heads)
    k = _split_heads(k_flat, heads)
    v = _split_heads(v_flat, heads)
    att = _merge_heads(kernels.masked_attention(q, k, v))
    x = x + att @ w.wo
    h2 = _layer_norm(x, w.ln2_g, w.ln2_b)
    y = x + _ffn_rows(h2, w)
    return y, k_flat, v_flat


def denoiser_step_full(
    x: jax.Array, all_weights: List[BlockWeights], *, heads: int
) -> jax.Array:
    """Reference full denoiser step (all blocks, all tokens).

    Used by the python tests as the L2 oracle; the rust coordinator chains
    per-block executables instead (so the pipeline DP can mix cached and
    full blocks).
    """
    for w in all_weights:
        x = block_y(x, w, heads=heads)
    return x


# ---------------------------------------------------------------------------
# Lowering entry points (called by aot.py). Weights are flattened to
# positional leaves so the HLO parameter order is stable and documented.
# ---------------------------------------------------------------------------


def lower_block_y(cfg: ModelConfig, n: int, batch: int):
    """jit-lowered cache-Y block for (n tokens, batch) bucket."""

    def fn(x, *wflat):
        return (block_y(x, BlockWeights(*wflat), heads=cfg.heads),)

    return _lower(cfg, fn, [(batch, n, cfg.hidden)])


def lower_block_kv(cfg: ModelConfig, n: int, batch: int):
    """jit-lowered cache-KV block for (n tokens, batch) bucket."""
    L = cfg.tokens

    def fn(x, kc, vc, *wflat):
        return (
            block_kv(x, kc, vc, BlockWeights(*wflat), heads=cfg.heads),
        )

    return _lower(
        cfg,
        fn,
        [
            (batch, n, cfg.hidden),
            (batch, L - n, cfg.hidden),
            (batch, L - n, cfg.hidden),
        ],
    )


def lower_block_reg(cfg: ModelConfig):
    """jit-lowered registration block (batch 1, full sequence)."""

    def fn(x, *wflat):
        return block_reg(x, BlockWeights(*wflat), heads=cfg.heads)

    return _lower(cfg, fn, [(1, cfg.tokens, cfg.hidden)])


def _weight_specs(cfg: ModelConfig):
    from .weights import BLOCK_WEIGHT_ORDER, block_weight_shapes

    shapes = block_weight_shapes(cfg)
    return [
        jax.ShapeDtypeStruct(shapes[name], jnp.float32)
        for name in BLOCK_WEIGHT_ORDER
    ]


def _lower(cfg: ModelConfig, fn, data_shapes):
    specs = [jax.ShapeDtypeStruct(s, jnp.float32) for s in data_shapes]
    specs += _weight_specs(cfg)
    return jax.jit(fn).lower(*specs)
