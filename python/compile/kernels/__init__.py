"""L1 Pallas kernels: masked attention (paper Fig. 5/7) and fused FFN."""

from .masked_attention import masked_attention
from .ffn import fused_ffn

__all__ = ["masked_attention", "fused_ffn"]
