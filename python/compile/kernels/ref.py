"""Pure-jnp oracles for the Pallas kernels (L1 correctness contract).

Every Pallas kernel in this package must match its reference here to
float32 tolerance; ``python/tests/test_kernels.py`` sweeps shapes and
dtypes with hypothesis.
"""

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Scaled dot-product attention.

    Args:
        q: (B, heads, n, dh) queries — in InstGenIE, only the masked
           (compute-set) tokens (paper Fig. 5-Bottom).
        k: (B, heads, m, dh) keys; ``m == n`` in cache-Y mode (attention
           restricted to the compute set) or ``m == L`` in cache-KV mode
           (cached unmasked K/V replenished, paper Fig. 7).
        v: (B, heads, m, dh) values.

    Returns:
        (B, heads, n, dh) attention output.
    """
    dh = q.shape[-1]
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(
        jnp.asarray(dh, q.dtype)
    )
    a = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", a, v)


def ffn_ref(
    x: jax.Array,
    w1: jax.Array,
    b1: jax.Array,
    w2: jax.Array,
    b2: jax.Array,
) -> jax.Array:
    """Two-layer GeLU feed-forward: ``gelu(x @ w1 + b1) @ w2 + b2``.

    Args:
        x: (R, H) rows (R = B * n flattened tokens).
        w1: (H, F), b1: (F,), w2: (F, H), b2: (H,).
    """
    h = jax.nn.gelu(x @ w1 + b1, approximate=True)
    return h @ w2 + b2


def layer_norm_ref(x: jax.Array, g: jax.Array, b: jax.Array) -> jax.Array:
    """LayerNorm over the trailing (hidden) axis."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-6) * g + b
