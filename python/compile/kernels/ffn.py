"""Pallas fused feed-forward kernel (the block's second hot-spot).

``gelu(x @ w1 + b1) @ w2 + b2`` fused in one kernel so the (R, F)
intermediate never round-trips through HBM. Rows are tiled; both weight
matrices are resident in VMEM per grid cell (mini-model sizes: H=128,
F=512 -> 384 KiB, far under the 16 MiB budget; at paper scale the row
tile loop would be extended with an F-tile loop).

Token-wise per Fig. 5: this kernel runs over the compute-set rows only
(masked tokens + bucket filler), which is where the 1/m FLOP saving of
Table 1 comes from.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_PREFERRED_BR = 64


def _largest_divisor_leq(n: int, cap: int) -> int:
    for d in range(min(n, cap), 0, -1):
        if n % d == 0:
            return d
    return 1


def _ffn_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    """One row-tile grid cell: fused matmul + GeLU + matmul.

    Refs: x_ref (br, H); w1_ref (H, F); b1_ref (1, F); w2_ref (F, H);
    b2_ref (1, H); o_ref (br, H).
    """
    x = x_ref[:, :]
    h = jnp.dot(x, w1_ref[:, :], preferred_element_type=jnp.float32)
    h = h + b1_ref[0, :]
    h = jax.nn.gelu(h, approximate=True)
    y = jnp.dot(h, w2_ref[:, :], preferred_element_type=jnp.float32)
    o_ref[:, :] = (y + b2_ref[0, :]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_ffn(
    x: jax.Array,
    w1: jax.Array,
    b1: jax.Array,
    w2: jax.Array,
    b2: jax.Array,
    *,
    interpret: bool = True,
) -> jax.Array:
    """Fused two-layer GeLU FFN over token rows.

    Args:
        x: (R, H) compute-set token rows (R = B * n).
        w1: (H, F); b1: (F,); w2: (F, H); b2: (H,).
        interpret: Pallas interpret mode (required on CPU PJRT).

    Returns:
        (R, H) FFN output.
    """
    R, H = x.shape
    F = w1.shape[1]
    if w1.shape != (H, F) or w2.shape != (F, H):
        raise ValueError(f"weight shapes {w1.shape}/{w2.shape} != ({H},{F})/({F},{H})")
    br = _largest_divisor_leq(R, _PREFERRED_BR)
    grid = (R // br,)
    return pl.pallas_call(
        _ffn_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, H), lambda i: (i, 0)),
            pl.BlockSpec((H, F), lambda i: (0, 0)),
            pl.BlockSpec((1, F), lambda i: (0, 0)),
            pl.BlockSpec((F, H), lambda i: (0, 0)),
            pl.BlockSpec((1, H), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, H), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, H), x.dtype),
        interpret=interpret,
    )(x, w1, b1.reshape(1, F), w2, b2.reshape(1, H))


def vmem_footprint_bytes(r: int, h: int, f: int, dtype_bytes: int = 4) -> int:
    """Structural VMEM estimate for one grid cell (EXPERIMENTS.md §Perf)."""
    br = _largest_divisor_leq(r, _PREFERRED_BR)
    return (br * h + h * f + f + f * h + h + br * f + br * h) * dtype_bytes
