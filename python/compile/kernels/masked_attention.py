"""Pallas masked attention — InstGenIE's L1 compute hot-spot.

The paper's mask-aware editing (Fig. 5-Bottom / Fig. 7) computes attention
for the *masked* tokens only. FISEdit does this on GPUs with gather/scatter
sparse kernels; the TPU adaptation here (DESIGN.md §Hardware-Adaptation)
instead relies on the host-side *masked-first permutation*, which turns the
sparsity into a leading-dimension crop, so the kernel only ever sees dense
tiles:

- grid cell = one (batch, head, q-tile); Q tile (bq, dh) lives in VMEM;
- K/V are streamed tile-by-tile (bk, dh) with a flash-attention-style
  *online softmax* (running max / running sum), so the (n x m) score
  matrix never materializes — on a real TPU this is what keeps the VMEM
  footprint flat in the sequence length;
- the same kernel serves both cache modes: cache-Y restricts K/V to the
  compute set (m == n); cache-KV passes K/V replenished with the cached
  unmasked rows (m == L).

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel is lowered to plain HLO for execution and the
TPU tiling story is validated structurally (see EXPERIMENTS.md §Perf).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Preferred tile sizes. On a real TPU these would be multiples of the
# (8, 128) vector-register tile; our mini models have dh in {16}, n down
# to 4, so we take the largest divisor <= the preferred size.
_PREFERRED_BQ = 32
_PREFERRED_BK = 64


def _largest_divisor_leq(n: int, cap: int) -> int:
    for d in range(min(n, cap), 0, -1):
        if n % d == 0:
            return d
    return 1


def _attention_kernel(q_ref, k_ref, v_ref, o_ref, *, bk: int, m: int):
    """One (batch, head, q-tile) grid cell with online softmax over K tiles.

    Refs (VMEM views selected by BlockSpec):
        q_ref: (1, 1, bq, dh)   o_ref: (1, 1, bq, dh)
        k_ref: (1, 1, m, dh)    v_ref: (1, 1, m, dh)
    """
    q = q_ref[0, 0, :, :]  # (bq, dh)
    bq, dh = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))
    nk = m // bk

    def body(j, carry):
        m_run, l_run, acc = carry
        k = k_ref[0, 0, pl.ds(j * bk, bk), :]  # (bk, dh)
        v = v_ref[0, 0, pl.ds(j * bk, bk), :]  # (bk, dh)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))  # (bq,)
        alpha = jnp.exp(m_run - m_new)  # rescale of old accumulator
        p = jnp.exp(s - m_new[:, None])  # (bq, bk)
        l_new = l_run * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        return m_new, l_new, acc_new

    init = (
        jnp.full((bq,), -jnp.inf, jnp.float32),
        jnp.zeros((bq,), jnp.float32),
        jnp.zeros((bq, dh), jnp.float32),
    )
    m_run, l_run, acc = jax.lax.fori_loop(0, nk, body, init)
    out = acc / l_run[:, None]
    o_ref[0, 0, :, :] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def masked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    interpret: bool = True,
) -> jax.Array:
    """Masked-token attention (paper Fig. 5-Bottom / Fig. 7).

    Args:
        q: (B, heads, n, dh) queries of the compute-set (masked) tokens.
        k: (B, heads, m, dh) keys — m == n (cache-Y) or m == L (cache-KV,
           with the unmasked rows replenished from the activation cache).
        v: (B, heads, m, dh) values, same m as keys.
        interpret: run the Pallas kernel in interpret mode (required on
           CPU PJRT; compile-only on real TPUs).

    Returns:
        (B, heads, n, dh) attention outputs for the compute-set tokens.
    """
    B, heads, n, dh = q.shape
    m = k.shape[2]
    if k.shape != (B, heads, m, dh) or v.shape != (B, heads, m, dh):
        raise ValueError(f"shape mismatch q={q.shape} k={k.shape} v={v.shape}")
    bq = _largest_divisor_leq(n, _PREFERRED_BQ)
    bk = _largest_divisor_leq(m, _PREFERRED_BK)

    grid = (B, heads, n // bq)
    kernel = functools.partial(_attention_kernel, bk=bk, m=m)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, dh), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, m, dh), lambda b, h, i: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, m, dh), lambda b, h, i: (b, h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dh), lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, heads, n, dh), q.dtype),
        interpret=interpret,
    )(q, k, v)


def vmem_footprint_bytes(n: int, m: int, dh: int, dtype_bytes: int = 4) -> int:
    """Structural VMEM estimate for one grid cell (EXPERIMENTS.md §Perf).

    Q tile + K tile + V tile + accumulator + running stats. Used to check
    the BlockSpec stays inside a ~16 MiB VMEM budget for the paper-scale
    shapes (L = 4096 tokens, dh = 128).
    """
    bq = _largest_divisor_leq(n, _PREFERRED_BQ)
    bk = _largest_divisor_leq(m, _PREFERRED_BK)
    tiles = bq * dh + 2 * bk * dh  # q + current k/v tiles
    acc = bq * dh + 2 * bq  # accumulator + m_run/l_run
    return (tiles + acc) * dtype_bytes
