"""Deterministic weight generation + binary export for the rust runtime.

Weights are *runtime inputs* to the AOT executables (DESIGN.md "Model
weights"): one executable per (kind, token-bucket, batch-bucket) is shared
across all block indices, and the rust coordinator feeds per-block weight
buffers loaded from ``artifacts/weights_<model>.bin``.

Binary format: a flat little-endian float32 stream; the tensor layout
(name, shape, offset in floats) is recorded in ``manifest.json`` so the
rust side needs no parsing heuristics.
"""

import hashlib
import math
from typing import Dict, List, Tuple

import numpy as np


def _stable_seed(*parts) -> int:
    """Process-independent seed (python's hash() is salted per process)."""
    h = hashlib.sha256("/".join(str(p) for p in parts).encode()).digest()
    return int.from_bytes(h[:4], "little")

from .configs import IMAGE_CHANNELS, INIT_SCALE, ModelConfig

# Per-block weight tensors, in the exact positional order the block
# executables take them after the data arguments. Shapes use H = hidden,
# F = ffn_dim.
BLOCK_WEIGHT_ORDER: List[str] = [
    "ln1_g",  # (H,)
    "ln1_b",  # (H,)
    "wq",     # (H, H)
    "wk",     # (H, H)
    "wv",     # (H, H)
    "wo",     # (H, H)
    "ln2_g",  # (H,)
    "ln2_b",  # (H,)
    "w1",     # (H, F)
    "b1",     # (F,)
    "w2",     # (F, H)
    "b2",     # (H,)
]


def block_weight_shapes(cfg: ModelConfig) -> Dict[str, Tuple[int, ...]]:
    H, F = cfg.hidden, cfg.ffn_dim
    return {
        "ln1_g": (H,),
        "ln1_b": (H,),
        "wq": (H, H),
        "wk": (H, H),
        "wv": (H, H),
        "wo": (H, H),
        "ln2_g": (H,),
        "ln2_b": (H,),
        "w1": (H, F),
        "b1": (F,),
        "w2": (F, H),
        "b2": (H,),
    }


def _init(rng: np.random.Generator, shape: Tuple[int, ...], name: str) -> np.ndarray:
    """Weight init keeping the residual stream tame over many steps."""
    if name.startswith("ln") and name.endswith("_g"):
        return np.ones(shape, np.float32)
    if name.endswith("_b") or name in ("b1", "b2"):
        return np.zeros(shape, np.float32)
    return rng.normal(0.0, INIT_SCALE, size=shape).astype(np.float32)


def make_block_weights(cfg: ModelConfig, block_idx: int) -> Dict[str, np.ndarray]:
    """Deterministic weights for one transformer block (seeded by name+idx)."""
    seed = _stable_seed(cfg.name, "block", block_idx)
    rng = np.random.default_rng(seed)
    return {
        name: _init(rng, shape, name)
        for name, shape in block_weight_shapes(cfg).items()
    }


def make_timestep_table(cfg: ModelConfig) -> np.ndarray:
    """Sinusoidal timestep embeddings, (steps, H).

    Added host-side by the rust coordinator before block 0 each denoise
    step (DESIGN.md: conditioning enters the compute rows only, so the
    unmasked rows of a request follow the template trajectory exactly).
    """
    H = cfg.hidden
    t = np.arange(cfg.steps, dtype=np.float32)[:, None]
    half = H // 2
    freqs = np.exp(-math.log(10_000.0) * np.arange(half, dtype=np.float32) / half)
    ang = t * freqs[None, :]
    emb = np.concatenate([np.sin(ang), np.cos(ang)], axis=1)
    return (emb * 0.1).astype(np.float32)


def make_sigma_schedule(cfg: ModelConfig) -> np.ndarray:
    """Karras-flavoured decreasing noise schedule, (steps + 1,) ending at 0."""
    steps = cfg.steps
    rho = 3.0
    i = np.arange(steps, dtype=np.float32)
    sig = (1.0 ** (1 / rho) + i / max(steps - 1, 1) * (0.05 ** (1 / rho) - 1.0 ** (1 / rho))) ** rho
    return np.concatenate([sig, [0.0]]).astype(np.float32)


def make_decoder(cfg: ModelConfig) -> np.ndarray:
    """VAE-analogue decoder (H, IMAGE_CHANNELS); applied host-side in post."""
    seed = _stable_seed(cfg.name, "decoder")
    rng = np.random.default_rng(seed)
    return rng.normal(0.0, 1.0 / math.sqrt(cfg.hidden), size=(cfg.hidden, IMAGE_CHANNELS)).astype(np.float32)


def make_encoder(cfg: ModelConfig) -> np.ndarray:
    """VAE-analogue encoder (IMAGE_CHANNELS, H); applied host-side in pre."""
    seed = _stable_seed(cfg.name, "encoder")
    rng = np.random.default_rng(seed)
    return rng.normal(0.0, 1.0 / math.sqrt(IMAGE_CHANNELS), size=(IMAGE_CHANNELS, cfg.hidden)).astype(np.float32)


def export_weights(cfg: ModelConfig):
    """Build the flat f32 stream + layout manifest for one model.

    Returns:
        (data, entries): ``data`` is a 1-D float32 array; ``entries`` is a
        list of {name, shape, offset (floats), len (floats)} dicts.
    """
    tensors: List[Tuple[str, np.ndarray]] = []
    for b in range(cfg.blocks):
        weights = make_block_weights(cfg, b)
        for name in BLOCK_WEIGHT_ORDER:
            tensors.append((f"block{b}.{name}", weights[name]))
    tensors.append(("temb", make_timestep_table(cfg)))
    tensors.append(("sigmas", make_sigma_schedule(cfg)))
    tensors.append(("decoder", make_decoder(cfg)))
    tensors.append(("encoder", make_encoder(cfg)))

    entries = []
    chunks = []
    offset = 0
    for name, arr in tensors:
        flat = np.ascontiguousarray(arr, np.float32).reshape(-1)
        entries.append(
            {
                "name": name,
                "shape": list(arr.shape),
                "offset": offset,
                "len": int(flat.size),
            }
        )
        chunks.append(flat)
        offset += int(flat.size)
    data = np.concatenate(chunks)
    return data, entries
