"""AOT pipeline: lower the L2 model grid to HLO text + export weights.

Python runs ONCE here (``make artifacts``); the rust coordinator serves
from the produced files and never imports python.

Interchange format is HLO **text**, not ``lowered.compile().serialize()``:
jax >= 0.5 emits HloModuleProto with 64-bit instruction ids, which the xla
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly (/opt/xla-example/README.md).

Root convention (manifest ``root`` per artifact): single-output block
programs (``block_y`` / ``block_kv``) are lowered with
``return_tuple=False`` so the root is the bare ``(B, n, H)`` array —
the rust coordinator chains that output buffer device-to-device into
the next block without a host round trip. The 3-output registration
block keeps ``return_tuple=True`` (root ``"tuple"``); the rust side
unwraps its tuple literal on readback.

Outputs (under --out-dir, default ../artifacts):

    manifest.json            artifact + weight-layout + schedule index
    <model>_<kind>_n<ن>_b<B>.hlo.txt
    weights_<model>.bin      flat little-endian f32 stream
"""

import argparse
import hashlib
import json
import os
import sys
import time

import numpy as np
from jax._src.lib import xla_client as xc

from .configs import BATCH_BUCKETS, IMAGE_CHANNELS, MODELS, ModelConfig
from .weights import BLOCK_WEIGHT_ORDER, block_weight_shapes, export_weights
from . import model as model_lib

MANIFEST_VERSION = 4

# Manifest ``root`` value per artifact kind: single-output blocks are
# array-rooted (device-chainable), the registration block stays tupled.
ARTIFACT_ROOTS = {"block_y": "array", "block_kv": "array", "block_reg": "tuple"}


def to_hlo_text(lowered, return_tuple: bool = True) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=return_tuple
    )
    return comp.as_hlo_text()


def _artifact_name(model: str, kind: str, n: int, batch: int) -> str:
    return f"{model}_{kind}_n{n}_b{batch}"


def _lower_grid(cfg: ModelConfig):
    """Yield (name, kind, n, batch, lowered) for the whole artifact grid."""
    for batch in BATCH_BUCKETS:
        for n in cfg.all_token_counts():
            yield (
                _artifact_name(cfg.name, "blky", n, batch),
                "block_y",
                n,
                batch,
                model_lib.lower_block_y(cfg, n, batch),
            )
        for n in cfg.token_buckets():
            yield (
                _artifact_name(cfg.name, "blkv", n, batch),
                "block_kv",
                n,
                batch,
                model_lib.lower_block_kv(cfg, n, batch),
            )
    yield (
        _artifact_name(cfg.name, "breg", cfg.tokens, 1),
        "block_reg",
        cfg.tokens,
        1,
        model_lib.lower_block_reg(cfg),
    )


def _inputs_fingerprint() -> str:
    """Hash of the compile-path sources, for make-style staleness checks."""
    here = os.path.dirname(os.path.abspath(__file__))
    h = hashlib.sha256()
    for root, _, files in sorted(os.walk(here)):
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(root, f), "rb") as fh:
                    h.update(fh.read())
    return h.hexdigest()[:16]


def build(out_dir: str, models=None, verbose: bool = True) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {
        "version": MANIFEST_VERSION,
        "fingerprint": _inputs_fingerprint(),
        "image_channels": IMAGE_CHANNELS,
        "batch_buckets": BATCH_BUCKETS,
        "block_weight_order": BLOCK_WEIGHT_ORDER,
        "models": {},
    }
    t_total = time.time()
    for name, cfg in MODELS.items():
        if models and name not in models:
            continue
        t0 = time.time()
        artifacts = []
        for art_name, kind, n, batch, lowered in _lower_grid(cfg):
            root = ARTIFACT_ROOTS[kind]
            text = to_hlo_text(lowered, return_tuple=(root == "tuple"))
            fname = art_name + ".hlo.txt"
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(text)
            artifacts.append(
                {
                    "name": art_name,
                    "file": fname,
                    "kind": kind,
                    "n": n,
                    "batch": batch,
                    "root": root,
                }
            )
        data, entries = export_weights(cfg)
        wname = f"weights_{name}.bin"
        data.astype("<f4").tofile(os.path.join(out_dir, wname))
        manifest["models"][name] = {
            "latent_hw": cfg.latent_hw,
            "tokens": cfg.tokens,
            "hidden": cfg.hidden,
            "heads": cfg.heads,
            "blocks": cfg.blocks,
            "steps": cfg.steps,
            "paper_analogue": cfg.paper_analogue,
            "token_buckets": cfg.token_buckets(),
            "weights_file": wname,
            "weights": entries,
            "block_weight_shapes": {
                k: list(v) for k, v in block_weight_shapes(cfg).items()
            },
            "artifacts": artifacts,
        }
        if verbose:
            print(
                f"[aot] {name}: {len(artifacts)} artifacts, "
                f"{data.size * 4 / 1e6:.1f} MB weights, {time.time() - t0:.1f}s",
                file=sys.stderr,
            )
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if verbose:
        print(f"[aot] total {time.time() - t_total:.1f}s -> {out_dir}", file=sys.stderr)
    return manifest


def is_fresh(out_dir: str) -> bool:
    """True if the manifest exists and matches the current sources."""
    path = os.path.join(out_dir, "manifest.json")
    if not os.path.exists(path):
        return False
    try:
        with open(path) as f:
            m = json.load(f)
    except (OSError, json.JSONDecodeError):
        return False
    return (
        m.get("version") == MANIFEST_VERSION
        and m.get("fingerprint") == _inputs_fingerprint()
    )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    ap.add_argument("--models", nargs="*", help="subset of model presets")
    ap.add_argument(
        "--force", action="store_true", help="rebuild even if artifacts are fresh"
    )
    args = ap.parse_args()
    if not args.force and not args.models and is_fresh(args.out_dir):
        print("[aot] artifacts fresh; skipping (use --force to rebuild)", file=sys.stderr)
        return
    build(args.out_dir, models=args.models)


if __name__ == "__main__":
    main()
