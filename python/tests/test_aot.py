"""AOT pipeline: manifest correctness and HLO-text round-trip.

The round-trip test executes a lowered artifact through the same XLA CPU
client the rust runtime uses (via jax's bundled xla_client), proving the
HLO text is loadable and numerically equal to the jit path — the
python-side half of the interchange contract.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot
from compile import model as M
from compile.configs import BATCH_BUCKETS, MODELS
from compile.weights import BLOCK_WEIGHT_ORDER, make_block_weights

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def _manifest():
    path = os.path.join(ART_DIR, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(path) as f:
        return json.load(f)


def test_manifest_covers_full_grid():
    man = _manifest()
    assert man["version"] == aot.MANIFEST_VERSION
    assert man["block_weight_order"] == BLOCK_WEIGHT_ORDER
    for name, cfg in MODELS.items():
        m = man["models"][name]
        assert m["tokens"] == cfg.tokens
        assert m["blocks"] == cfg.blocks
        arts = {(a["kind"], a["n"], a["batch"]) for a in m["artifacts"]}
        for b in BATCH_BUCKETS:
            for n in cfg.all_token_counts():
                assert ("block_y", n, b) in arts
            for n in cfg.token_buckets():
                assert ("block_kv", n, b) in arts
        assert ("block_reg", cfg.tokens, 1) in arts
        for a in m["artifacts"]:
            assert os.path.exists(os.path.join(ART_DIR, a["file"]))
        assert os.path.exists(os.path.join(ART_DIR, m["weights_file"]))


def test_weights_file_matches_layout():
    man = _manifest()
    for name, m in man["models"].items():
        data = np.fromfile(
            os.path.join(ART_DIR, m["weights_file"]), dtype="<f4"
        )
        total = sum(e["len"] for e in m["weights"])
        assert data.size == total
        # spot-check one tensor against regeneration
        cfg = MODELS[name]
        want = make_block_weights(cfg, 0)["wq"].reshape(-1)
        entry = next(e for e in m["weights"] if e["name"] == "block0.wq")
        got = data[entry["offset"] : entry["offset"] + entry["len"]]
        np.testing.assert_array_equal(got, want)


def test_hlo_text_round_trip_executes():
    """Compile an artifact's HLO text with the raw XLA CPU client and
    compare against the jit execution — the same load path rust uses."""
    cfg = MODELS["sd21m"]
    n, batch = cfg.token_buckets()[1], 2
    lowered = M.lower_block_y(cfg, n, batch)
    text = aot.to_hlo_text(lowered)

    # the text must be well-formed HLO with the documented parameter order:
    # 1 data arg + 12 positional block weights (the rust loader re-parses
    # this text; the rust integration tests complete the round trip).
    assert "ENTRY" in text and "f32[" in text
    n_params = 1 + len(BLOCK_WEIGHT_ORDER)
    assert f"parameter({n_params - 1})" in text  # highest param present
    assert f"parameter({n_params})" not in text  # and nothing beyond

    # AOT-compiled executable (same lowering) matches the eager block.
    rng = np.random.default_rng(0)
    x = rng.normal(size=(batch, n, cfg.hidden)).astype(np.float32)
    w = make_block_weights(cfg, 0)
    exe = lowered.compile()
    (out,) = exe(jnp.asarray(x), *[jnp.asarray(w[k]) for k in BLOCK_WEIGHT_ORDER])

    want = M.block_y(
        jnp.asarray(x),
        M.BlockWeights(*[jnp.asarray(w[k]) for k in BLOCK_WEIGHT_ORDER]),
        heads=cfg.heads,
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=3e-5, rtol=3e-5)


def test_fingerprint_freshness():
    man = _manifest()
    # is_fresh must agree with the stored fingerprint
    assert aot.is_fresh(ART_DIR) == (man["fingerprint"] == aot._inputs_fingerprint())


def test_artifact_names_unique():
    man = _manifest()
    for m in man["models"].values():
        names = [a["name"] for a in m["artifacts"]]
        assert len(names) == len(set(names))


def test_artifact_roots_follow_kind_convention():
    # block_y / block_kv are array-rooted (device-chainable by the rust
    # step loop); the 3-output registration block stays tupled.
    man = _manifest()
    for m in man["models"].values():
        for a in m["artifacts"]:
            assert a["root"] == aot.ARTIFACT_ROOTS[a["kind"]]


def test_array_root_lowering_drops_tuple_wrapper():
    cfg = MODELS["sd21m"]
    n, batch = cfg.token_buckets()[0], 1
    lowered = M.lower_block_y(cfg, n, batch)
    array_text = aot.to_hlo_text(lowered, return_tuple=False)
    tuple_text = aot.to_hlo_text(lowered, return_tuple=True)
    # the array-rooted program ends in the bare (B, n, H) result; the
    # tupled one wraps it — both must stay parseable HLO text
    assert "ENTRY" in array_text and "ENTRY" in tuple_text
    assert "ROOT" in array_text
    assert array_text.count("tuple(") <= tuple_text.count("tuple(")
