"""Fig. 6 claims hold on the mini models: unmasked activations are more
similar across requests than masked ones, and attention mass concentrates
on the diagonal quadrants."""

from compile.analysis import run


def test_unmasked_activations_more_similar():
    r = run(model="sd21m", mask_ratio=0.25, seed=0)
    assert r["cos_unmasked"] > r["cos_masked"]
    assert r["cos_unmasked"] > 0.95  # "highly similar" (paper Fig. 6-Left)


def test_attention_quadrants_diagonal_dominant():
    r = run(model="sd21m", mask_ratio=0.25, seed=0)
    q = r["attention_quadrants"]
    # each row's diagonal entry carries more mass than its off-diagonal,
    # normalised by quadrant size (masked quadrant is small).
    L_frac = r["mask_ratio"]
    mm = q[0][0] / L_frac
    mu = q[0][1] / (1 - L_frac)
    uu = q[1][1] / (1 - L_frac)
    um = q[1][0] / L_frac
    assert mm > mu
    assert uu > um
