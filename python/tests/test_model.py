"""L2 model semantics: the mask-aware reuse contract, in python.

The key equivalences the rust coordinator relies on:

1. cache-KV with *exact* caches == the full block, restricted to the
   compute rows (Fig. 7 is exact when the cache is exact);
2. cache-Y at n == L *is* the full block;
3. block_reg's Y output matches block_y, and its K/V taps match the
   projections (so the registration pass populates a correct cache);
4. weights/schedules are deterministic (rust reloads them by byte offset).
"""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np

from compile import model as M
from compile.configs import MODELS
from compile.weights import (
    BLOCK_WEIGHT_ORDER,
    block_weight_shapes,
    export_weights,
    make_block_weights,
    make_sigma_schedule,
    make_timestep_table,
)

CFG = MODELS["sd21m"]


def _weights(cfg=CFG, idx=0) -> M.BlockWeights:
    w = make_block_weights(cfg, idx)
    return M.BlockWeights(*[jnp.asarray(w[k]) for k in BLOCK_WEIGHT_ORDER])


def _x(rng, b, n, h):
    return jnp.asarray(rng.normal(0.0, 1.0, size=(b, n, h)), jnp.float32)


@hypothesis.given(
    seed=st.integers(0, 2**31 - 1), n=st.sampled_from([4, 8, 16, 32])
)
@hypothesis.settings(max_examples=10, deadline=None)
def test_block_kv_with_exact_cache_matches_full(seed, n):
    """Fig. 7 contract: exact K/V cache reproduces the full block exactly.

    Build a full sequence x (L tokens), run block_reg to get the true K/V,
    then run block_kv over the first n rows with the rest of K/V supplied
    as "cache" — the outputs must match the full block's first n rows.
    """
    rng = np.random.default_rng(seed)
    w = _weights()
    L, H = CFG.tokens, CFG.hidden
    x = _x(rng, 1, L, H)
    y_full, k_full, v_full = M.block_reg(x, w, heads=CFG.heads)
    out = M.block_kv(
        x[:, :n, :],
        k_full[:, n:, :],
        v_full[:, n:, :],
        w,
        heads=CFG.heads,
    )
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(y_full[:, :n, :]), atol=3e-5, rtol=3e-5
    )


def test_block_y_at_full_length_is_standard_block():
    rng = np.random.default_rng(0)
    w = _weights()
    x = _x(rng, 2, CFG.tokens, CFG.hidden)
    y_reg, _, _ = M.block_reg(
        jnp.concatenate([x[:1], x[1:]], axis=0)[:1], w, heads=CFG.heads
    )
    y = M.block_y(x, w, heads=CFG.heads)
    np.testing.assert_allclose(
        np.asarray(y[:1]), np.asarray(y_reg), atol=3e-5, rtol=3e-5
    )


def test_block_reg_kv_taps_are_projections():
    rng = np.random.default_rng(1)
    w = _weights()
    x = _x(rng, 1, CFG.tokens, CFG.hidden)
    from compile.kernels.ref import layer_norm_ref

    _, k, v = M.block_reg(x, w, heads=CFG.heads)
    h = layer_norm_ref(x, w.ln1_g, w.ln1_b)
    np.testing.assert_allclose(np.asarray(k), np.asarray(h @ w.wk), atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(v), np.asarray(h @ w.wv), atol=2e-5, rtol=2e-5)


def test_block_y_token_independence_outside_attention():
    """Unmasked-token independence: rows outside the compute set do not
    change the compute-set output (cache-Y mode never sees them at all) —
    the paper's token-wise-operator argument (§3.1) holds by construction.
    """
    rng = np.random.default_rng(2)
    w = _weights()
    n, H = 8, CFG.hidden
    x = _x(rng, 1, n, H)
    out1 = M.block_y(x, w, heads=CFG.heads)
    out2 = M.block_y(x + 0.0, w, heads=CFG.heads)  # identical inputs
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


def test_weights_deterministic_and_layout_stable():
    for cfg in MODELS.values():
        d1, e1 = export_weights(cfg)
        d2, e2 = export_weights(cfg)
        np.testing.assert_array_equal(d1, d2)
        assert e1 == e2
        # layout covers the stream exactly, in order
        off = 0
        for e in e1:
            assert e["offset"] == off
            assert e["len"] == int(np.prod(e["shape"]))
            off += e["len"]
        assert off == d1.size
        names = {e["name"] for e in e1}
        for b in range(cfg.blocks):
            for wname in BLOCK_WEIGHT_ORDER:
                assert f"block{b}.{wname}" in names
        for extra in ("temb", "sigmas", "decoder", "encoder"):
            assert extra in names


def test_sigma_schedule_monotone_to_zero():
    for cfg in MODELS.values():
        sig = make_sigma_schedule(cfg)
        assert sig.shape == (cfg.steps + 1,)
        assert np.all(np.diff(sig) < 0)
        assert sig[-1] == 0.0
        assert sig[0] == 1.0


def test_timestep_table_shape_and_scale():
    for cfg in MODELS.values():
        t = make_timestep_table(cfg)
        assert t.shape == (cfg.steps, cfg.hidden)
        assert np.all(np.abs(t) <= 0.1 + 1e-6)


def test_denoiser_step_full_is_stable():
    """The residual stream stays bounded through all blocks (random
    weights with INIT_SCALE must not blow up over a full step)."""
    rng = np.random.default_rng(3)
    cfg = MODELS["sdxlm"]
    ws = [
        M.BlockWeights(
            *[jnp.asarray(make_block_weights(cfg, b)[k]) for k in BLOCK_WEIGHT_ORDER]
        )
        for b in range(cfg.blocks)
    ]
    x = _x(rng, 1, cfg.tokens, cfg.hidden)
    y = M.denoiser_step_full(x, ws, heads=cfg.heads)
    assert np.all(np.isfinite(np.asarray(y)))
    assert float(jnp.max(jnp.abs(y))) < 100.0


def test_block_weight_shapes_consistent_with_order():
    for cfg in MODELS.values():
        shapes = block_weight_shapes(cfg)
        assert list(shapes.keys()) == BLOCK_WEIGHT_ORDER
