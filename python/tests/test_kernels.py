"""L1 kernel correctness: Pallas vs pure-jnp oracle, hypothesis-swept.

The masked-attention kernel is the paper's compute hot-spot; any numeric
divergence here propagates into every cached activation, so the sweep
covers the full bucket grid (odd token counts included — sdxlm buckets are
9/18/36/72) and both cache modes (m == n and m > n).
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import fused_ffn, masked_attention
from compile.kernels import ref
from compile.kernels.ffn import vmem_footprint_bytes as ffn_vmem
from compile.kernels.masked_attention import (
    _largest_divisor_leq,
    vmem_footprint_bytes as attn_vmem,
)

SETTINGS = dict(max_examples=25, deadline=None)


def _rand(rng, shape, dtype=np.float32):
    return jnp.asarray(rng.normal(0.0, 1.0, size=shape), dtype)


@hypothesis.given(
    b=st.sampled_from([1, 2, 4, 8]),
    heads=st.sampled_from([4, 6, 8]),
    n=st.sampled_from([4, 8, 9, 16, 18, 32, 36, 64, 72, 128]),
    extra=st.sampled_from([0, 7, 32, 128]),
    dh=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
@hypothesis.settings(**SETTINGS)
def test_masked_attention_matches_ref(b, heads, n, extra, dh, seed):
    """Cache-Y (extra == 0) and cache-KV (extra > 0) modes match the oracle."""
    m = n + extra
    rng = np.random.default_rng(seed)
    q = _rand(rng, (b, heads, n, dh))
    k = _rand(rng, (b, heads, m, dh))
    v = _rand(rng, (b, heads, m, dh))
    out = masked_attention(q, k, v)
    want = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5, rtol=2e-5)


@hypothesis.given(
    rows=st.sampled_from([4, 9, 16, 36, 64, 72, 144, 256]),
    h=st.sampled_from([64, 96, 128]),
    seed=st.integers(0, 2**31 - 1),
)
@hypothesis.settings(**SETTINGS)
def test_fused_ffn_matches_ref(rows, h, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, (rows, h))
    w1 = _rand(rng, (h, 4 * h)) * 0.05
    b1 = _rand(rng, (4 * h,)) * 0.05
    w2 = _rand(rng, (4 * h, h)) * 0.05
    b2 = _rand(rng, (h,)) * 0.05
    out = fused_ffn(x, w1, b1, w2, b2)
    want = ref.ffn_ref(x, w1, b1, w2, b2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5, rtol=2e-5)


def test_masked_attention_bf16_runs():
    """bf16 inputs (the TPU target dtype) stay finite and close to f32."""
    rng = np.random.default_rng(0)
    q = _rand(rng, (2, 4, 16, 16))
    k = _rand(rng, (2, 4, 64, 16))
    v = _rand(rng, (2, 4, 64, 16))
    out16 = masked_attention(
        q.astype(jnp.bfloat16), k.astype(jnp.bfloat16), v.astype(jnp.bfloat16)
    )
    out32 = masked_attention(q, k, v)
    assert out16.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(out16, np.float32), np.asarray(out32), atol=0.05, rtol=0.05
    )


def test_masked_attention_rejects_shape_mismatch():
    q = jnp.zeros((1, 4, 8, 16))
    k = jnp.zeros((1, 4, 8, 8))
    with pytest.raises(ValueError):
        masked_attention(q, k, k)


@hypothesis.given(n=st.integers(1, 512), cap=st.integers(1, 64))
@hypothesis.settings(**SETTINGS)
def test_largest_divisor_invariants(n, cap):
    d = _largest_divisor_leq(n, cap)
    assert 1 <= d <= min(n, cap)
    assert n % d == 0


def test_vmem_footprint_under_budget_at_paper_scale():
    """Structural perf check: paper-scale shapes fit the 16 MiB VMEM budget."""
    # SDXL-scale latent: 128x128 tokens = 16384, dh = 64; Flux: 4096, dh=128.
    assert attn_vmem(n=16384, m=16384, dh=64) < 16 * 2**20
    assert attn_vmem(n=4096, m=4096, dh=128) < 16 * 2**20
    assert ffn_vmem(r=4096, h=128, f=512) < 16 * 2**20


def test_attention_is_permutation_equivariant_over_queries():
    """Masked-first permutation safety: permuting Q rows permutes outputs.

    This is the property that lets the coordinator put masked tokens first
    and crop, instead of gather/scatter inside the kernel.
    """
    rng = np.random.default_rng(1)
    q = _rand(rng, (1, 2, 16, 8))
    k = _rand(rng, (1, 2, 32, 8))
    v = _rand(rng, (1, 2, 32, 8))
    perm = rng.permutation(16)
    out = np.asarray(masked_attention(q, k, v))
    out_p = np.asarray(masked_attention(q[:, :, perm, :], k, v))
    np.testing.assert_allclose(out[:, :, perm, :], out_p, atol=2e-5, rtol=2e-5)
