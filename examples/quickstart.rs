//! Quickstart: edit one image template with InstGenIE.
//!
//! Loads the small model, registers a template (one full inference pass,
//! populating the activation cache), then serves three masked edit
//! requests through a single worker — printing latency and verifying the
//! unmasked region is untouched.
//!
//! Run: `cargo run --release --example quickstart`
//! (requires `make artifacts`)

use std::sync::mpsc::channel;
use std::sync::Arc;

use instgenie::cache::{LatencyModel, TieredStore};
use instgenie::config::{EngineConfig, SystemKind};
use instgenie::engine::{EditRequestBuilder, Worker, WorkerEvent};
use instgenie::model::MaskSpec;
use instgenie::runtime::ModelRuntime;
use instgenie::util::rng::Pcg;

fn main() -> anyhow::Result<()> {
    // 1. runtime: loads AOT artifacts + weights, owns the PJRT client
    let rt = ModelRuntime::create("artifacts", "sd21m")?;
    let hw = rt.config.latent_hw;
    println!(
        "model sd21m: {} tokens, {} blocks, {} denoise steps",
        rt.config.tokens, rt.config.blocks, rt.config.steps
    );

    // 2. worker: cache tiers + loader + continuous batcher
    let tiers = Arc::new(TieredStore::new(256 << 20, "artifacts/cache_spill".into(), 0.0));
    let (results_tx, results_rx) = channel();
    let worker = Worker::new(
        0,
        EngineConfig::for_system(SystemKind::InstGenIE),
        rt,
        tiers,
        LatencyModel::load_or_nominal("artifacts", "sd21m"),
        results_tx,
    );

    // 3. register the image template (the paper's §4.2 cache build)
    let t0 = std::time::Instant::now();
    worker.ensure_registered("quickstart-template")?;
    println!("template registered (activation cache built) in {:?}", t0.elapsed());

    // 4. serve three edits with different masks
    let submit = worker.submitter();
    let stop = worker.stop_flag();
    let handle = worker.start();
    let mut rng = Pcg::new(7);
    for i in 0..3u64 {
        let mask = MaskSpec::synth(hw, 0.15, &mut rng);
        println!(
            "request {i}: editing {} / {} tokens (ratio {:.2})",
            mask.masked_count(),
            mask.tokens(),
            mask.ratio()
        );
        let req = EditRequestBuilder::new(i)
            .template("quickstart-template")
            .prompt_seed(100 + i)
            .mask(mask)
            .build()?;
        submit.submit(req);
    }
    let mut done = 0;
    while done < 3 {
        match results_rx.recv()? {
            WorkerEvent::Started { id, .. } => println!("  .. id={id} joined the batch"),
            WorkerEvent::Finished { result, .. } => {
                let resp = result?;
                println!(
                    "  -> done id={} queue={:.1}ms inference={:.1}ms e2e={:.1}ms image={}x{}",
                    resp.id,
                    resp.timing.queue * 1e3,
                    resp.timing.inference * 1e3,
                    resp.timing.e2e * 1e3,
                    resp.image.shape()[0],
                    resp.image.shape()[1],
                );
                done += 1;
            }
        }
    }
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    handle.join().unwrap()?;
    println!("quickstart OK");
    Ok(())
}
