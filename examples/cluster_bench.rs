//! Cluster bench smoke: replay one Poisson trace per routing policy
//! through a small cluster and write `BENCH_cluster.json` (throughput +
//! p50/p99 end-to-end latency per scheduler). `ci.sh` runs this after
//! the test suite so every PR leaves a comparable perf record.
//!
//! Run: `cargo run --release --example cluster_bench -- [requests] [rps] [workers]`

use std::time::Duration;

use instgenie::cache::LatencyModel;
use instgenie::cluster::{Cluster, ClusterOpts};
use instgenie::config::{EngineConfig, SystemKind};
use instgenie::metrics::Recorder;
use instgenie::runtime::Manifest;
use instgenie::scheduler;
use instgenie::util::json::Json;
use instgenie::workload::{replay, MaskDist, TraceGen};

const TEMPLATES: usize = 2;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let requests: usize = args.first().and_then(|a| a.parse().ok()).unwrap_or(24);
    let rps: f64 = args.get(1).and_then(|a| a.parse().ok()).unwrap_or(8.0);
    let workers: usize = args.get(2).and_then(|a| a.parse().ok()).unwrap_or(2);

    let Ok(manifest) = Manifest::load("artifacts") else {
        eprintln!("[cluster_bench] no artifacts; skipping (run `make artifacts`)");
        return Ok(());
    };
    // smallest model for a smoke run, falling back to whatever is built
    let model = if manifest.models.contains_key("sd21m") {
        "sd21m".to_string()
    } else {
        match manifest.models.keys().next() {
            Some(m) => m.clone(),
            None => {
                eprintln!("[cluster_bench] empty manifest; skipping");
                return Ok(());
            }
        }
    };
    let mcfg = manifest.model(&model)?.config.clone();
    let lat = LatencyModel::load_or_nominal("artifacts", &model);

    println!("== cluster bench smoke: model={model} workers={workers} rps={rps} requests={requests} ==");
    let mut rows: Vec<(&str, Json)> = Vec::new();
    for sched_name in scheduler::POLICY_NAMES {
        let mut engine = EngineConfig::for_system(SystemKind::InstGenIE);
        engine.prepost_cpu_us = 200;
        let sched =
            scheduler::by_name(sched_name, &mcfg, &lat, engine.cache_mode, engine.max_batch)
                .expect("scheduler");
        let cluster = Cluster::launch(
            ClusterOpts {
                workers,
                engine,
                model: model.clone(),
                artifact_dir: "artifacts".into(),
                templates: (0..TEMPLATES).map(|i| format!("tpl-{i}")).collect(),
                lat_model: lat.clone(),
                warmup: true,
            },
            sched,
        )?;
        let gen = TraceGen::new(rps, MaskDist::Production, TEMPLATES, 42);
        let events = gen.generate(requests);
        let t0 = std::time::Instant::now();
        replay(&events, |ev| {
            cluster.submit_event(ev);
        });
        anyhow::ensure!(
            cluster.await_completed(events.len(), Duration::from_secs(600)),
            "{sched_name}: serving timed out"
        );
        let makespan = t0.elapsed().as_secs_f64();
        let responses = cluster.shutdown()?;
        let mut rec = Recorder::new();
        for r in &responses {
            rec.record(r);
        }
        let rep = rec.report(makespan);
        println!(
            "{sched_name:>12}: tput={:.2} req/s  e2e p50={:.1}ms p99={:.1}ms  queue mean={:.1}ms",
            rep.throughput,
            rep.e2e.p50 * 1e3,
            rep.e2e.p99 * 1e3,
            rep.queue.mean * 1e3,
        );
        rows.push((
            sched_name,
            Json::obj(vec![
                ("throughput", Json::num(rep.throughput)),
                ("p50_e2e", Json::num(rep.e2e.p50)),
                ("p95_e2e", Json::num(rep.e2e.p95)),
                ("p99_e2e", Json::num(rep.e2e.p99)),
                ("mean_e2e", Json::num(rep.e2e.mean)),
                ("mean_queue", Json::num(rep.queue.mean)),
                ("completed", Json::num(rep.completed as f64)),
                ("makespan", Json::num(rep.makespan)),
            ]),
        ));
    }

    let out = Json::obj(vec![
        ("model", Json::str(model)),
        ("workers", Json::num(workers as f64)),
        ("requests", Json::num(requests as f64)),
        ("rps", Json::num(rps)),
        ("templates", Json::num(TEMPLATES as f64)),
        ("schedulers", Json::obj(rows)),
    ]);
    std::fs::write("BENCH_cluster.json", out.to_string())?;
    println!("[cluster_bench] wrote BENCH_cluster.json");
    Ok(())
}
