//! Fault-injection bench: replay one Poisson trace through the
//! distributed plane (router + worker nodes over loopback RPC) at swept
//! injected fault rates — 0%, 1%, 5% across disk corruption, loader
//! drops, device-upload refusals, step-boundary crashes and transport
//! faults — and write `BENCH_faults.json`: throughput + p50/p99 per
//! rate, degraded-block counts per ladder rung, breaker trips, and
//! retry-budget spend.
//!
//! **Hard gate:** zero failed requests at every swept rate. The whole
//! point of the degradation ladder is that injected faults cost latency,
//! never correctness — a single failed request fails the bench (and
//! ci.sh with it).
//!
//! Run: `cargo run --release --example fault_bench -- [requests] [rps] [workers]`

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use instgenie::cache::LatencyModel;
use instgenie::cluster::ClusterOpts;
use instgenie::config::{EngineConfig, SystemKind};
use instgenie::dist::{DistConfig, Router, WorkerNode};
use instgenie::faults::{FaultPlan, FaultSite};
use instgenie::metrics::Recorder;
use instgenie::runtime::Manifest;
use instgenie::scheduler;
use instgenie::util::json::Json;
use instgenie::workload::{replay, MaskDist, TraceGen};

const TEMPLATES: usize = 2;
const SCHED: &str = "round-robin";
const SEED: u64 = 43;
const RATES: [f64; 3] = [0.0, 0.01, 0.05];

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("ig-faultbench-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&d).expect("spill dir");
    d
}

/// The swept plan: one rate across every ladder rung — storage, loader,
/// device retention, engine crashes, transport.
fn plan(rate: f64) -> Option<FaultPlan> {
    if rate <= 0.0 {
        return None;
    }
    Some(
        FaultPlan::new(SEED)
            .with_rate(FaultSite::DiskRead, rate)
            .with_rate(FaultSite::DiskCorrupt, rate)
            .with_rate(FaultSite::LoaderFail, rate)
            .with_rate(FaultSite::DeviceUpload, rate)
            .with_rate(FaultSite::WorkerCrash, rate)
            .with_rate(FaultSite::RpcDrop, rate)
            .with_rate(FaultSite::RpcConnect, rate)
            .with_rate(FaultSite::RpcDelay, rate),
    )
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let requests: usize = args.first().and_then(|a| a.parse().ok()).unwrap_or(16);
    let rps: f64 = args.get(1).and_then(|a| a.parse().ok()).unwrap_or(8.0);
    let workers: usize = args.get(2).and_then(|a| a.parse().ok()).unwrap_or(2).max(1);

    let Ok(manifest) = Manifest::load("artifacts") else {
        eprintln!("[fault_bench] no artifacts; skipping (run `make artifacts`)");
        return Ok(());
    };
    let model = if manifest.models.contains_key("sd21m") {
        "sd21m".to_string()
    } else {
        match manifest.models.keys().next() {
            Some(m) => m.clone(),
            None => {
                eprintln!("[fault_bench] empty manifest; skipping");
                return Ok(());
            }
        }
    };
    let mcfg = manifest.model(&model)?.config.clone();
    let lat = LatencyModel::load_or_nominal("artifacts", &model);
    let events = TraceGen::new(rps, MaskDist::Production, TEMPLATES, 42).generate(requests);
    println!(
        "== fault bench: model={model} workers={workers} rps={rps} requests={requests} \
         rates={RATES:?} =="
    );

    let mut rows: Vec<Json> = Vec::new();
    for (sweep, &rate) in RATES.iter().enumerate() {
        // fresh plane per rate: small host budget keeps the disk tier on
        // the serving path so storage faults actually exercise the ladder
        let engine = |tag: &str| {
            let mut e = EngineConfig::for_system(SystemKind::InstGenIE);
            e.prepost_cpu_us = 200;
            e.host_cache_budget = 1;
            e.spill_dir = tmp_dir(&format!("{tag}-{sweep}"));
            e.faults = plan(rate);
            e
        };
        let mut cfg = DistConfig::fast();
        cfg.faults = plan(rate);

        let e = engine("sched");
        let sched = scheduler::by_name(SCHED, &mcfg, &lat, e.cache_mode, e.max_batch)
            .expect("scheduler");
        let router = Router::new(mcfg.clone(), sched, None, cfg.clone());
        let addr = router.start("127.0.0.1:0")?;
        let mut nodes: Vec<Arc<WorkerNode>> = Vec::new();
        for i in 0..workers {
            let opts = ClusterOpts {
                workers: 1,
                engine: engine(&format!("w{i}")),
                model: model.clone(),
                artifact_dir: "artifacts".into(),
                templates: (0..TEMPLATES).map(|i| format!("tpl-{i}")).collect(),
                lat_model: lat.clone(),
                warmup: false,
            };
            let node = Arc::new(WorkerNode::launch(format!("w{i}"), opts)?);
            node.start("127.0.0.1:0")?;
            node.announce_to(&addr.to_string(), &cfg);
            nodes.push(node);
        }
        let deadline = Instant::now() + Duration::from_secs(120);
        while router.ready_count() < workers {
            anyhow::ensure!(Instant::now() < deadline, "workers never became ready");
            std::thread::sleep(Duration::from_millis(50));
        }

        let t0 = Instant::now();
        let mut tickets = Vec::new();
        let mut rec = Recorder::new();
        replay(&events, |ev| match router.submit_event(ev) {
            Ok(t) => tickets.push(t),
            Err(e) => rec.record_failure(&e),
        });
        for t in &tickets {
            match t.wait(Duration::from_secs(600)) {
                Ok(resp) => rec.record(&resp),
                Err(e) => rec.record_failure(&e),
            }
        }
        let makespan = t0.elapsed().as_secs_f64();
        let rep = rec.report(makespan);

        // ladder observability, read off the in-thread worker engines
        let mut degraded = (0u64, 0u64, 0u64);
        let mut trips = 0u64;
        for n in &nodes {
            for s in n.cluster().worker_snapshots() {
                degraded.0 += s.transfers.cache_degraded_disk;
                degraded.1 += s.transfers.cache_degraded_device;
                degraded.2 += s.transfers.cache_degraded_loader;
            }
            trips += n.cluster().breaker_trips();
        }
        let (_, cluster_body) = router.route("GET", "/v1/cluster", "");
        let retry_spent = cluster_body.at("retry_budget_spent").as_f64().unwrap_or(0.0);

        router.shutdown();
        for n in &nodes {
            n.stop();
        }

        println!(
            "   rate={:>4.1}%  tput={:.2} req/s  p50={:.1}ms p99={:.1}ms  \
             degraded disk/dev/loader={}/{}/{}  trips={trips}  retries={retry_spent}",
            rate * 100.0,
            rep.throughput,
            rep.e2e.p50 * 1e3,
            rep.e2e.p99 * 1e3,
            degraded.0,
            degraded.1,
            degraded.2,
        );
        // the hard gate: faults may cost latency, never a request
        anyhow::ensure!(
            rep.failed == 0 && rep.completed == events.len(),
            "fault rate {rate}: {}/{} completed, {} failed — the degradation \
             ladder must absorb every injected fault",
            rep.completed,
            events.len(),
            rep.failed
        );
        rows.push(Json::obj(vec![
            ("fault_rate", Json::num(rate)),
            ("throughput", Json::num(rep.throughput)),
            ("p50_e2e", Json::num(rep.e2e.p50)),
            ("p95_e2e", Json::num(rep.e2e.p95)),
            ("p99_e2e", Json::num(rep.e2e.p99)),
            ("mean_e2e", Json::num(rep.e2e.mean)),
            ("completed", Json::num(rep.completed as f64)),
            ("failed", Json::num(rep.failed as f64)),
            ("makespan", Json::num(rep.makespan)),
            ("degraded_disk", Json::num(degraded.0 as f64)),
            ("degraded_device", Json::num(degraded.1 as f64)),
            ("degraded_loader", Json::num(degraded.2 as f64)),
            ("breaker_trips", Json::num(trips as f64)),
            ("retry_budget_spent", Json::num(retry_spent)),
        ]));
    }

    let out = Json::obj(vec![
        ("model", Json::str(model)),
        ("workers", Json::num(workers as f64)),
        ("requests", Json::num(requests as f64)),
        ("rps", Json::num(rps)),
        ("seed", Json::num(SEED as f64)),
        ("gate", Json::str("zero failed requests at every swept fault rate")),
        ("sweeps", Json::arr(rows)),
    ]);
    std::fs::write("BENCH_faults.json", out.to_string())?;
    println!("[fault_bench] wrote BENCH_faults.json (gate: zero failed requests)");
    Ok(())
}
