//! Durability bench: what does the write-ahead journal cost, and how
//! fast is recovery?
//!
//! Three measurements, written to `BENCH_recovery.json`:
//!
//! 1. **Journal micro-bench** (always runs, no artifacts needed):
//!    append throughput per fsync policy (`always` / `batched` / `off`)
//!    and cold replay time over the same records.
//! 2. **Serving overhead** (needs artifacts): the same trace through the
//!    dist plane with the journal off vs on at the default `batched`
//!    policy. **Hard gate:** journaled throughput ≥ 95% of the volatile
//!    baseline — durability must cost less than 5% of throughput.
//! 3. **Recovery time** (needs artifacts): after the journaled run, a
//!    cold router replays the journal back into registries — the time
//!    from "process start" to "ready to place work".
//!
//! Run: `cargo run --release --example recovery_bench -- [requests] [rps] [workers]`

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use instgenie::cache::LatencyModel;
use instgenie::cluster::ClusterOpts;
use instgenie::config::{EngineConfig, SystemKind};
use instgenie::dist::{DistConfig, Router, WorkerNode};
use instgenie::durable::{self, FsyncPolicy, Journal, JournalConfig};
use instgenie::metrics::Recorder;
use instgenie::runtime::Manifest;
use instgenie::scheduler;
use instgenie::util::json::Json;
use instgenie::workload::{replay, MaskDist, TraceEvent, TraceGen};

const TEMPLATES: usize = 2;
const SCHED: &str = "round-robin";
const OVERHEAD_GATE: f64 = 0.95; // journaled tput must stay within 5%

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("ig-recbench-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).expect("bench dir");
    d
}

/// Append `n` records under `policy`, then cold-replay them; returns
/// (appends/sec, replay millis).
fn journal_micro(policy: FsyncPolicy, n: usize) -> anyhow::Result<(f64, f64)> {
    let mut cfg = JournalConfig::new(tmp_dir(&format!("micro-{}", policy.label())));
    cfg.fsync = policy;
    let (mut j, _) = Journal::open(cfg.clone())?;
    let t0 = Instant::now();
    for i in 0..n {
        j.append(&durable::rec_req_state(i as u64, "done"))?;
    }
    j.flush()?;
    let append_secs = t0.elapsed().as_secs_f64();
    drop(j);

    let t0 = Instant::now();
    let (_, rep) = Journal::open(cfg)?;
    let replay_ms = t0.elapsed().as_secs_f64() * 1e3;
    anyhow::ensure!(rep.records.len() == n, "replay lost records: {}/{n}", rep.records.len());
    Ok((n as f64 / append_secs.max(1e-9), replay_ms))
}

/// One trace through router + worker nodes; returns throughput (req/s).
/// Fails hard if any request is lost.
fn run_trace(
    mcfg: &instgenie::config::ModelConfig,
    lat: &LatencyModel,
    model: &str,
    events: &[TraceEvent],
    cfg: &DistConfig,
    workers: usize,
    tag: &str,
) -> anyhow::Result<f64> {
    let e0 = EngineConfig::for_system(SystemKind::InstGenIE);
    let sched = scheduler::by_name(SCHED, mcfg, lat, e0.cache_mode, e0.max_batch)
        .expect("scheduler");
    let router = Router::new(mcfg.clone(), sched, None, cfg.clone());
    let addr = router.start("127.0.0.1:0")?;
    let mut nodes: Vec<Arc<WorkerNode>> = Vec::new();
    for i in 0..workers {
        let mut e = EngineConfig::for_system(SystemKind::InstGenIE);
        e.prepost_cpu_us = 200;
        e.spill_dir = tmp_dir(&format!("{tag}-w{i}"));
        let opts = ClusterOpts {
            workers: 1,
            engine: e,
            model: model.to_string(),
            artifact_dir: "artifacts".into(),
            templates: (0..TEMPLATES).map(|i| format!("tpl-{i}")).collect(),
            lat_model: lat.clone(),
            warmup: false,
        };
        let node = Arc::new(WorkerNode::launch(format!("{tag}-w{i}"), opts)?);
        node.start("127.0.0.1:0")?;
        node.announce_to(&addr.to_string(), cfg);
        nodes.push(node);
    }
    let deadline = Instant::now() + Duration::from_secs(120);
    while router.ready_count() < workers {
        anyhow::ensure!(Instant::now() < deadline, "workers never became ready");
        std::thread::sleep(Duration::from_millis(50));
    }

    let t0 = Instant::now();
    let mut tickets = Vec::new();
    let mut rec = Recorder::new();
    replay(events, |ev| match router.submit_event(ev) {
        Ok(t) => tickets.push(t),
        Err(e) => rec.record_failure(&e),
    });
    for t in &tickets {
        match t.wait(Duration::from_secs(600)) {
            Ok(resp) => rec.record(&resp),
            Err(e) => rec.record_failure(&e),
        }
    }
    let rep = rec.report(t0.elapsed().as_secs_f64());
    router.shutdown();
    for n in &nodes {
        n.stop();
    }
    anyhow::ensure!(
        rep.failed == 0 && rep.completed == events.len(),
        "{tag}: {}/{} completed, {} failed — journaling must never cost a request",
        rep.completed,
        events.len(),
        rep.failed
    );
    Ok(rep.throughput)
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let requests: usize = args.first().and_then(|a| a.parse().ok()).unwrap_or(24);
    let rps: f64 = args.get(1).and_then(|a| a.parse().ok()).unwrap_or(200.0);
    let workers: usize = args.get(2).and_then(|a| a.parse().ok()).unwrap_or(2).max(1);

    // 1. journal micro-bench: always runs
    println!("== recovery bench: journal micro ==");
    let mut micro_rows = Vec::new();
    for (policy, n) in [
        (FsyncPolicy::Always, 500usize),
        (FsyncPolicy::Batched, 5000),
        (FsyncPolicy::Off, 5000),
    ] {
        let (aps, replay_ms) = journal_micro(policy, n)?;
        println!(
            "   fsync={:<7} appends/s={aps:>10.0}  cold replay of {n} recs: {replay_ms:.1}ms",
            policy.label()
        );
        micro_rows.push(Json::obj(vec![
            ("fsync", Json::str(policy.label())),
            ("records", Json::num(n as f64)),
            ("appends_per_sec", Json::num(aps)),
            ("replay_ms", Json::num(replay_ms)),
        ]));
    }

    // 2 + 3. serving overhead + recovery time: need artifacts
    let mut serving = Json::Null;
    if let Ok(manifest) = Manifest::load("artifacts") {
        let model = if manifest.models.contains_key("sd21m") {
            "sd21m".to_string()
        } else {
            manifest.models.keys().next().cloned().unwrap_or_default()
        };
        if !model.is_empty() {
            let mcfg = manifest.model(&model)?.config.clone();
            let lat = LatencyModel::load_or_nominal("artifacts", &model);
            let events = TraceGen::new(rps, MaskDist::Production, TEMPLATES, 47).generate(requests);
            println!(
                "== recovery bench: serving overhead model={model} workers={workers} \
                 requests={requests} rps={rps} =="
            );

            let volatile_cfg = DistConfig::fast();
            let jdir = tmp_dir("serve-journal");
            let mut journaled_cfg = DistConfig::fast();
            journaled_cfg.journal_dir = Some(jdir.clone());
            // default policy under test: batched group fsync

            // interleave two runs per arm; best-of-two damps scheduler noise
            let mut base_tput = 0f64;
            let mut jour_tput = 0f64;
            for round in 0..2 {
                let b = run_trace(&mcfg, &lat, &model, &events, &volatile_cfg, workers,
                    &format!("base{round}"))?;
                let j = run_trace(&mcfg, &lat, &model, &events, &journaled_cfg, workers,
                    &format!("jour{round}"))?;
                base_tput = base_tput.max(b);
                jour_tput = jour_tput.max(j);
            }
            let overhead_pct = (1.0 - jour_tput / base_tput) * 100.0;

            // recovery time: a cold router replays the journal the
            // serving runs just wrote (members, requests, sessions)
            let t0 = Instant::now();
            let e0 = EngineConfig::for_system(SystemKind::InstGenIE);
            let sched = scheduler::by_name(SCHED, &mcfg, &lat, e0.cache_mode, e0.max_batch)
                .expect("scheduler");
            let recovered = Router::new(mcfg.clone(), sched, None, journaled_cfg.clone());
            let recovery_ms = t0.elapsed().as_secs_f64() * 1e3;
            recovered.shutdown();

            println!(
                "   baseline={base_tput:.2} req/s  journaled={jour_tput:.2} req/s  \
                 overhead={overhead_pct:.2}%  cold recovery={recovery_ms:.1}ms"
            );
            // the hard gate: durability must cost < 5% throughput
            anyhow::ensure!(
                jour_tput >= OVERHEAD_GATE * base_tput,
                "journal overhead gate failed: {jour_tput:.2} req/s journaled vs \
                 {base_tput:.2} req/s baseline ({overhead_pct:.2}% > 5%)"
            );
            serving = Json::obj(vec![
                ("model", Json::str(model)),
                ("workers", Json::num(workers as f64)),
                ("requests", Json::num(requests as f64)),
                ("rps", Json::num(rps)),
                ("fsync", Json::str(FsyncPolicy::default().label())),
                ("baseline_throughput", Json::num(base_tput)),
                ("journaled_throughput", Json::num(jour_tput)),
                ("overhead_pct", Json::num(overhead_pct)),
                ("recovery_ms", Json::num(recovery_ms)),
            ]);
        }
    } else {
        eprintln!("[recovery_bench] no artifacts; journal micro-bench only");
    }

    let out = Json::obj(vec![
        ("gate", Json::str(format!(
            "journaled throughput >= {:.0}% of volatile baseline at default fsync",
            OVERHEAD_GATE * 100.0
        ))),
        ("journal_micro", Json::arr(micro_rows)),
        ("serving", serving),
    ]);
    std::fs::write("BENCH_recovery.json", out.to_string())?;
    println!("[recovery_bench] wrote BENCH_recovery.json");
    Ok(())
}
