//! QoS bench smoke: replay one overloaded mixed-class Poisson trace
//! through a small cluster twice — FIFO baseline vs the QoS subsystem
//! (priority queues + aging, step-boundary preemption, qos-aware
//! routing) — and write `BENCH_qos.json` with per-class throughput and
//! p50/p99 latency, plus an admission-control demonstration (bounded
//! queue: over-capacity submissions shed with 429/`Retry-After`).
//! `ci.sh` runs this after the cluster bench so every PR leaves a
//! comparable QoS perf record.
//!
//! Run: `cargo run --release --example qos_bench -- [requests] [rps] [workers]`

use std::time::Duration;

use instgenie::cache::LatencyModel;
use instgenie::cluster::{Cluster, ClusterOpts, RequestState};
use instgenie::config::{EngineConfig, SystemKind};
use instgenie::engine::request::EditError;
use instgenie::metrics::{Recorder, Report};
use instgenie::qos::{Priority, QosConfig};
use instgenie::runtime::Manifest;
use instgenie::scheduler;
use instgenie::util::json::Json;
use instgenie::workload::{replay, ClassMix, MaskDist, TraceGen};

const TEMPLATES: usize = 2;
const CLASS_MIX: &str = "0.25,0.5,0.25";

struct ModeOutcome {
    report: Report,
    admitted: usize,
    shed: usize,
    batch_admitted: usize,
}

fn run_mode(
    name: &str,
    qos: bool,
    model: &str,
    lat: &LatencyModel,
    requests: usize,
    rps: f64,
    workers: usize,
) -> anyhow::Result<ModeOutcome> {
    let mut engine = EngineConfig::for_system(SystemKind::InstGenIE);
    engine.prepost_cpu_us = 200;
    engine.qos = if qos {
        QosConfig { aging_ms: 500, ..QosConfig::standard() }
    } else {
        QosConfig::disabled()
    };
    let sched_name = if qos { "qos-aware" } else { "mask-aware" };
    let manifest = Manifest::load("artifacts")?;
    let mcfg = manifest.model(model)?.config.clone();
    let sched = scheduler::by_name(sched_name, &mcfg, lat, engine.cache_mode, engine.max_batch)
        .expect("scheduler");
    let cluster = Cluster::launch(
        ClusterOpts {
            workers,
            engine,
            model: model.to_string(),
            artifact_dir: "artifacts".into(),
            templates: (0..TEMPLATES).map(|i| format!("tpl-{i}")).collect(),
            lat_model: lat.clone(),
            warmup: true,
        },
        sched,
    )?;
    let gen = TraceGen::new(rps, MaskDist::Production, TEMPLATES, 42)
        .with_mix(ClassMix::parse(CLASS_MIX).expect("mix"));
    let events = gen.generate(requests);
    let batch_total = events.iter().filter(|e| e.priority == Priority::Batch).count();

    let mut rec = Recorder::new();
    let mut tickets = Vec::new();
    let mut shed = 0usize;
    let mut batch_shed = 0usize;
    let t0 = std::time::Instant::now();
    replay(&events, |ev| {
        match cluster.submit_guarded(cluster.event_request(ev)) {
            Ok(t) => tickets.push(t),
            Err(e) => {
                shed += 1;
                if ev.priority == Priority::Batch {
                    batch_shed += 1;
                }
                rec.record_failure(&e);
            }
        }
    });
    anyhow::ensure!(
        cluster.await_completed(tickets.len(), Duration::from_secs(600)),
        "{name}: serving timed out"
    );
    let makespan = t0.elapsed().as_secs_f64();
    for t in &tickets {
        match t.status().map(|s| s.state) {
            Some(RequestState::Done(resp)) => rec.record(&resp),
            Some(RequestState::Failed(e)) => rec.record_failure(&e),
            _ => rec.record_failure(&EditError::Internal("ticket not terminal".into())),
        }
    }
    cluster.shutdown()?;
    let report = rec.report(makespan);
    println!("-- {name}: {}", report.line());
    for c in &report.by_class {
        println!(
            "   {:>11}: n={:<3} e2e p50={:.1}ms p99={:.1}ms",
            c.class,
            c.completed,
            c.e2e.p50 * 1e3,
            c.e2e.p99 * 1e3,
        );
    }
    Ok(ModeOutcome {
        report,
        admitted: tickets.len(),
        shed,
        batch_admitted: batch_total - batch_shed,
    })
}

/// Bounded-queue demonstration: with `max_pending` tiny, a burst sheds
/// deterministically with `Overloaded` + a positive retry estimate.
fn overload_guard(model: &str, lat: &LatencyModel) -> anyhow::Result<Json> {
    let mut engine = EngineConfig::for_system(SystemKind::InstGenIE);
    engine.prepost_cpu_us = 200;
    engine.qos = QosConfig { max_pending: 2, ..QosConfig::standard() };
    let manifest = Manifest::load("artifacts")?;
    let mcfg = manifest.model(model)?.config.clone();
    let sched = scheduler::by_name("qos-aware", &mcfg, lat, engine.cache_mode, engine.max_batch)
        .expect("scheduler");
    let cluster = Cluster::launch(
        ClusterOpts {
            workers: 1,
            engine,
            model: model.to_string(),
            artifact_dir: "artifacts".into(),
            templates: vec!["tpl-0".into()],
            lat_model: lat.clone(),
            warmup: false,
        },
        sched,
    )?;
    let gen = TraceGen::new(1e6, MaskDist::Production, 1, 7); // burst: no gaps
    let events = gen.generate(10);
    let mut admitted = 0usize;
    let mut sheds = 0usize;
    let mut min_retry_ms = u64::MAX;
    let mut tickets = Vec::new();
    for ev in &events {
        match cluster.submit_guarded(cluster.event_request(ev)) {
            Ok(t) => {
                admitted += 1;
                tickets.push(t);
            }
            Err(EditError::Overloaded { retry_after_ms }) => {
                sheds += 1;
                min_retry_ms = min_retry_ms.min(retry_after_ms);
            }
            Err(e) => anyhow::bail!("unexpected admission error: {e}"),
        }
    }
    cluster.await_completed(admitted, Duration::from_secs(600));
    cluster.shutdown()?;
    println!(
        "-- overload guard: {admitted}/{} admitted, {sheds} shed with 429 (min Retry-After {} ms)",
        events.len(),
        if sheds > 0 { min_retry_ms } else { 0 },
    );
    anyhow::ensure!(sheds > 0, "a 10-deep burst over max_pending=2 must shed");
    Ok(Json::obj(vec![
        ("submitted", Json::num(events.len() as f64)),
        ("admitted", Json::num(admitted as f64)),
        ("shed", Json::num(sheds as f64)),
        ("min_retry_after_ms", Json::num(min_retry_ms as f64)),
    ]))
}

fn mode_json(m: &ModeOutcome) -> Json {
    let classes = m
        .report
        .by_class
        .iter()
        .map(|c| {
            (
                c.class,
                Json::obj(vec![
                    ("completed", Json::num(c.completed as f64)),
                    ("p50_e2e", Json::num(c.e2e.p50)),
                    ("p99_e2e", Json::num(c.e2e.p99)),
                    ("mean_e2e", Json::num(c.e2e.mean)),
                ]),
            )
        })
        .collect();
    let kinds = m
        .report
        .failed_by_kind
        .iter()
        .map(|(k, n)| (k.as_str(), Json::num(*n as f64)))
        .collect();
    Json::obj(vec![
        ("throughput", Json::num(m.report.throughput)),
        ("completed", Json::num(m.report.completed as f64)),
        ("admitted", Json::num(m.admitted as f64)),
        ("shed", Json::num(m.shed as f64)),
        ("failed", Json::num(m.report.failed as f64)),
        ("failed_by_kind", Json::obj(kinds)),
        ("makespan", Json::num(m.report.makespan)),
        ("classes", Json::obj(classes)),
    ])
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    // default arrival rate is far above a 2-worker cluster's service
    // rate, so queues reliably build and the class policies separate
    let requests: usize = args.first().and_then(|a| a.parse().ok()).unwrap_or(60);
    let rps: f64 = args.get(1).and_then(|a| a.parse().ok()).unwrap_or(120.0);
    let workers: usize = args.get(2).and_then(|a| a.parse().ok()).unwrap_or(2);

    let Ok(manifest) = Manifest::load("artifacts") else {
        eprintln!("[qos_bench] no artifacts; skipping (run `make artifacts`)");
        return Ok(());
    };
    let model = if manifest.models.contains_key("sd21m") {
        "sd21m".to_string()
    } else {
        match manifest.models.keys().next() {
            Some(m) => m.clone(),
            None => {
                eprintln!("[qos_bench] empty manifest; skipping");
                return Ok(());
            }
        }
    };
    let lat = LatencyModel::load_or_nominal("artifacts", &model);

    println!(
        "== qos bench smoke: model={model} workers={workers} rps={rps} requests={requests} \
         mix={CLASS_MIX} =="
    );
    let fifo = run_mode("fifo", false, &model, &lat, requests, rps, workers)?;
    let qos = run_mode("qos", true, &model, &lat, requests, rps, workers)?;

    let irank = Priority::Interactive.rank();
    let fifo_p99 = fifo.report.by_class[irank].e2e.p99;
    let qos_p99 = qos.report.by_class[irank].e2e.p99;
    let p99_ratio = if qos_p99 > 0.0 { fifo_p99 / qos_p99 } else { f64::INFINITY };
    let goodput_ratio = if fifo.report.throughput > 0.0 {
        qos.report.throughput / fifo.report.throughput
    } else {
        f64::INFINITY
    };
    let batch_done = qos.report.by_class[Priority::Batch.rank()].completed;
    let starved = qos.batch_admitted.saturating_sub(batch_done);
    println!(
        "== interactive p99: fifo={:.1}ms qos={:.1}ms ({p99_ratio:.2}x) | goodput ratio \
         qos/fifo={goodput_ratio:.3} | starved batch requests={starved} ==",
        fifo_p99 * 1e3,
        qos_p99 * 1e3,
    );

    let guard = overload_guard(&model, &lat)?;

    let out = Json::obj(vec![
        ("model", Json::str(model)),
        ("workers", Json::num(workers as f64)),
        ("requests", Json::num(requests as f64)),
        ("rps", Json::num(rps)),
        ("class_mix", Json::str(CLASS_MIX)),
        (
            "modes",
            Json::obj(vec![("fifo", mode_json(&fifo)), ("qos", mode_json(&qos))]),
        ),
        ("interactive_p99_ratio", Json::num(p99_ratio)),
        ("goodput_ratio", Json::num(goodput_ratio)),
        ("qos_batch_starved", Json::num(starved as f64)),
        ("overload_guard", guard),
    ]);
    std::fs::write("BENCH_qos.json", out.to_string())?;
    println!("[qos_bench] wrote BENCH_qos.json");
    Ok(())
}
