//! Coordinator-overhead bench: per-step transfer counts and per-step
//! coordinator overhead (measured step latency minus the pipeline's
//! ideal latency) for the device-resident step loop vs the
//! host-round-trip reference, plus the device KV tier's warm/cold
//! upload split (hit rate, per-step KV bytes, and a regression guard:
//! a warm template must perform zero steady-state KV uploads). Writes
//! `BENCH_overhead.json` so every PR leaves a comparable record of the
//! hot-path trajectory (§6.6 budgets ~1 ms/step for everything around
//! the kernels).
//!
//! The measurement itself lives in
//! `instgenie::util::bench::measure_step_overhead` (shared with the
//! §6.6 microbench rows).
//!
//! Run: `cargo run --release --example overhead_bench -- [requests] [mask_ratio]`

use instgenie::runtime::Manifest;
use instgenie::util::bench::{measure_kv_tier_overhead, measure_step_overhead, StepOverhead};
use instgenie::util::json::Json;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let requests: usize = args.first().and_then(|a| a.parse().ok()).unwrap_or(8);
    let ratio: f64 = args.get(1).and_then(|a| a.parse().ok()).unwrap_or(0.3);

    let Ok(manifest) = Manifest::load("artifacts") else {
        eprintln!("[overhead_bench] no artifacts; skipping (run `make artifacts`)");
        return Ok(());
    };
    let model = if manifest.models.contains_key("sd21m") {
        "sd21m".to_string()
    } else {
        match manifest.models.keys().next() {
            Some(m) => m.clone(),
            None => {
                eprintln!("[overhead_bench] empty manifest; skipping");
                return Ok(());
            }
        }
    };
    let blocks = manifest.model(&model)?.config.blocks;

    // host first: it is the pre-PR baseline the JSON records as "before"
    let Some(host) = measure_step_overhead(&model, false, requests, ratio)? else {
        eprintln!("[overhead_bench] artifacts vanished; skipping");
        return Ok(());
    };
    let device = measure_step_overhead(&model, true, requests, ratio)?
        .expect("artifacts vanished mid-run");

    println!(
        "== coordinator overhead: model={model} requests={requests} ratio={ratio} \
         bucket n={} ideal={:.3}ms planned={:.3}ms ==",
        host.bucket_n,
        host.ideal * 1e3,
        host.planned * 1e3
    );
    for (name, s) in [("host", &host), ("device", &device)] {
        println!(
            "{name:>7}: step={:.3}ms overhead={:.3}ms transfers/step={:.1} \
             h2d={:.1}KiB/step d2h={:.1}KiB/step",
            s.step_latency * 1e3,
            s.overhead * 1e3,
            s.transfers_per_step,
            s.h2d_bytes_per_step / 1024.0,
            s.d2h_bytes_per_step / 1024.0,
        );
    }
    println!(
        "[overhead_bench] transfers/step {:.1} -> {:.1} ({blocks} blocks), \
         overhead {:.3}ms -> {:.3}ms",
        host.transfers_per_step,
        device.transfers_per_step,
        host.overhead * 1e3,
        device.overhead * 1e3,
    );

    // Device KV tier warm/cold split: request 1 populates the tier,
    // requests 2.. replay the identical mask warm. Regression guard:
    // once the tier engaged at all (misses on the cold pass), the warm
    // steady state must perform zero KV uploads — a panic here fails ci.
    let kv = measure_kv_tier_overhead(&model, requests.max(3), ratio)?
        .expect("artifacts vanished mid-run");
    println!(
        "kv tier: cold={:.1}KiB/step warm={:.1}KiB/step hits={} misses={} \
         hit_rate={:.2}",
        kv.cold_kv_bytes_per_step / 1024.0,
        kv.warm_kv_bytes_per_step / 1024.0,
        kv.dev_hits,
        kv.dev_misses,
        kv.hit_rate,
    );
    if kv.dev_misses > 0 {
        assert_eq!(
            kv.warm_kv_bytes_per_step, 0.0,
            "regression: warm template still uploads K/V \
             ({:.1} B/step over {} warm steps)",
            kv.warm_kv_bytes_per_step, kv.warm_steps
        );
        assert_eq!(
            kv.warm_misses, 0,
            "regression: warm template misses the device KV tier"
        );
    }

    let row = |s: &StepOverhead| {
        Json::obj(vec![
            ("step_latency", Json::num(s.step_latency)),
            ("coordinator_overhead", Json::num(s.overhead)),
            ("transfers_per_step", Json::num(s.transfers_per_step)),
            ("h2d_bytes_per_step", Json::num(s.h2d_bytes_per_step)),
            ("d2h_bytes_per_step", Json::num(s.d2h_bytes_per_step)),
            ("steps", Json::num(s.steps as f64)),
        ])
    };
    let out = Json::obj(vec![
        ("model", Json::str(model)),
        ("requests", Json::num(requests as f64)),
        ("mask_ratio", Json::num(ratio)),
        ("bucket_n", Json::num(host.bucket_n as f64)),
        ("blocks", Json::num(blocks as f64)),
        ("ideal_step_latency", Json::num(host.ideal)),
        ("planned_step_latency", Json::num(host.planned)),
        ("host", row(&host)),
        ("device", row(&device)),
        (
            "kv_tier",
            Json::obj(vec![
                ("cold_kv_bytes_per_step", Json::num(kv.cold_kv_bytes_per_step)),
                ("warm_kv_bytes_per_step", Json::num(kv.warm_kv_bytes_per_step)),
                ("dev_hits", Json::num(kv.dev_hits as f64)),
                ("dev_misses", Json::num(kv.dev_misses as f64)),
                ("hit_rate", Json::num(kv.hit_rate)),
            ]),
        ),
    ]);
    std::fs::write("BENCH_overhead.json", out.to_string())?;
    println!("[overhead_bench] wrote BENCH_overhead.json");
    Ok(())
}
