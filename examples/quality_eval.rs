//! Table 2 — image quality evaluation.
//!
//! For each model, the same edit requests are served by every system;
//! Diffusers (full recompute + trajectory-pinned unmasked rows) is the
//! ground truth, exactly as in the paper. Metrics (DESIGN.md
//! "Substitutions"):
//!   SSIM      windowed structural similarity vs the Diffusers output (^)
//!   FrechetD  Fréchet distance between decoder-feature sets (FID-style, v)
//!   Align     cosine(output feature, conditioning) — CLIP-score analogue (^)
//!
//! Run: `cargo run --release --example quality_eval`

use std::collections::BTreeMap;
use std::sync::mpsc::channel;
use std::sync::Arc;

use instgenie::cache::{LatencyModel, TieredStore};
use instgenie::config::{CacheMode, EngineConfig, SystemKind};
use instgenie::engine::{EditRequest, EditResponse, Worker, WorkerEvent};
use instgenie::model::MaskSpec;
use instgenie::quality::{alignment_score, frechet_distance, image_feature, ssim};
use instgenie::runtime::ModelRuntime;
use instgenie::util::bench::Table;
use instgenie::util::rng::Pcg;
use instgenie::util::tensor::Tensor;

const REQUESTS: usize = 12;

fn serve(
    model: &str,
    system: SystemKind,
    cache_mode: CacheMode,
) -> anyhow::Result<BTreeMap<u64, EditResponse>> {
    let rt = ModelRuntime::create("artifacts", model)?;
    let hw = rt.config.latent_hw;
    let tiers = Arc::new(TieredStore::new(1 << 30, "artifacts/cache_spill".into(), 0.0));
    let (tx, rx) = channel();
    let mut cfg = EngineConfig::for_system(system);
    cfg.cache_mode = cache_mode;
    cfg.max_batch = 1; // fixed compute context -> deterministic comparison
    cfg.prepost_cpu_us = 0;
    let worker = Worker::new(0, cfg, rt, tiers, LatencyModel::load_or_nominal("artifacts", model), tx);
    worker.ensure_registered("q-template")?;
    let submit = worker.submitter();
    let stop = worker.stop_flag();
    let handle = worker.start();
    let mut rng = Pcg::new(99);
    for i in 0..REQUESTS as u64 {
        let ratio = rng.range_f64(0.08, 0.3);
        let mut mask_rng = Pcg::with_stream(1000 + i, 0x6d61_736b);
        let mask = MaskSpec::synth(hw, ratio, &mut mask_rng);
        submit.submit(EditRequest::new(i, "q-template", mask, 2000 + i));
    }
    let mut out = BTreeMap::new();
    while out.len() < REQUESTS {
        if let WorkerEvent::Finished { result, .. } = rx.recv()? {
            let r: EditResponse = result?; // fail fast, don't hang the loop
            out.insert(r.id, r);
        }
    }
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    handle.join().unwrap()?;
    Ok(out)
}

fn conditioning(prompt_seed: u64, hidden: usize) -> Vec<f32> {
    let mut rng = Pcg::new(prompt_seed);
    let mut c = vec![0f32; hidden];
    rng.fill_normal_f32(&mut c, 0.5);
    c
}

fn main() -> anyhow::Result<()> {
    let mut table = Table::new(
        "Table 2: image quality vs Diffusers ground truth",
        &["model", "system", "SSIM(^)", "FrechetD(v)", "Align(^)"],
    );
    for model in ["sd21m", "sdxlm", "fluxm"] {
        let rt = ModelRuntime::create("artifacts", model)?;
        let hw = rt.config.latent_hw;
        let hidden = rt.config.hidden;
        let encoder = rt.weights().encoder.clone();
        drop(rt);

        let truth = serve(model, SystemKind::Diffusers, CacheMode::CacheY)?;
        let truth_feats: Vec<Vec<f32>> =
            truth.values().map(|r| image_feature(&r.image, &encoder)).collect();

        let systems: Vec<(&str, SystemKind, CacheMode)> = vec![
            ("diffusers", SystemKind::Diffusers, CacheMode::CacheY),
            ("instgenie", SystemKind::InstGenIE, CacheMode::CacheY),
            ("instgenie-kv", SystemKind::InstGenIE, CacheMode::CacheKV),
            ("fisedit", SystemKind::FisEdit, CacheMode::CacheY),
            ("teacache", SystemKind::TeaCache, CacheMode::CacheY),
        ];
        for (name, system, mode) in systems {
            let got = serve(model, system, mode)?;
            let mut ssims = Vec::new();
            let mut aligns = Vec::new();
            let feats: Vec<Vec<f32>> =
                got.values().map(|r| image_feature(&r.image, &encoder)).collect();
            for (id, r) in &got {
                let t = &truth[id];
                ssims.push(ssim(&r.image, &t.image, hw, 4));
                aligns.push(alignment_score(
                    &r.image,
                    &encoder,
                    &conditioning(2000 + id, hidden),
                ));
            }
            let fd = frechet_distance(&feats, &truth_feats);
            let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
            table.rowf(&[
                &model,
                &name,
                &format!("{:.4}", mean(&ssims)),
                &format!("{:.5}", fd),
                &format!("{:.4}", mean(&aligns)),
            ]);
        }
    }
    table.print();
    table.save_csv("table2_quality").ok();
    println!("\n(SSIM of 1.0 on the diffusers row is the self-check; paper Table 2");
    println!(" reports InstGenIE SSIM 0.88-0.99 vs Diffusers and better quality");
    println!(" than FISEdit/TeaCache at matched latency budgets.)");
    Ok(())
}
