//! End-to-end serving driver (the repo's required full-system proof):
//! launch a multi-worker InstGenIE cluster on a real (mini) model, serve
//! Poisson-arriving masked edit requests from the production mask-ratio
//! distribution through the mask-aware scheduler, and report
//! latency/throughput — all three layers composing (Pallas kernels ->
//! AOT HLO -> rust coordinator).
//!
//! Run: `cargo run --release --example serving_cluster -- [requests] [rps] [workers]`

use std::time::Duration;

use instgenie::cache::LatencyModel;
use instgenie::cluster::{Cluster, ClusterOpts};
use instgenie::config::{EngineConfig, SystemKind};
use instgenie::engine::request::EditRequestBuilder;
use instgenie::metrics::Recorder;
use instgenie::model::MaskSpec;
use instgenie::runtime::Manifest;
use instgenie::scheduler;
use instgenie::util::rng::Pcg;
use instgenie::workload::{replay, MaskDist, TraceGen};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let requests: usize = args.first().and_then(|a| a.parse().ok()).unwrap_or(48);
    let rps: f64 = args.get(1).and_then(|a| a.parse().ok()).unwrap_or(6.0);
    let workers: usize = args.get(2).and_then(|a| a.parse().ok()).unwrap_or(2);
    let model = "sdxlm";
    let templates = 4;

    println!("== InstGenIE end-to-end serving driver ==");
    println!("model={model} workers={workers} rps={rps} requests={requests}");

    let manifest = Manifest::load("artifacts")?;
    let mcfg = manifest.model(model)?.config.clone();
    let lat = LatencyModel::load_or_nominal("artifacts", model);
    let engine = EngineConfig::for_system(SystemKind::InstGenIE);
    let sched = scheduler::by_name("mask-aware", &mcfg, &lat, engine.cache_mode, engine.max_batch)
        .expect("scheduler");

    let t_launch = std::time::Instant::now();
    let cluster = Cluster::launch(
        ClusterOpts {
            workers,
            engine,
            model: model.into(),
            artifact_dir: "artifacts".into(),
            templates: (0..templates).map(|i| format!("tpl-{i}")).collect(),
            lat_model: lat,
            warmup: true,
        },
        sched,
    )?;
    println!(
        "cluster up in {:?} ({} templates registered, program grid warm)",
        t_launch.elapsed(),
        templates
    );

    let gen = TraceGen::new(rps, MaskDist::Production, templates, 42);
    let events = gen.generate(requests);
    println!(
        "replaying Poisson trace: mean mask ratio {:.3} (paper production trace: 0.11)",
        events.iter().map(|e| e.mask_ratio).sum::<f64>() / events.len() as f64
    );

    // submit returns one ticket per request; each resolves to its *own*
    // response (the handle-based lifecycle the HTTP frontend builds on)
    let t0 = std::time::Instant::now();
    let mut tickets = Vec::with_capacity(requests);
    replay(&events, |ev| {
        tickets.push(cluster.submit_event(ev));
    });
    for t in &tickets {
        let resp = t
            .wait(Duration::from_secs(600))
            .map_err(|e| anyhow::anyhow!("request {} failed: {e}", t.id()))?;
        anyhow::ensure!(resp.id == t.id(), "ticket resolved to a foreign response");
    }
    let makespan = t0.elapsed().as_secs_f64();

    // online template lifecycle: register a template while the cluster is
    // live (background trace), edit against it without a restart, then
    // retire it — freeing its bytes on every worker tier
    println!("\nregistering tpl-online while serving...");
    cluster.register_template_async("tpl-online");
    cluster
        .await_template("tpl-online", Duration::from_secs(600))
        .map_err(|e| anyhow::anyhow!("online registration: {e}"))?;
    let status = cluster
        .template_status("tpl-online")
        .expect("registered template");
    println!(
        "tpl-online ready: {} bytes, residency per worker: {:?}",
        status.info.bytes,
        status
            .residency
            .iter()
            .map(|r| r.label())
            .collect::<Vec<_>>()
    );
    let mut rng = Pcg::new(7);
    let req = EditRequestBuilder::new(1_000_000)
        .template("tpl-online")
        .prompt_seed(9)
        .mask(MaskSpec::synth(cluster.model.latent_hw, 0.15, &mut rng))
        .build()
        .map_err(|e| anyhow::anyhow!("build: {e}"))?;
    let ticket = cluster
        .submit_checked(req)
        .map_err(|e| anyhow::anyhow!("submit: {e}"))?;
    let resp = ticket
        .wait(Duration::from_secs(600))
        .map_err(|e| anyhow::anyhow!("online edit: {e}"))?;
    println!(
        "online edit served in {:.1}ms e2e; retiring tpl-online: {:?}",
        resp.timing.e2e * 1e3,
        cluster.retire_template("tpl-online"),
    );

    let responses = cluster.shutdown()?;
    let mut rec = Recorder::new();
    for r in &responses {
        assert!(r.image.data().iter().all(|v| v.is_finite()));
        rec.record(r);
    }
    let rep = rec.report(makespan);
    println!("\n== results ==");
    println!("completed      : {}", rep.completed);
    println!("throughput     : {:.2} req/s", rep.throughput);
    println!(
        "e2e latency    : mean {:.1}ms  p50 {:.1}ms  p95 {:.1}ms  p99 {:.1}ms",
        rep.e2e.mean * 1e3,
        rep.e2e.p50 * 1e3,
        rep.e2e.p95 * 1e3,
        rep.e2e.p99 * 1e3
    );
    println!(
        "queue / infer  : {:.1}ms / {:.1}ms (means)",
        rep.queue.mean * 1e3,
        rep.inference.mean * 1e3
    );
    println!("interruptions  : {:.2}/req (disaggregated pre/post => 0)", rep.mean_interruptions);
    println!("json: {}", rep.to_json());
    Ok(())
}
