//! Session serving bench smoke: replay multi-round interactive editing
//! sessions through the session plane on a `session-affinity` cluster
//! and write `BENCH_sessions.json` — rounds/sec, per-round p50/p99, the
//! warm-vs-cold round split, and the affinity hit rate (fraction of
//! follow-up rounds landing on the session owner's worker). A second
//! phase is the regression gate: a zero-drift session on a 1-worker
//! `CacheKV` cluster must perform **zero KV upload bytes** on its warm
//! steady-state rounds (the delta-mask reuse invariant) — the bench
//! fails otherwise. `ci.sh` runs this after the qos bench so every PR
//! leaves a comparable session-plane perf record.
//!
//! Run: `cargo run --release --example session_bench -- [sessions] [rounds] [workers]`

use std::time::Duration;

use instgenie::cache::LatencyModel;
use instgenie::cluster::{Cluster, ClusterOpts};
use instgenie::config::{CacheMode, EngineConfig, SystemKind};
use instgenie::qos::Priority;
use instgenie::runtime::Manifest;
use instgenie::scheduler;
use instgenie::util::json::Json;
use instgenie::util::stats::Summary;
use instgenie::workload::{MaskDist, SessionGen, TraceEvent};

const TEMPLATES: usize = 2;
const MASK_DRIFT: f64 = 0.25;

fn launch(
    model: &str,
    lat: &LatencyModel,
    workers: usize,
    templates: Vec<String>,
    sched_name: &str,
) -> anyhow::Result<Cluster> {
    let mut engine = EngineConfig::for_system(SystemKind::InstGenIE);
    engine.prepost_cpu_us = 200;
    engine.cache_mode = CacheMode::CacheKV;
    let manifest = Manifest::load("artifacts")?;
    let mcfg = manifest.model(model)?.config.clone();
    let sched = scheduler::by_name(sched_name, &mcfg, lat, engine.cache_mode, engine.max_batch)
        .expect("scheduler");
    Cluster::launch(
        ClusterOpts {
            workers,
            engine,
            model: model.to_string(),
            artifact_dir: "artifacts".into(),
            templates,
            lat_model: lat.clone(),
            warmup: true,
        },
        sched,
    )
}

fn summary_json(xs: &[f64]) -> Json {
    if xs.is_empty() {
        return Json::obj(vec![("count", Json::num(0.0))]);
    }
    let s = Summary::of(xs);
    Json::obj(vec![
        ("count", Json::num(xs.len() as f64)),
        ("mean", Json::num(s.mean)),
        ("p50", Json::num(s.p50)),
        ("p99", Json::num(s.p99)),
    ])
}

struct SessionOutcome {
    rounds_total: usize,
    completed: usize,
    makespan: f64,
    all: Vec<f64>,
    warm: Vec<f64>,
    cold: Vec<f64>,
    affinity_opportunities: usize,
    affinity_hits: usize,
}

/// Phase 1: drifting sessions over a `session-affinity` cluster.
fn run_sessions(
    model: &str,
    lat: &LatencyModel,
    sessions: usize,
    rounds: usize,
    workers: usize,
) -> anyhow::Result<SessionOutcome> {
    let gen = SessionGen::new(sessions, rounds, MASK_DRIFT, MaskDist::Production, TEMPLATES, 42);
    let scripts = gen.generate();
    let cluster = launch(model, lat, workers, gen.template_ids(), "session-affinity")?;

    let mut out = SessionOutcome {
        rounds_total: 0,
        completed: 0,
        makespan: 0.0,
        all: Vec::new(),
        warm: Vec::new(),
        cold: Vec::new(),
        affinity_opportunities: 0,
        affinity_hits: 0,
    };
    let mut next_id = 1u64;
    let t0 = std::time::Instant::now();
    for script in &scripts {
        let sid = cluster.open_session(&script.template).map_err(anyhow::Error::new)?;
        let mut prev_worker: Option<usize> = None;
        for round in &script.rounds {
            out.rounds_total += 1;
            let ev = TraceEvent {
                id: next_id,
                at: 0.0,
                template: script.template.clone(),
                mask_ratio: round.mask_ratio,
                prompt_seed: round.prompt_seed,
                priority: Priority::Interactive,
                deadline_ms: None,
            };
            next_id += 1;
            let (ticket, plan) = cluster
                .submit_session_round(sid, cluster.event_request(&ev))
                .map_err(anyhow::Error::new)?;
            if let Some(w) = prev_worker {
                out.affinity_opportunities += 1;
                if ticket.worker() == w {
                    out.affinity_hits += 1;
                }
            }
            prev_worker = Some(ticket.worker());
            let resp = ticket.wait(Duration::from_secs(600)).map_err(anyhow::Error::new)?;
            out.completed += 1;
            out.all.push(resp.timing.e2e);
            if plan.warm {
                out.warm.push(resp.timing.e2e);
            } else {
                out.cold.push(resp.timing.e2e);
            }
        }
        cluster.close_session(sid, Duration::from_secs(30)).map_err(anyhow::Error::new)?;
    }
    out.makespan = t0.elapsed().as_secs_f64();
    cluster.shutdown()?;

    // in-process workers never die or drain here, so sticky routing must
    // hold every follow-up round on its session owner
    anyhow::ensure!(
        out.affinity_hits == out.affinity_opportunities,
        "affinity miss: {}/{} follow-up rounds left the session owner",
        out.affinity_opportunities - out.affinity_hits,
        out.affinity_opportunities,
    );
    Ok(out)
}

/// Phase 2 — the regression gate: a zero-drift session re-submits the
/// identical mask every round, so every round after the first is warm
/// and must move **zero** KV bytes host->device.
fn steady_state_guard(model: &str, lat: &LatencyModel, rounds: usize) -> anyhow::Result<Json> {
    let cluster = launch(model, lat, 1, vec!["tpl-0".into()], "session-affinity")?;
    let sid = cluster.open_session("tpl-0").map_err(anyhow::Error::new)?;
    let run_round = |id: u64| -> anyhow::Result<bool> {
        let ev = TraceEvent {
            id,
            at: 0.0,
            template: "tpl-0".into(),
            mask_ratio: 0.3,
            prompt_seed: 7, // identical mask every round -> warm steady state
            priority: Priority::Interactive,
            deadline_ms: None,
        };
        let (ticket, plan) = cluster
            .submit_session_round(sid, cluster.event_request(&ev))
            .map_err(anyhow::Error::new)?;
        ticket.wait(Duration::from_secs(600)).map_err(anyhow::Error::new)?;
        // the transfer-counter publish lands just after the final step
        // resolves the ticket
        std::thread::sleep(Duration::from_millis(200));
        Ok(plan.warm)
    };

    let kv = |c: &Cluster| c.worker_snapshots()[0].transfers.kv_h2d_bytes;
    let rounds = rounds.max(2);
    let base = kv(&cluster);
    let first_warm = run_round(1)?;
    anyhow::ensure!(!first_warm, "round 1 has no prior mask and must be cold");
    let after_cold = kv(&cluster);
    for i in 2..=rounds as u64 {
        let warm = run_round(i)?;
        anyhow::ensure!(warm, "round {i} repeats round 1's mask and must be warm");
    }
    let after_warm = kv(&cluster);
    cluster.close_session(sid, Duration::from_secs(30)).map_err(anyhow::Error::new)?;
    cluster.shutdown()?;

    let warm_delta = after_warm - after_cold;
    println!(
        "-- steady-state guard: cold round uploaded {} KV bytes, {} warm rounds uploaded {}",
        after_cold - base,
        rounds - 1,
        warm_delta,
    );
    anyhow::ensure!(
        warm_delta == 0,
        "warm steady-state rounds must perform zero KV uploads, saw {warm_delta} bytes"
    );
    Ok(Json::obj(vec![
        ("rounds", Json::num(rounds as f64)),
        ("cold_kv_h2d_bytes", Json::num((after_cold - base) as f64)),
        ("warm_kv_h2d_bytes", Json::num(warm_delta as f64)),
    ]))
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let sessions: usize = args.first().and_then(|a| a.parse().ok()).unwrap_or(6);
    let rounds: usize = args.get(1).and_then(|a| a.parse().ok()).unwrap_or(4);
    let workers: usize = args.get(2).and_then(|a| a.parse().ok()).unwrap_or(2);

    let Ok(manifest) = Manifest::load("artifacts") else {
        eprintln!("[session_bench] no artifacts; skipping (run `make artifacts`)");
        return Ok(());
    };
    let model = if manifest.models.contains_key("sd21m") {
        "sd21m".to_string()
    } else {
        match manifest.models.keys().next() {
            Some(m) => m.clone(),
            None => {
                eprintln!("[session_bench] empty manifest; skipping");
                return Ok(());
            }
        }
    };
    let lat = LatencyModel::load_or_nominal("artifacts", &model);

    println!(
        "== session bench smoke: model={model} sessions={sessions} rounds={rounds} \
         workers={workers} drift={MASK_DRIFT} =="
    );
    let out = run_sessions(&model, &lat, sessions, rounds, workers)?;
    let rounds_per_sec = out.completed as f64 / out.makespan.max(1e-9);
    println!(
        "-- {} rounds ({} warm / {} cold) in {:.2}s = {rounds_per_sec:.2} rounds/s, \
         affinity {}/{}",
        out.completed,
        out.warm.len(),
        out.cold.len(),
        out.makespan,
        out.affinity_hits,
        out.affinity_opportunities,
    );
    let guard = steady_state_guard(&model, &lat, rounds)?;

    let hit_rate = if out.affinity_opportunities > 0 {
        out.affinity_hits as f64 / out.affinity_opportunities as f64
    } else {
        1.0
    };
    let json = Json::obj(vec![
        ("model", Json::str(model)),
        ("workers", Json::num(workers as f64)),
        ("sessions", Json::num(sessions as f64)),
        ("rounds_per_session", Json::num(rounds as f64)),
        ("mask_drift", Json::num(MASK_DRIFT)),
        ("rounds_total", Json::num(out.rounds_total as f64)),
        ("completed", Json::num(out.completed as f64)),
        ("makespan", Json::num(out.makespan)),
        ("rounds_per_sec", Json::num(rounds_per_sec)),
        ("e2e", summary_json(&out.all)),
        ("warm", summary_json(&out.warm)),
        ("cold", summary_json(&out.cold)),
        (
            "affinity",
            Json::obj(vec![
                ("opportunities", Json::num(out.affinity_opportunities as f64)),
                ("hits", Json::num(out.affinity_hits as f64)),
                ("hit_rate", Json::num(hit_rate)),
            ]),
        ),
        ("steady_state_guard", guard),
    ]);
    std::fs::write("BENCH_sessions.json", json.to_string())?;
    println!("[session_bench] wrote BENCH_sessions.json");
    Ok(())
}
