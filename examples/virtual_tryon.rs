//! Virtual try-on scenario (paper Fig. 1 / §2.1): one hot "model photo"
//! template reused by many requests with garment-shaped masks (VITON-HD
//! ratio distribution, mean 0.35), demonstrating template reuse, the
//! tiered cache (host-budget eviction to disk + paced promotion), and the
//! mask-aware speedup on a realistic editing task.
//!
//! Run: `cargo run --release --example virtual_tryon`

use std::sync::mpsc::channel;
use std::sync::Arc;

use instgenie::cache::{LatencyModel, TieredStore};
use instgenie::config::{EngineConfig, SystemKind};
use instgenie::engine::{EditRequest, Worker, WorkerEvent};
use instgenie::model::MaskSpec;
use instgenie::runtime::ModelRuntime;
use instgenie::util::rng::Pcg;
use instgenie::workload::MaskDist;

fn main() -> anyhow::Result<()> {
    let model = "sdxlm";
    let rt = ModelRuntime::create("artifacts", model)?;
    let hw = rt.config.latent_hw;

    // a small host budget so cold templates spill to disk (the paper's
    // hierarchical storage, §4.2), with a paced "SSD" link
    let one_template_bytes = rt.config.steps * rt.config.blocks * rt.config.tokens * rt.config.hidden * 4;
    let tiers = Arc::new(TieredStore::new(
        2 * one_template_bytes + one_template_bytes / 2, // fits 2 templates
        "artifacts/cache_spill".into(),
        512.0 * 1024.0 * 1024.0, // disk-tier pacing
    ));
    let (tx, rx) = channel();
    let mut cfg = EngineConfig::for_system(SystemKind::InstGenIE);
    cfg.prepost_cpu_us = 500;
    let worker = Worker::new(
        0,
        cfg,
        rt,
        Arc::clone(&tiers),
        LatencyModel::load_or_nominal("artifacts", model),
        tx,
    );

    // register three model photos; budget only keeps two in host memory
    for tpl in ["model-photo-a", "model-photo-b", "model-photo-c"] {
        let t0 = std::time::Instant::now();
        worker.ensure_registered(tpl)?;
        println!(
            "registered {tpl} ({:.1} MB activations) in {:?}",
            one_template_bytes as f64 / 1e6,
            t0.elapsed()
        );
    }
    let stats = tiers.stats();
    println!(
        "tiered cache after registration: host {:.1} MB, {} eviction(s) to disk",
        tiers.host_bytes() as f64 / 1e6,
        stats.evictions
    );

    // try on 12 garments against the hot template + 2 against the cold one
    let submit = worker.submitter();
    let stop = worker.stop_flag();
    let handle = worker.start();
    let mut rng = Pcg::new(3);
    let dist = MaskDist::VitonHD;
    let mut id = 0u64;
    for _ in 0..12 {
        let ratio = dist.sample(&mut rng);
        let mask = MaskSpec::synth(hw, ratio, &mut rng);
        submit.submit(EditRequest::new(id, "model-photo-b", mask, 500 + id));
        id += 1;
    }
    for _ in 0..2 {
        // model-photo-a was evicted: these promote it back from disk
        let ratio = dist.sample(&mut rng);
        let mask = MaskSpec::synth(hw, ratio, &mut rng);
        submit.submit(EditRequest::new(id, "model-photo-a", mask, 500 + id));
        id += 1;
    }

    let mut ratios = Vec::new();
    let mut lat = Vec::new();
    while (ratios.len() as u64) < id {
        if let WorkerEvent::Finished { result, .. } = rx.recv()? {
            let r = result?; // a failed request aborts instead of hanging
            ratios.push(r.mask_ratio);
            lat.push(r.timing.e2e);
        }
    }
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    handle.join().unwrap()?;

    let stats = tiers.stats();
    println!("\n== try-on session ==");
    println!("requests           : {id}");
    println!(
        "mean garment ratio : {:.2} (VITON-HD mean: 0.35)",
        ratios.iter().sum::<f64>() / ratios.len() as f64
    );
    println!(
        "mean e2e latency   : {:.1} ms",
        lat.iter().sum::<f64>() / lat.len() as f64 * 1e3
    );
    println!(
        "cache behaviour    : {} host hits, {} disk promotion(s), {} eviction(s)",
        stats.host_hits, stats.disk_promotions, stats.evictions
    );
    anyhow::ensure!(stats.disk_promotions >= 1, "expected a disk promotion");
    println!("virtual_tryon OK");
    Ok(())
}
