//! Distributed-plane bench smoke: replay one Zipf-popular Poisson trace
//! through (a) the in-process cluster baseline and (b) a router + N
//! worker nodes over the loopback RPC data plane, then write
//! `BENCH_dist.json` (throughput + p50/p99 for both planes, so the RPC
//! overhead is a recorded number, not a guess). Also generates a
//! million-template Zipf trace to show the popularity law scales without
//! perturbing arrivals.
//!
//! Run: `cargo run --release --example dist_bench -- [requests] [rps] [workers]`
//!
//! Flags:
//!   --procs <path-to-instgenie-binary>
//!       spawn the workers as real separate processes (`serve --role
//!       worker`) instead of in-process threads
//!   --zipf <s>
//!       Zipf exponent for template popularity (default 1.1)

use std::process::{Child, Command, Stdio};
use std::sync::Arc;
use std::time::{Duration, Instant};

use instgenie::cache::LatencyModel;
use instgenie::cluster::{Cluster, ClusterOpts};
use instgenie::config::{EngineConfig, SystemKind};
use instgenie::dist::{DistConfig, Router, WorkerNode};
use instgenie::metrics::{Recorder, Report};
use instgenie::runtime::Manifest;
use instgenie::scheduler;
use instgenie::util::json::Json;
use instgenie::workload::{replay, MaskDist, TraceGen};

const TEMPLATES: usize = 2;
const SCHED: &str = "round-robin";

fn engine() -> EngineConfig {
    let mut e = EngineConfig::for_system(SystemKind::InstGenIE);
    e.prepost_cpu_us = 200;
    e
}

fn report_row(rep: &Report) -> Json {
    Json::obj(vec![
        ("throughput", Json::num(rep.throughput)),
        ("p50_e2e", Json::num(rep.e2e.p50)),
        ("p95_e2e", Json::num(rep.e2e.p95)),
        ("p99_e2e", Json::num(rep.e2e.p99)),
        ("mean_e2e", Json::num(rep.e2e.mean)),
        ("mean_queue", Json::num(rep.queue.mean)),
        ("completed", Json::num(rep.completed as f64)),
        ("failed", Json::num(rep.failed as f64)),
        ("makespan", Json::num(rep.makespan)),
    ])
}

fn main() -> anyhow::Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let mut pos: Vec<String> = Vec::new();
    let mut procs: Option<String> = None;
    let mut zipf_s = 1.1f64;
    let mut i = 0;
    while i < raw.len() {
        match raw[i].as_str() {
            "--procs" => {
                procs = raw.get(i + 1).cloned();
                i += 2;
            }
            "--zipf" => {
                if let Some(v) = raw.get(i + 1).and_then(|v| v.parse().ok()) {
                    zipf_s = v;
                }
                i += 2;
            }
            _ => {
                pos.push(raw[i].clone());
                i += 1;
            }
        }
    }
    let requests: usize = pos.first().and_then(|a| a.parse().ok()).unwrap_or(24);
    let rps: f64 = pos.get(1).and_then(|a| a.parse().ok()).unwrap_or(8.0);
    let workers: usize = pos.get(2).and_then(|a| a.parse().ok()).unwrap_or(2).max(1);

    let Ok(manifest) = Manifest::load("artifacts") else {
        eprintln!("[dist_bench] no artifacts; skipping (run `make artifacts`)");
        return Ok(());
    };
    let model = if manifest.models.contains_key("sd21m") {
        "sd21m".to_string()
    } else {
        match manifest.models.keys().next() {
            Some(m) => m.clone(),
            None => {
                eprintln!("[dist_bench] empty manifest; skipping");
                return Ok(());
            }
        }
    };
    let mcfg = manifest.model(&model)?.config.clone();
    let lat = LatencyModel::load_or_nominal("artifacts", &model);
    let opts = |workers: usize| ClusterOpts {
        workers,
        engine: engine(),
        model: model.clone(),
        artifact_dir: "artifacts".into(),
        templates: (0..TEMPLATES).map(|i| format!("tpl-{i}")).collect(),
        lat_model: lat.clone(),
        warmup: true,
    };

    println!(
        "== dist bench smoke: model={model} workers={workers} rps={rps} requests={requests} zipf={zipf_s} =="
    );
    let events = TraceGen::new(rps, MaskDist::Production, TEMPLATES, 42)
        .with_zipf(zipf_s)
        .generate(requests);

    // Million-template scale: same seed and popularity law over 10^6
    // templates. One uniform draw per event maps through the closed-form
    // Zipf inverse CDF, so generation is O(requests) and the arrival
    // times / masks / prompt seeds are invariant in the template count.
    let huge = TraceGen::new(rps, MaskDist::Production, 1_000_000, 42)
        .with_zipf(zipf_s)
        .generate(requests);
    for (a, b) in events.iter().zip(&huge) {
        anyhow::ensure!(
            a.at == b.at && a.mask_ratio == b.mask_ratio && a.prompt_seed == b.prompt_seed,
            "template count must not perturb arrivals or masks"
        );
    }
    let head = huge
        .iter()
        .filter(|e| {
            e.template
                .strip_prefix("tpl-")
                .and_then(|s| s.parse::<usize>().ok())
                .is_some_and(|k| k < 1_000)
        })
        .count() as f64
        / huge.len().max(1) as f64;
    println!(
        "million-template zipf({zipf_s}): top-1000 templates receive {:.0}% of traffic",
        head * 100.0
    );

    // -- Phase A: in-process cluster baseline ---------------------------
    let e = engine();
    let sched = scheduler::by_name(SCHED, &mcfg, &lat, e.cache_mode, e.max_batch).expect("sched");
    let baseline = Cluster::launch(opts(workers), sched)?;
    let t0 = Instant::now();
    replay(&events, |ev| {
        baseline.submit_event(ev);
    });
    anyhow::ensure!(
        baseline.await_completed(events.len(), Duration::from_secs(600)),
        "baseline serving timed out"
    );
    let makespan = t0.elapsed().as_secs_f64();
    let responses = baseline.shutdown()?;
    let mut rec = Recorder::new();
    for r in &responses {
        rec.record(r);
    }
    let base_rep = rec.report(makespan);
    println!(
        "   in-process: tput={:.2} req/s  e2e p50={:.1}ms p99={:.1}ms",
        base_rep.throughput,
        base_rep.e2e.p50 * 1e3,
        base_rep.e2e.p99 * 1e3,
    );

    // -- Phase B: router + N workers over the RPC plane -----------------
    let cfg = DistConfig::fast();
    let e = engine();
    let sched = scheduler::by_name(SCHED, &mcfg, &lat, e.cache_mode, e.max_batch).expect("sched");
    let router = Router::new(mcfg.clone(), sched, None, cfg.clone());
    let addr = router.start("127.0.0.1:0")?;

    let mut nodes: Vec<Arc<WorkerNode>> = Vec::new();
    let mut children: Vec<Child> = Vec::new();
    let mode = if let Some(bin) = &procs {
        for i in 0..workers {
            let child = Command::new(bin)
                .args([
                    "serve",
                    "--role",
                    "worker",
                    "--router",
                    &addr.to_string(),
                    "--rpc-addr",
                    "127.0.0.1:0",
                    "--name",
                    &format!("proc-{i}"),
                    "--model",
                    &model,
                    "--artifacts",
                    "artifacts",
                    "--templates",
                    &TEMPLATES.to_string(),
                    "--prepost-us",
                    "200",
                    "--warmup",
                    "--heartbeat-ms",
                    &cfg.heartbeat_ms.to_string(),
                ])
                .stdout(Stdio::null())
                .stderr(Stdio::null())
                .spawn()?;
            children.push(child);
        }
        "processes"
    } else {
        for i in 0..workers {
            let node = Arc::new(WorkerNode::launch(format!("w{i}"), opts(1))?);
            node.start("127.0.0.1:0")?;
            node.announce_to(&addr.to_string(), &cfg);
            nodes.push(node);
        }
        "threads"
    };

    let deadline = Instant::now() + Duration::from_secs(120);
    while router.ready_count() < workers {
        anyhow::ensure!(
            Instant::now() < deadline,
            "{mode}: workers never became ready at the router"
        );
        std::thread::sleep(Duration::from_millis(50));
    }

    let t0 = Instant::now();
    let mut tickets = Vec::new();
    let mut rec = Recorder::new();
    replay(&events, |ev| match router.submit_event(ev) {
        Ok(t) => tickets.push(t),
        Err(e) => rec.record_failure(&e),
    });
    for t in &tickets {
        match t.wait(Duration::from_secs(600)) {
            Ok(resp) => rec.record(&resp),
            Err(e) => rec.record_failure(&e),
        }
    }
    let makespan = t0.elapsed().as_secs_f64();
    let dist_rep = rec.report(makespan);
    println!(
        "   dist ({mode}): tput={:.2} req/s  e2e p50={:.1}ms p99={:.1}ms",
        dist_rep.throughput,
        dist_rep.e2e.p50 * 1e3,
        dist_rep.e2e.p99 * 1e3,
    );

    router.shutdown();
    for n in &nodes {
        n.stop();
    }
    for mut c in children {
        let _ = c.kill();
        let _ = c.wait();
    }
    anyhow::ensure!(
        dist_rep.completed == events.len(),
        "dist plane completed {}/{} requests",
        dist_rep.completed,
        events.len()
    );

    let out = Json::obj(vec![
        ("model", Json::str(model)),
        ("workers", Json::num(workers as f64)),
        ("requests", Json::num(requests as f64)),
        ("rps", Json::num(rps)),
        ("templates", Json::num(TEMPLATES as f64)),
        ("zipf_s", Json::num(zipf_s)),
        ("mode", Json::str(mode)),
        ("million_template_head_share", Json::num(head)),
        ("baseline", report_row(&base_rep)),
        ("dist", report_row(&dist_rep)),
    ]);
    std::fs::write("BENCH_dist.json", out.to_string())?;
    println!("[dist_bench] wrote BENCH_dist.json");
    Ok(())
}
