#!/usr/bin/env bash
# CI gate: build, test, format, lint.
#
# Usage: ./ci.sh [--no-clippy]
# Runs from the directory containing Cargo.toml (repo root or rust/),
# so it works both in the assembled workspace and a bare checkout.

set -euo pipefail

here="$(cd "$(dirname "$0")" && pwd)"
if [[ -f "$here/Cargo.toml" ]]; then
  cd "$here"
elif [[ -f "$here/rust/Cargo.toml" ]]; then
  cd "$here/rust"
else
  echo "ci.sh: no Cargo.toml found under $here or $here/rust" >&2
  exit 1
fi

run() {
  echo "== $* =="
  "$@"
}

run cargo build --release
run cargo test -q
# Chaos suite: seeded fault scenarios (disk corruption, loader drops, KV
# upload failures, RPC drop/delay/truncation, step-boundary crashes).
# Seeds are compiled into the tests, so every run sweeps the exact same
# fault schedule. Asserts no hung tickets, no lost or duplicated
# requests, and bit-identical latents vs the fault-free run.
run cargo test -q --test chaos
run cargo fmt --check
if [[ "${1:-}" != "--no-clippy" ]]; then
  run cargo clippy --all-targets -- -D warnings
fi

# Cluster bench smoke: throughput + p50/p99 per scheduler, written to
# BENCH_cluster.json to seed the perf trajectory. Needs the compiled
# model artifacts; skipped on bare checkouts (the bench also self-skips).
if [[ -d artifacts ]]; then
  run cargo run --release --example cluster_bench -- 24 8 2
else
  echo "ci.sh: artifacts/ absent; skipping cluster bench smoke"
fi

# QoS bench smoke: overloaded mixed-class trace, FIFO vs QoS — per-class
# p50/p99 + shed counts, written to BENCH_qos.json.
if [[ -d artifacts ]]; then
  run cargo run --release --example qos_bench -- 60 120 2
else
  echo "ci.sh: artifacts/ absent; skipping qos bench smoke"
fi

# Distributed-plane smoke: router + 2 workers over the loopback RPC data
# plane vs the in-process baseline on the same Zipf trace, written to
# BENCH_dist.json. Uses real separate worker processes when the serving
# binary is built; falls back to in-thread worker nodes otherwise.
if [[ -d artifacts ]]; then
  if [[ -x target/release/instgenie ]]; then
    run cargo run --release --example dist_bench -- 24 8 2 --procs target/release/instgenie
  else
    run cargo run --release --example dist_bench -- 24 8 2
  fi
else
  echo "ci.sh: artifacts/ absent; skipping dist bench smoke"
fi

# Session-plane smoke: multi-round interactive sessions over the
# session-affinity scheduler — rounds/sec, warm-vs-cold round split,
# affinity hit rate, written to BENCH_sessions.json. The bench fails —
# failing this gate — if a warm steady-state round (identical mask as
# the previous round) performs any KV upload bytes, or if a follow-up
# round leaves its session owner while all workers are healthy.
if [[ -d artifacts ]]; then
  run cargo run --release --example session_bench -- 6 4 2
else
  echo "ci.sh: artifacts/ absent; skipping session bench smoke"
fi

# Coordinator-overhead smoke: per-step transfer counts + per-step
# overhead (measured minus pipeline-ideal), host reference vs the
# device-resident step loop, plus the device KV tier's warm/cold upload
# split (hit rate, per-step KV bytes). The bench panics — failing this
# gate — if a warm template still uploads K/V in steady state, written
# to BENCH_overhead.json.
if [[ -d artifacts ]]; then
  run cargo run --release --example overhead_bench -- 8 0.3
else
  echo "ci.sh: artifacts/ absent; skipping overhead bench smoke"
fi

# Fault-injection smoke: the same trace replayed through the dist plane
# at 0%/1%/5% injected fault rates with a fixed seed — throughput +
# p50/p99 per rate, degraded-block counts, breaker trips, retry-budget
# spend, written to BENCH_faults.json. Hard gate: zero failed requests
# at every swept rate (faults may cost latency, never a request).
if [[ -d artifacts ]]; then
  run cargo run --release --example fault_bench -- 16 8 2
else
  echo "ci.sh: artifacts/ absent; skipping fault bench smoke"
fi

# Durability smoke: journal append throughput + cold replay per fsync
# policy (always runs), and — with artifacts — the same trace journaled
# vs volatile plus cold router recovery time, written to
# BENCH_recovery.json. Hard gate: journaled throughput >= 95% of the
# volatile baseline at the default batched policy.
run cargo run --release --example recovery_bench -- 24 200 2

echo "ci.sh: all checks passed"
