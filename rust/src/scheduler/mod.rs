//! Cluster request scheduling — paper §4.4, Algorithm 2, plus the
//! baselines it is evaluated against (§6.5).
//!
//! The scheduler tracks, per worker, the *outstanding* requests (queued +
//! running) it has dispatched; completions retire them. Every pick also
//! sees a [`RouteCtx`]: the request's template residency on each
//! candidate worker plus its cache footprint. The mask-aware policy
//! estimates each candidate's completion latency by pushing the
//! hypothetical batch through the same regression models + pipeline DP
//! the workers use, **plus a cache-load penalty** when the candidate does
//! not hold the template host-resident — completing the "computation +
//! cache loading" cost model of Algorithm 2. The `cache-aware` policy is
//! the residency-first baseline: route to a host-resident worker,
//! tie-break on queue depth.

use crate::cache::pipeline;
use crate::cache::tier::Residency;
use crate::cache::LatencyModel;
use crate::config::{CacheMode, ModelConfig};
use crate::qos::Priority;

/// One dispatched-but-unfinished request, as the scheduler sees it.
#[derive(Debug, Clone)]
pub struct Outstanding {
    pub id: u64,
    pub masked_tokens: usize,
    pub remaining_steps: usize,
    /// Request class (class-aware policies route on it).
    pub priority: Priority,
}

/// Per-worker outstanding sets (indexed by worker id).
pub type Book = [Vec<Outstanding>];

/// Per-request routing context: where the template lives on each
/// candidate worker, and how many bytes a cache load would move.
#[derive(Debug, Clone, Default)]
pub struct RouteCtx {
    /// `residency[w]` = worker w's residency for this request's template.
    /// May be shorter than the book (treated as host-resident: no
    /// penalty), so residency-blind callers can pass
    /// [`RouteCtx::default`].
    pub residency: Vec<Residency>,
    /// The template's registered cache footprint in bytes (the numerator
    /// of the cache-load penalty; 0 when unknown).
    pub template_bytes: usize,
    /// `available[w]` = worker w may take new work. Empty means every
    /// worker is available (the in-process cluster's case). The dist
    /// router marks draining / suspect / dead members — and members whose
    /// snapshots have gone stale — unavailable, so a dead remote worker
    /// reads as *infinite cost* to every policy instead of as its
    /// last-published load.
    pub available: Vec<bool>,
    /// The worker that owns the request's session, when the request
    /// belongs to one: its host tier and device KV tier are warm for the
    /// session's template, so the session-affinity policy pins rounds
    /// there. `None` for sessionless requests (every policy ignores it
    /// except [`SessionAffinity`]).
    pub session_owner: Option<usize>,
}

impl RouteCtx {
    pub fn residency_for(&self, worker: usize) -> Residency {
        self.residency.get(worker).copied().unwrap_or(Residency::Host)
    }

    /// Whether worker `w` may be routed to (missing entries = available).
    pub fn is_available(&self, worker: usize) -> bool {
        self.available.get(worker).copied().unwrap_or(true)
    }
}

/// A routing policy.
pub trait Scheduler: Send {
    fn name(&self) -> &'static str;

    /// Choose a worker for `req` given the current book + cache context.
    fn pick(&mut self, req: &Outstanding, book: &Book, ctx: &RouteCtx) -> usize;
}

/// Round-robin (the weakest baseline; also used by Diffusers deployments).
pub struct RoundRobin {
    next: usize,
}

impl RoundRobin {
    pub fn new() -> RoundRobin {
        RoundRobin { next: 0 }
    }
}

impl Default for RoundRobin {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler for RoundRobin {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn pick(&mut self, _req: &Outstanding, book: &Book, ctx: &RouteCtx) -> usize {
        let n = book.len();
        for _ in 0..n {
            let w = self.next % n;
            self.next = self.next.wrapping_add(1);
            if ctx.is_available(w) {
                return w;
            }
        }
        // every worker unavailable: degenerate pick (callers gate on
        // having at least one ready member before routing)
        self.next % n
    }
}

/// Request-granularity load balance: fewest outstanding requests (§6.5
/// baseline; what LLM routers call least-requests).
pub struct LeastRequests;

impl Scheduler for LeastRequests {
    fn name(&self) -> &'static str {
        "request-lb"
    }

    fn pick(&mut self, _req: &Outstanding, book: &Book, ctx: &RouteCtx) -> usize {
        (0..book.len())
            .filter(|&w| ctx.is_available(w))
            .min_by_key(|&w| book[w].len())
            .unwrap_or(0)
    }
}

/// Token-granularity load balance: fewest outstanding masked tokens
/// (§6.5 baseline; least-tokens in LLM serving).
pub struct LeastTokens;

impl Scheduler for LeastTokens {
    fn name(&self) -> &'static str {
        "token-lb"
    }

    fn pick(&mut self, _req: &Outstanding, book: &Book, ctx: &RouteCtx) -> usize {
        (0..book.len())
            .filter(|&w| ctx.is_available(w))
            .min_by_key(|&w| {
                book[w]
                    .iter()
                    .map(|o| o.masked_tokens * o.remaining_steps)
                    .sum::<usize>()
            })
            .unwrap_or(0)
    }
}

/// Cache-residency-first routing: prefer workers that hold the template
/// hot in their host tier (then spilled-to-disk over absent), breaking
/// ties by fewest outstanding requests. The pure cache-affinity half of
/// Algorithm 2 — cheap, model-free, and already enough to beat
/// residency-blind balancing when per-worker tiers diverge.
pub struct CacheAware;

impl Scheduler for CacheAware {
    fn name(&self) -> &'static str {
        "cache-aware"
    }

    fn pick(&mut self, _req: &Outstanding, book: &Book, ctx: &RouteCtx) -> usize {
        (0..book.len())
            .filter(|&w| ctx.is_available(w))
            .min_by_key(|&w| (ctx.residency_for(w), book[w].len()))
            .unwrap_or(0)
    }
}

/// Mask-aware scheduling (Algorithm 2): cost = estimated completion
/// latency of the worker's backlog with the new request included (the
/// calibrated regression models + pipeline DP), plus the cache-loading
/// cost of bringing the template to the candidate worker when it is not
/// host-resident there.
pub struct MaskAware {
    cfg: ModelConfig,
    lat: LatencyModel,
    mode: CacheMode,
    max_batch: usize,
}

impl MaskAware {
    pub fn new(cfg: ModelConfig, lat: LatencyModel, mode: CacheMode, max_batch: usize) -> MaskAware {
        MaskAware { cfg, lat, mode, max_batch }
    }

    /// Algo 2's CalcCost: simulate the backlog in admission order as
    /// batches of up to `max_batch`, scoring each batch's steps with the
    /// DP step latency (Algo 1 on estimated costs).
    pub fn calc_cost(&self, backlog: &[Outstanding]) -> f64 {
        if backlog.is_empty() {
            return 0.0;
        }
        let mut cost = 0.0;
        for chunk in backlog.chunks(self.max_batch) {
            let n = chunk
                .iter()
                .map(|o| self.cfg.bucket_for(o.masked_tokens))
                .max()
                .unwrap_or(self.cfg.tokens);
            let steps = chunk.iter().map(|o| o.remaining_steps).max().unwrap_or(0);
            let step_latency = if n >= self.cfg.tokens {
                pipeline::full_latency(&self.lat.step_costs(
                    &self.cfg,
                    self.cfg.tokens,
                    chunk.len(),
                    self.mode,
                ))
            } else {
                pipeline::plan(&self.lat.step_costs(&self.cfg, n, chunk.len(), self.mode))
                    .latency
            };
            cost += step_latency * steps as f64;
        }
        cost
    }

    /// Best candidate for `req`: the worker minimizing backlog cost +
    /// cache-load penalty, with that cost. One shared implementation for
    /// routing ([`Scheduler::pick`]) and the QoS admission estimate, so
    /// the two can never diverge.
    pub fn best_completion(&self, req: &Outstanding, book: &Book, ctx: &RouteCtx) -> (usize, f64) {
        let mut best = 0;
        let mut best_cost = f64::INFINITY;
        for (w, outstanding) in book.iter().enumerate() {
            if !ctx.is_available(w) {
                continue; // dead/draining member: infinite cost
            }
            let mut hypo = outstanding.clone();
            hypo.push(req.clone());
            let cost = self.calc_cost(&hypo)
                + self.cache_load_cost(ctx.residency_for(w), ctx.template_bytes);
            if cost < best_cost {
                best_cost = cost;
                best = w;
            }
        }
        (best, best_cost)
    }

    /// Cache-loading term of Algorithm 2 for one candidate worker:
    /// nothing when host-resident, one tier promotion (load model over
    /// the template's bytes) when spilled, and a full registration trace
    /// (estimated as `steps` full-sequence step latencies) when absent.
    pub fn cache_load_cost(&self, residency: Residency, template_bytes: usize) -> f64 {
        match residency {
            Residency::Host => 0.0,
            Residency::Disk => self.lat.load_seconds(template_bytes as f64),
            Residency::Absent => {
                let full_step = pipeline::full_latency(&self.lat.step_costs(
                    &self.cfg,
                    self.cfg.tokens,
                    1,
                    self.mode,
                ));
                full_step * self.cfg.steps as f64
            }
        }
    }
}

impl Scheduler for MaskAware {
    fn name(&self) -> &'static str {
        "mask-aware"
    }

    fn pick(&mut self, req: &Outstanding, book: &Book, ctx: &RouteCtx) -> usize {
        self.best_completion(req, book, ctx).0
    }
}

/// Class-aware routing (QoS tentpole part 4): latency-sensitive classes
/// route like [`MaskAware`] — to the worker with the best estimated
/// completion time, cache penalty included — while `Batch` requests go to
/// the *cheapest* worker: first avoid cache loads (don't spend copy
/// bandwidth on bulk work), then the least marginal backlog cost. Bulk
/// traffic thus soaks up leftover capacity instead of competing with
/// interactive edits for the fastest replicas.
pub struct QosAware {
    inner: MaskAware,
}

impl QosAware {
    pub fn new(cfg: ModelConfig, lat: LatencyModel, mode: CacheMode, max_batch: usize) -> QosAware {
        QosAware { inner: MaskAware::new(cfg, lat, mode, max_batch) }
    }
}

impl Scheduler for QosAware {
    fn name(&self) -> &'static str {
        "qos-aware"
    }

    fn pick(&mut self, req: &Outstanding, book: &Book, ctx: &RouteCtx) -> usize {
        if req.priority != Priority::Batch {
            return self.inner.pick(req, book, ctx);
        }
        let mut best = 0;
        let mut best_key = (f64::INFINITY, f64::INFINITY);
        for (w, outstanding) in book.iter().enumerate() {
            if !ctx.is_available(w) {
                continue;
            }
            let penalty = self
                .inner
                .cache_load_cost(ctx.residency_for(w), ctx.template_bytes);
            let mut hypo = outstanding.clone();
            hypo.push(req.clone());
            let key = (penalty, self.inner.calc_cost(&hypo));
            if key < best_key {
                best_key = key;
                best = w;
            }
        }
        best
    }
}

/// Session-sticky routing (session tentpole): a round of an interactive
/// editing session goes to the worker that served the session's previous
/// rounds — its host tier holds the template hot and its device KV tier
/// still holds the masked-region K/V under the very keys the round will
/// look up, so a sticky pick turns every steady-state round into pure
/// device-tier hits (zero KV upload bytes). When the owner is draining,
/// suspect, or dead — or the request has no session — fall back to the
/// full mask-aware cost model, which re-homes the session on whatever
/// worker wins Algorithm 2.
pub struct SessionAffinity {
    fallback: MaskAware,
}

impl SessionAffinity {
    pub fn new(
        cfg: ModelConfig,
        lat: LatencyModel,
        mode: CacheMode,
        max_batch: usize,
    ) -> SessionAffinity {
        SessionAffinity { fallback: MaskAware::new(cfg, lat, mode, max_batch) }
    }
}

impl Scheduler for SessionAffinity {
    fn name(&self) -> &'static str {
        "session-affinity"
    }

    fn pick(&mut self, req: &Outstanding, book: &Book, ctx: &RouteCtx) -> usize {
        if let Some(owner) = ctx.session_owner {
            if owner < book.len() && ctx.is_available(owner) {
                return owner;
            }
        }
        self.fallback.pick(req, book, ctx)
    }
}

/// Construct a scheduler by name (CLI / bench plumbing).
pub fn by_name(
    name: &str,
    cfg: &ModelConfig,
    lat: &LatencyModel,
    mode: CacheMode,
    max_batch: usize,
) -> Option<Box<dyn Scheduler>> {
    match name {
        "round-robin" => Some(Box::new(RoundRobin::new())),
        "request-lb" => Some(Box::new(LeastRequests)),
        "token-lb" => Some(Box::new(LeastTokens)),
        "cache-aware" => Some(Box::new(CacheAware)),
        "mask-aware" => Some(Box::new(MaskAware::new(
            cfg.clone(),
            lat.clone(),
            mode,
            max_batch,
        ))),
        "qos-aware" => Some(Box::new(QosAware::new(
            cfg.clone(),
            lat.clone(),
            mode,
            max_batch,
        ))),
        "session-affinity" => Some(Box::new(SessionAffinity::new(
            cfg.clone(),
            lat.clone(),
            mode,
            max_batch,
        ))),
        _ => None,
    }
}

/// All routing policies, in bench/report order.
pub const POLICY_NAMES: [&str; 7] = [
    "round-robin",
    "request-lb",
    "token-lb",
    "cache-aware",
    "mask-aware",
    "qos-aware",
    "session-affinity",
];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::prop_check;
    use crate::util::rng::Pcg;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            latent_hw: 8,
            tokens: 64,
            hidden: 64,
            heads: 4,
            blocks: 4,
            steps: 8,
            token_buckets: vec![4, 8, 16, 32],
            paper_analogue: String::new(),
        }
    }

    fn o(id: u64, masked: usize) -> Outstanding {
        Outstanding {
            id,
            masked_tokens: masked,
            remaining_steps: 8,
            priority: Priority::Standard,
        }
    }

    fn o_class(id: u64, masked: usize, priority: Priority) -> Outstanding {
        Outstanding { priority, ..o(id, masked) }
    }

    fn uniform() -> RouteCtx {
        RouteCtx::default()
    }

    #[test]
    fn round_robin_cycles() {
        let mut s = RoundRobin::new();
        let book = vec![vec![], vec![], vec![]];
        let picks: Vec<usize> = (0..6).map(|i| s.pick(&o(i, 4), &book, &uniform())).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_requests_balances_counts() {
        let mut s = LeastRequests;
        let book = vec![vec![o(1, 4), o(2, 4)], vec![o(3, 4)], vec![]];
        assert_eq!(s.pick(&o(9, 4), &book, &uniform()), 2);
    }

    #[test]
    fn least_tokens_prefers_light_worker() {
        let mut s = LeastTokens;
        // worker 0 has 1 big request, worker 1 has 2 small ones
        let book = vec![vec![o(1, 32)], vec![o(2, 2), o(3, 2)]];
        assert_eq!(s.pick(&o(9, 4), &book, &uniform()), 1);
    }

    #[test]
    fn mask_aware_sees_through_request_counts() {
        // request-count LB would pick worker 1 (1 outstanding vs 2), but
        // its single huge-mask request costs more than two tiny ones —
        // the mask-aware policy must pick worker 0.
        let mut s = MaskAware::new(cfg(), LatencyModel::nominal(1e9, 1e8), CacheMode::CacheY, 8);
        let book = vec![vec![o(1, 2), o(2, 2)], vec![o(3, 64)]];
        assert_eq!(s.pick(&o(9, 2), &book, &uniform()), 0);
        let mut lr = LeastRequests;
        assert_eq!(lr.pick(&o(9, 2), &book, &uniform()), 1);
    }

    #[test]
    fn cache_aware_routes_to_hot_worker_where_request_lb_does_not() {
        // acceptance scenario: worker 0's host tier is cold for the
        // template, worker 1's is hot, load is otherwise equal — the
        // cache-aware policy must route to the hot worker while the
        // residency-blind request-lb baseline sticks with worker 0.
        let book = vec![vec![], vec![]];
        let ctx = RouteCtx {
            residency: vec![Residency::Absent, Residency::Host],
            template_bytes: 1 << 20,
            ..RouteCtx::default()
        };
        let mut ca = CacheAware;
        assert_eq!(ca.pick(&o(1, 4), &book, &ctx), 1);
        let mut lr = LeastRequests;
        assert_eq!(lr.pick(&o(1, 4), &book, &ctx), 0);
    }

    #[test]
    fn cache_aware_prefers_disk_over_absent_and_breaks_ties_by_load() {
        let mut ca = CacheAware;
        let ctx = RouteCtx {
            residency: vec![Residency::Absent, Residency::Disk],
            template_bytes: 1024,
            ..RouteCtx::default()
        };
        let book = vec![vec![], vec![]];
        assert_eq!(ca.pick(&o(1, 4), &book, &ctx), 1, "disk beats absent");
        // both hot: fall back to least-requests
        let ctx = RouteCtx {
            residency: vec![Residency::Host, Residency::Host],
            template_bytes: 1024,
            ..RouteCtx::default()
        };
        let book = vec![vec![o(1, 4)], vec![]];
        assert_eq!(ca.pick(&o(2, 4), &book, &ctx), 1);
    }

    #[test]
    fn mask_aware_charges_cache_load_penalty() {
        let mut s = MaskAware::new(cfg(), LatencyModel::nominal(1e9, 1e8), CacheMode::CacheY, 8);
        // equal backlogs; only residency differs -> prefer the hot tier
        let book = vec![vec![o(1, 4)], vec![o(2, 4)]];
        let ctx = RouteCtx {
            residency: vec![Residency::Disk, Residency::Host],
            template_bytes: 8 << 20,
            ..RouteCtx::default()
        };
        assert_eq!(s.pick(&o(9, 4), &book, &ctx), 1);
        // penalty ordering: host < disk < absent (registration trace)
        let host = s.cache_load_cost(Residency::Host, 8 << 20);
        let disk = s.cache_load_cost(Residency::Disk, 8 << 20);
        let absent = s.cache_load_cost(Residency::Absent, 8 << 20);
        assert_eq!(host, 0.0);
        assert!(disk > 0.0);
        assert!(absent > disk, "registration must cost more than promotion");
    }

    #[test]
    fn mask_aware_penalty_trades_off_against_backlog() {
        // a hot worker with a monstrous backlog still loses to a cold one
        let mut s = MaskAware::new(cfg(), LatencyModel::nominal(1e9, 1e8), CacheMode::CacheY, 8);
        let big: Vec<Outstanding> = (0..32).map(|i| o(i, 64)).collect();
        let book = vec![big, vec![]];
        let ctx = RouteCtx {
            residency: vec![Residency::Host, Residency::Disk],
            template_bytes: 1 << 10,
            ..RouteCtx::default()
        };
        assert_eq!(s.pick(&o(99, 4), &book, &ctx), 1);
    }

    #[test]
    fn mask_aware_cost_monotone_in_backlog() {
        prop_check("adding requests never lowers cost", 100, |rng: &mut Pcg| {
            let s = MaskAware::new(cfg(), LatencyModel::nominal(1e9, 1e8), CacheMode::CacheY, 8);
            let mut backlog: Vec<Outstanding> = (0..rng.below(10))
                .map(|i| o(i as u64, 1 + rng.below(64)))
                .collect();
            let before = s.calc_cost(&backlog);
            backlog.push(o(99, 1 + rng.below(64)));
            let after = s.calc_cost(&backlog);
            prop_assert!(after >= before - 1e-12, "cost dropped {before} -> {after}");
            Ok(())
        });
    }

    #[test]
    fn empty_backlog_costs_zero() {
        let s = MaskAware::new(cfg(), LatencyModel::nominal(1e9, 1e8), CacheMode::CacheY, 8);
        assert_eq!(s.calc_cost(&[]), 0.0);
    }

    #[test]
    fn qos_aware_routes_interactive_to_best_completion() {
        // same scenario as mask_aware_sees_through_request_counts: for a
        // latency-sensitive class, qos-aware must behave like mask-aware
        let mut s = QosAware::new(cfg(), LatencyModel::nominal(1e9, 1e8), CacheMode::CacheY, 8);
        let book = vec![vec![o(1, 2), o(2, 2)], vec![o(3, 64)]];
        assert_eq!(s.pick(&o_class(9, 2, Priority::Interactive), &book, &uniform()), 0);
        assert_eq!(s.pick(&o_class(9, 2, Priority::Standard), &book, &uniform()), 0);
    }

    #[test]
    fn qos_aware_routes_batch_to_cheapest_worker() {
        let mut s = QosAware::new(cfg(), LatencyModel::nominal(1e9, 1e8), CacheMode::CacheY, 8);
        // worker 0 holds the template hot but has a deep backlog; worker 1
        // is idle but cold (would pay a full registration trace)
        let busy: Vec<Outstanding> = (0..16).map(|i| o(i, 64)).collect();
        let book = vec![busy, vec![]];
        let ctx = RouteCtx {
            residency: vec![Residency::Host, Residency::Absent],
            template_bytes: 8 << 20,
            ..RouteCtx::default()
        };
        // batch avoids the cache load: it has no latency target, so the
        // cheapest (no-penalty) worker wins despite the backlog
        assert_eq!(s.pick(&o_class(9, 4, Priority::Batch), &book, &ctx), 0);
        // interactive pays for latency instead: the idle worker's
        // registration cost is smaller than the monster backlog
        assert_eq!(s.pick(&o_class(9, 4, Priority::Interactive), &book, &ctx), 1);
        // with equal (absent) residency everywhere, batch falls back to
        // the least marginal backlog cost
        let ctx = RouteCtx {
            residency: vec![Residency::Absent, Residency::Absent],
            template_bytes: 8 << 20,
            ..RouteCtx::default()
        };
        assert_eq!(s.pick(&o_class(9, 4, Priority::Batch), &book, &ctx), 1);
    }

    #[test]
    fn by_name_covers_all() {
        let c = cfg();
        let l = LatencyModel::nominal(1e9, 1e8);
        for n in POLICY_NAMES {
            assert!(by_name(n, &c, &l, CacheMode::CacheY, 8).is_some(), "{n}");
        }
        assert!(by_name("nope", &c, &l, CacheMode::CacheY, 8).is_none());
    }

    #[test]
    fn empty_availability_means_everyone_available() {
        let ctx = uniform();
        assert!(ctx.is_available(0));
        assert!(ctx.is_available(17));
    }

    #[test]
    fn all_policies_skip_unavailable_workers() {
        let c = cfg();
        let l = LatencyModel::nominal(1e9, 1e8);
        // worker 0 is idle but unavailable (dead / draining); worker 1 is
        // loaded but alive — every policy must route to worker 1
        let book = vec![vec![], vec![o(1, 16), o(2, 16)]];
        let ctx = RouteCtx {
            residency: vec![Residency::Host, Residency::Absent],
            template_bytes: 8 << 20,
            available: vec![false, true],
            ..RouteCtx::default()
        };
        for n in POLICY_NAMES {
            let mut s = by_name(n, &c, &l, CacheMode::CacheY, 8).unwrap();
            assert_eq!(s.pick(&o(9, 4), &book, &ctx), 1, "policy {n}");
        }
        // a session pinned to the dead worker must fall back, not stick
        let mut sa = SessionAffinity::new(cfg(), l.clone(), CacheMode::CacheY, 8);
        let pinned_dead = RouteCtx { session_owner: Some(0), ..ctx.clone() };
        assert_eq!(sa.pick(&o(9, 4), &book, &pinned_dead), 1);
        // batch class goes through the qos-aware penalty path; make sure
        // that branch skips the dead worker too
        let mut q = QosAware::new(cfg(), l.clone(), CacheMode::CacheY, 8);
        assert_eq!(q.pick(&o_class(9, 4, Priority::Batch), &book, &ctx), 1);
    }

    #[test]
    fn session_affinity_sticks_to_owner_and_falls_back() {
        let l = LatencyModel::nominal(1e9, 1e8);
        let mut s = SessionAffinity::new(cfg(), l, CacheMode::CacheY, 8);
        // owner is busier than its peer, but the session sticks anyway:
        // warm device KV beats a shorter queue
        let book = vec![vec![o(1, 16), o(2, 16)], vec![]];
        let owned = RouteCtx { session_owner: Some(0), ..RouteCtx::default() };
        assert_eq!(s.pick(&o(9, 4), &book, &owned), 0);
        // no session -> behaves exactly like mask-aware (best completion)
        assert_eq!(s.pick(&o(9, 4), &book, &RouteCtx::default()), 1);
        // stale owner index beyond the book -> fallback, not a panic
        let beyond = RouteCtx { session_owner: Some(7), ..RouteCtx::default() };
        assert_eq!(s.pick(&o(9, 4), &book, &beyond), 1);
    }

    #[test]
    fn round_robin_cycles_over_available_subset() {
        let mut rr = RoundRobin::default();
        let book = vec![vec![], vec![], vec![]];
        let ctx = RouteCtx { available: vec![true, false, true], ..RouteCtx::default() };
        let picks: Vec<usize> = (0..4).map(|_| rr.pick(&o(1, 4), &book, &ctx)).collect();
        assert_eq!(picks, vec![0, 2, 0, 2]);
    }
}
