//! Statistics kit: percentiles, summaries, least-squares regression, R².
//!
//! Used by the metrics recorder (latency percentiles), the latency models
//! of §4.4 (linear regression + R², Fig. 11) and the bench harness.

/// Percentile of a sample (linear interpolation, p in [0, 100]).
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let p = p.clamp(0.0, 100.0);
    let idx = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = idx.floor() as usize;
    let hi = idx.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = idx - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Summary of a latency sample.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary::default();
        }
        let mut s = xs.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n: s.len(),
            mean: mean(&s),
            p50: percentile(&s, 50.0),
            p95: percentile(&s, 95.0),
            p99: percentile(&s, 99.0),
            min: s[0],
            max: *s.last().unwrap(),
        }
    }
}

/// Ordinary least squares fit `y = a * x + b` with R².
///
/// The paper's latency models (§4.4) are linear in FLOPs / bytes derived
/// from the mask ratio (Table 1); Fig. 11 reports R² = 0.99.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinearFit {
    pub slope: f64,
    pub intercept: f64,
    pub r2: f64,
}

pub fn linear_fit(xs: &[f64], ys: &[f64]) -> LinearFit {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2, "need >= 2 points");
    let n = xs.len() as f64;
    let mx = mean(xs);
    let my = mean(ys);
    let sxx: f64 = xs.iter().map(|x| (x - mx).powi(2)).sum();
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let slope = if sxx.abs() < 1e-30 { 0.0 } else { sxy / sxx };
    let intercept = my - slope * mx;
    let ss_tot: f64 = ys.iter().map(|y| (y - my).powi(2)).sum();
    let ss_res: f64 = xs
        .iter()
        .zip(ys)
        .map(|(x, y)| (y - (slope * x + intercept)).powi(2))
        .sum();
    let r2 = if ss_tot.abs() < 1e-30 { 1.0 } else { 1.0 - ss_res / ss_tot };
    let _ = n;
    LinearFit { slope, intercept, r2 }
}

impl LinearFit {
    pub fn predict(&self, x: f64) -> f64 {
        self.slope * x + self.intercept
    }
}

/// Least squares with a non-negative intercept — latency models must not
/// predict negative (or zero) time for small shapes where fixed dispatch
/// overhead dominates. When plain OLS yields a negative intercept, the
/// intercept is floored at the smallest observed sample and the slope is
/// refit through that floor.
pub fn linear_fit_nonneg(xs: &[f64], ys: &[f64]) -> LinearFit {
    let fit = linear_fit(xs, ys);
    if fit.intercept >= 0.0 {
        return fit;
    }
    let b = ys.iter().cloned().fold(f64::INFINITY, f64::min).max(0.0);
    let sxx: f64 = xs.iter().map(|x| x * x).sum();
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| x * (y - b)).sum();
    let slope = if sxx.abs() < 1e-30 { 0.0 } else { (sxy / sxx).max(0.0) };
    let my = mean(ys);
    let ss_tot: f64 = ys.iter().map(|y| (y - my).powi(2)).sum();
    let ss_res: f64 = xs
        .iter()
        .zip(ys)
        .map(|(x, y)| (y - (slope * x + b)).powi(2))
        .sum();
    let r2 = if ss_tot.abs() < 1e-30 { 1.0 } else { 1.0 - ss_res / ss_tot };
    LinearFit { slope, intercept: b, r2 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[3.0, 1.0, 2.0]);
        assert_eq!(s.n, 3);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert!((s.mean - 2.0).abs() < 1e-12);
    }

    #[test]
    fn linear_fit_exact_line() {
        let xs: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 * x + 1.0).collect();
        let fit = linear_fit(&xs, &ys);
        assert!((fit.slope - 3.0).abs() < 1e-9);
        assert!((fit.intercept - 1.0).abs() < 1e-9);
        assert!((fit.r2 - 1.0).abs() < 1e-12);
        assert!((fit.predict(20.0) - 61.0).abs() < 1e-9);
    }

    #[test]
    fn linear_fit_noisy_r2_below_one() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs
            .iter()
            .enumerate()
            .map(|(i, x)| 2.0 * x + if i % 2 == 0 { 5.0 } else { -5.0 })
            .collect();
        let fit = linear_fit(&xs, &ys);
        assert!(fit.r2 < 1.0);
        assert!(fit.r2 > 0.9); // signal dominates
    }
}
