//! Bench harness (offline substitute for `criterion`).
//!
//! Each `benches/*.rs` is a `harness = false` binary that uses this module
//! to time closures (warmup + measured iterations, mean/p50/p95) and to
//! print paper-style tables + CSV files under `bench_results/`.

use std::io::Write;
use std::time::Instant;

use crate::util::stats::Summary;

/// Time `f` for `iters` iterations after `warmup` unmeasured runs.
pub fn time_it(warmup: usize, iters: usize, mut f: impl FnMut()) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    Summary::of(&samples)
}

/// A result table with aligned columns, echoed to stdout and saved as CSV.
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity");
        self.rows.push(cells.to_vec());
    }

    /// Convenience: format mixed cells.
    pub fn rowf(&mut self, cells: &[&dyn std::fmt::Display]) {
        self.row(&cells.iter().map(|c| format!("{c}")).collect::<Vec<_>>());
    }

    /// Print aligned to stdout.
    pub fn print(&self) {
        println!("\n== {} ==", self.title);
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        println!("{}", fmt_row(&self.header));
        for row in &self.rows {
            println!("{}", fmt_row(row));
        }
    }

    /// Save as CSV under `bench_results/<name>.csv`.
    pub fn save_csv(&self, name: &str) -> std::io::Result<std::path::PathBuf> {
        let dir = std::path::Path::new("bench_results");
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{name}.csv"));
        let mut f = std::fs::File::create(&path)?;
        writeln!(f, "{}", self.header.join(","))?;
        for row in &self.rows {
            writeln!(f, "{}", row.join(","))?;
        }
        Ok(path)
    }
}

/// Format seconds adaptively (ns/µs/ms/s).
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.0}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.3}s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_it_reports_sane_numbers() {
        let s = time_it(2, 10, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(s.n, 10);
        assert!(s.mean >= 0.0 && s.mean < 0.1);
        assert!(s.p95 >= s.p50);
    }

    #[test]
    fn table_roundtrip() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        t.rowf(&[&3, &"x"]);
        assert_eq!(t.rows.len(), 2);
        t.print();
    }

    #[test]
    fn fmt_secs_units() {
        assert!(fmt_secs(5e-9).ends_with("ns"));
        assert!(fmt_secs(5e-6).ends_with("µs"));
        assert!(fmt_secs(5e-3).ends_with("ms"));
        assert!(fmt_secs(5.0).ends_with('s'));
    }
}
