//! Bench harness (offline substitute for `criterion`).
//!
//! Each `benches/*.rs` is a `harness = false` binary that uses this module
//! to time closures (warmup + measured iterations, mean/p50/p95) and to
//! print paper-style tables + CSV files under `bench_results/`.

use std::io::Write;
use std::time::Instant;

use crate::util::stats::Summary;

/// Time `f` for `iters` iterations after `warmup` unmeasured runs.
pub fn time_it(warmup: usize, iters: usize, mut f: impl FnMut()) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    Summary::of(&samples)
}

/// A result table with aligned columns, echoed to stdout and saved as CSV.
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity");
        self.rows.push(cells.to_vec());
    }

    /// Convenience: format mixed cells.
    pub fn rowf(&mut self, cells: &[&dyn std::fmt::Display]) {
        self.row(&cells.iter().map(|c| format!("{c}")).collect::<Vec<_>>());
    }

    /// Print aligned to stdout.
    pub fn print(&self) {
        println!("\n== {} ==", self.title);
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        println!("{}", fmt_row(&self.header));
        for row in &self.rows {
            println!("{}", fmt_row(row));
        }
    }

    /// Save as CSV under `bench_results/<name>.csv`.
    pub fn save_csv(&self, name: &str) -> std::io::Result<std::path::PathBuf> {
        let dir = std::path::Path::new("bench_results");
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{name}.csv"));
        let mut f = std::fs::File::create(&path)?;
        writeln!(f, "{}", self.header.join(","))?;
        for row in &self.rows {
            writeln!(f, "{}", row.join(","))?;
        }
        Ok(path)
    }
}

/// One measured step-loop overhead sample (shared by the §6.6 microbench
/// row and `examples/overhead_bench.rs`, so the two cannot drift apart).
#[derive(Debug, Clone, Copy)]
pub struct StepOverhead {
    /// Mean measured step latency (batch inference time / steps).
    pub step_latency: f64,
    /// `step_latency - pipeline::ideal_latency(costs)`.
    pub overhead: f64,
    pub transfers_per_step: f64,
    pub h2d_bytes_per_step: f64,
    pub d2h_bytes_per_step: f64,
    pub steps: usize,
    /// Token bucket of the solo requests.
    pub bucket_n: usize,
    /// Ideal (free-load) per-step latency from the worker's own costs.
    pub ideal: f64,
    /// Algorithm-1 predicted per-step latency.
    pub planned: f64,
}

/// Measure per-step coordinator overhead on a solo request stream: a
/// 1-worker static-batching InstGenIE cluster serves `requests` equal
/// edits sequentially (every step at b = 1, fixed bucket), then the
/// measured step latency is compared against `pipeline::ideal_latency`
/// on the same costs the worker's DP sees (copy-stream slope =
/// 1/bandwidth, engine cache mode). `device` toggles the
/// device-resident loop vs the host-round-trip reference. `Ok(None)`
/// when artifacts are not built.
pub fn measure_step_overhead(
    model: &str,
    device: bool,
    requests: usize,
    ratio: f64,
) -> anyhow::Result<Option<StepOverhead>> {
    use crate::cache::{pipeline, LatencyModel};
    use crate::cluster::{Cluster, ClusterOpts};
    use crate::config::{BatchingPolicy, EngineConfig, SystemKind};
    use crate::engine::request::EditRequestBuilder;
    use crate::util::stats::LinearFit;
    use std::time::Duration;

    let Ok(manifest) = crate::runtime::Manifest::load("artifacts") else {
        return Ok(None);
    };
    let Ok(mcfg) = manifest.model(model).map(|m| m.config.clone()) else {
        return Ok(None);
    };
    let lat = LatencyModel::load_or_nominal("artifacts", model);
    let mut engine = EngineConfig::for_system(SystemKind::InstGenIE);
    engine.batching = BatchingPolicy::Static;
    engine.device_resident = device;
    engine.prepost_cpu_us = 100;
    let mode = engine.cache_mode;
    let bandwidth = engine.sim_bandwidth;
    let sched = crate::scheduler::by_name(
        "round-robin",
        &mcfg,
        &lat,
        engine.cache_mode,
        engine.max_batch,
    )
    .expect("scheduler");
    let cluster = Cluster::launch(
        ClusterOpts {
            workers: 1,
            engine,
            model: model.into(),
            artifact_dir: "artifacts".into(),
            templates: vec!["tpl-oh".into()],
            lat_model: lat.clone(),
            warmup: true,
        },
        sched,
    )?;

    let mut inference = 0.0;
    let mut n = 0;
    for i in 0..requests.max(1) {
        let req = EditRequestBuilder::new(1 + i as u64)
            .template("tpl-oh")
            .prompt_seed(7) // same mask for every request -> fixed bucket
            .synth_mask(mcfg.latent_hw, ratio)
            .map_err(anyhow::Error::new)?
            .build()
            .map_err(anyhow::Error::new)?;
        n = mcfg.bucket_for(req.mask.masked_count());
        let resp = cluster
            .submit_checked(req)
            .map_err(anyhow::Error::new)?
            .wait(Duration::from_secs(600))
            .map_err(anyhow::Error::new)?;
        inference += resp.timing.inference;
    }
    // the final publish lands just after the last ticket resolves
    std::thread::sleep(Duration::from_millis(200));
    let snap = cluster.worker_snapshots().remove(0);
    cluster.shutdown()?;

    let steps = snap.steps_executed.max(1);
    let mut worker_lat = lat;
    worker_lat.load = LinearFit { slope: 1.0 / bandwidth, intercept: 0.0, r2: 1.0 };
    let costs = worker_lat.step_costs(&mcfg, n, 1, mode);
    let ideal = pipeline::ideal_latency(&costs);
    let planned = pipeline::plan(&costs).latency;
    let step_latency = inference / steps as f64;
    Ok(Some(StepOverhead {
        step_latency,
        overhead: step_latency - ideal,
        transfers_per_step: (snap.transfers.h2d_ops + snap.transfers.d2h_ops) as f64
            / steps as f64,
        h2d_bytes_per_step: snap.transfers.h2d_bytes as f64 / steps as f64,
        d2h_bytes_per_step: snap.transfers.d2h_bytes as f64 / steps as f64,
        steps,
        bucket_n: n,
        ideal,
        planned,
    }))
}

/// Device-KV-tier warm/cold transfer split measured on a repeated solo
/// request (same prompt seed -> same mask -> same tier keys): request 1
/// is the cold pass that populates the tier, requests 2.. replay it
/// warm. Shared by `examples/overhead_bench.rs` and its CI regression
/// guard.
#[derive(Debug, Clone, Copy)]
pub struct KvTierOverhead {
    /// Staged-K/V bytes uploaded per step during the cold pass.
    pub cold_kv_bytes_per_step: f64,
    /// Staged-K/V bytes uploaded per step once the template is warm
    /// (the tentpole invariant: 0 in steady state).
    pub warm_kv_bytes_per_step: f64,
    pub cold_steps: usize,
    pub warm_steps: usize,
    /// Device-tier hits/misses over the whole run.
    pub dev_hits: u64,
    pub dev_misses: u64,
    /// Misses during the warm passes alone (0 when the budget holds the
    /// whole trace).
    pub warm_misses: u64,
    /// hits / (hits + misses) over the whole run; 0 when the tier never
    /// engaged (no chainable artifacts, tier disabled).
    pub hit_rate: f64,
}

/// Measure the device KV tier's warm/cold split: a 1-worker static
/// InstGenIE cluster in `CacheKV` mode with the device-resident loop
/// serves `requests` *identical* solo edits sequentially, and the
/// KV transfer counters are snapshotted after the first (cold) request
/// and after the rest (warm). `Ok(None)` when artifacts are not built.
pub fn measure_kv_tier_overhead(
    model: &str,
    requests: usize,
    ratio: f64,
) -> anyhow::Result<Option<KvTierOverhead>> {
    use crate::cache::LatencyModel;
    use crate::cluster::{Cluster, ClusterOpts};
    use crate::config::{BatchingPolicy, CacheMode, EngineConfig, SystemKind};
    use crate::engine::request::EditRequestBuilder;
    use std::time::Duration;

    let Ok(manifest) = crate::runtime::Manifest::load("artifacts") else {
        return Ok(None);
    };
    let Ok(mcfg) = manifest.model(model).map(|m| m.config.clone()) else {
        return Ok(None);
    };
    let lat = LatencyModel::load_or_nominal("artifacts", model);
    let mut engine = EngineConfig::for_system(SystemKind::InstGenIE);
    engine.batching = BatchingPolicy::Static;
    engine.cache_mode = CacheMode::CacheKV;
    engine.device_resident = true;
    engine.prepost_cpu_us = 100;
    let sched = crate::scheduler::by_name(
        "round-robin",
        &mcfg,
        &lat,
        engine.cache_mode,
        engine.max_batch,
    )
    .expect("scheduler");
    let cluster = Cluster::launch(
        ClusterOpts {
            workers: 1,
            engine,
            model: model.into(),
            artifact_dir: "artifacts".into(),
            templates: vec!["tpl-kv".into()],
            lat_model: lat,
            warmup: true,
        },
        sched,
    )?;

    let run_one = |id: u64| -> anyhow::Result<()> {
        let req = EditRequestBuilder::new(id)
            .template("tpl-kv")
            .prompt_seed(7) // identical mask -> identical tier keys
            .synth_mask(mcfg.latent_hw, ratio)
            .map_err(anyhow::Error::new)?
            .build()
            .map_err(anyhow::Error::new)?;
        cluster
            .submit_checked(req)
            .map_err(anyhow::Error::new)?
            .wait(Duration::from_secs(600))
            .map_err(anyhow::Error::new)?;
        // the publish lands just after the final step resolves the ticket
        std::thread::sleep(Duration::from_millis(200));
        Ok(())
    };
    let snap = |c: &Cluster| {
        let s = &c.worker_snapshots()[0];
        (s.transfers, s.steps_executed)
    };

    let (t0, s0) = snap(&cluster);
    run_one(1)?;
    let (t1, s1) = snap(&cluster);
    for i in 1..requests.max(2) {
        run_one(1 + i as u64)?;
    }
    let (t2, s2) = snap(&cluster);
    cluster.shutdown()?;

    let cold_steps = (s1 - s0).max(1);
    let warm_steps = (s2 - s1).max(1);
    let hits = t2.kv_dev_hits - t0.kv_dev_hits;
    let misses = t2.kv_dev_misses - t0.kv_dev_misses;
    Ok(Some(KvTierOverhead {
        cold_kv_bytes_per_step: (t1.kv_h2d_bytes - t0.kv_h2d_bytes) as f64 / cold_steps as f64,
        warm_kv_bytes_per_step: (t2.kv_h2d_bytes - t1.kv_h2d_bytes) as f64 / warm_steps as f64,
        cold_steps,
        warm_steps,
        dev_hits: hits,
        dev_misses: misses,
        warm_misses: t2.kv_dev_misses - t1.kv_dev_misses,
        hit_rate: if hits + misses > 0 {
            hits as f64 / (hits + misses) as f64
        } else {
            0.0
        },
    }))
}

/// Format seconds adaptively (ns/µs/ms/s).
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.0}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.3}s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_it_reports_sane_numbers() {
        let s = time_it(2, 10, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(s.n, 10);
        assert!(s.mean >= 0.0 && s.mean < 0.1);
        assert!(s.p95 >= s.p50);
    }

    #[test]
    fn table_roundtrip() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        t.rowf(&[&3, &"x"]);
        assert_eq!(t.rows.len(), 2);
        t.print();
    }

    #[test]
    fn fmt_secs_units() {
        assert!(fmt_secs(5e-9).ends_with("ns"));
        assert!(fmt_secs(5e-6).ends_with("µs"));
        assert!(fmt_secs(5e-3).ends_with("ms"));
        assert!(fmt_secs(5.0).ends_with('s'));
    }
}
