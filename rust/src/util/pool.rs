//! Fixed-size worker thread pool (offline substitute for tokio's blocking
//! pool). Used for the disaggregated pre/post-processing of §4.3: the
//! denoising step-loop thread never runs CPU-bound image work itself; it
//! submits jobs here and receives completions over channels.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed pool of named worker threads.
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    queued: Arc<AtomicUsize>,
}

impl ThreadPool {
    /// Spawn `size` workers named `<name>-<i>`.
    pub fn new(name: &str, size: usize) -> ThreadPool {
        assert!(size > 0);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let queued = Arc::new(AtomicUsize::new(0));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let queued = Arc::clone(&queued);
                std::thread::Builder::new()
                    .name(format!("{name}-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                job();
                                queued.fetch_sub(1, Ordering::Relaxed);
                            }
                            Err(_) => break, // pool dropped
                        }
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, queued }
    }

    /// Submit a job; never blocks.
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        self.queued.fetch_add(1, Ordering::Relaxed);
        self.tx
            .as_ref()
            .expect("pool alive")
            .send(Box::new(job))
            .expect("pool workers alive");
    }

    /// Jobs submitted but not yet finished (approximate; for backpressure).
    pub fn in_flight(&self) -> usize {
        self.queued.load(Ordering::Relaxed)
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close the channel; workers drain then exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// One-shot result slot: submit work to a pool, await the value elsewhere.
pub struct Promise<T> {
    rx: Receiver<T>,
}

impl<T: Send + 'static> Promise<T> {
    /// Run `f` on `pool`, returning a promise for its result.
    pub fn spawn<F>(pool: &ThreadPool, f: F) -> Promise<T>
    where
        F: FnOnce() -> T + Send + 'static,
    {
        let (tx, rx) = channel();
        pool.submit(move || {
            let _ = tx.send(f());
        });
        Promise { rx }
    }

    /// Block until the result is ready.
    pub fn wait(self) -> T {
        self.rx.recv().expect("promise completed")
    }

    /// Non-blocking poll.
    pub fn try_take(&self) -> Option<T> {
        self.rx.try_recv().ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new("t", 4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn promise_returns_value() {
        let pool = ThreadPool::new("p", 2);
        let p = Promise::spawn(&pool, || 21 * 2);
        assert_eq!(p.wait(), 42);
    }

    #[test]
    fn promises_run_concurrently() {
        let pool = ThreadPool::new("c", 2);
        let t0 = std::time::Instant::now();
        let a = Promise::spawn(&pool, || std::thread::sleep(std::time::Duration::from_millis(50)));
        let b = Promise::spawn(&pool, || std::thread::sleep(std::time::Duration::from_millis(50)));
        a.wait();
        b.wait();
        assert!(t0.elapsed() < std::time::Duration::from_millis(95));
    }
}
