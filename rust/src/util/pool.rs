//! Fixed-size worker thread pool (offline substitute for tokio's blocking
//! pool) with two priority lanes. Used for the disaggregated pre/post-
//! processing of §4.3: the denoising step-loop thread never runs CPU-bound
//! image work itself; it submits jobs here and receives completions over
//! channels. The low-priority lane carries background cache work — online
//! template registration and disk-tier prefetches — so it can never delay
//! latency-critical pre/post jobs.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

#[derive(Default)]
struct Lanes {
    normal: VecDeque<Job>,
    low: VecDeque<Job>,
    closed: bool,
}

struct Shared {
    lanes: Mutex<Lanes>,
    cv: Condvar,
}

/// A fixed pool of named worker threads with a normal and a low-priority
/// lane. Workers drain the normal lane first; low-lane jobs run only when
/// no normal job is waiting.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    queued: Arc<AtomicUsize>,
}

impl ThreadPool {
    /// Spawn `size` workers named `<name>-<i>`.
    pub fn new(name: &str, size: usize) -> ThreadPool {
        assert!(size > 0);
        let shared = Arc::new(Shared { lanes: Mutex::new(Lanes::default()), cv: Condvar::new() });
        let queued = Arc::new(AtomicUsize::new(0));
        let workers = (0..size)
            .map(|i| {
                let shared = Arc::clone(&shared);
                let queued = Arc::clone(&queued);
                std::thread::Builder::new()
                    .name(format!("{name}-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let mut g = shared.lanes.lock().unwrap();
                            loop {
                                let lanes = &mut *g;
                                if let Some(j) = lanes
                                    .normal
                                    .pop_front()
                                    .or_else(|| lanes.low.pop_front())
                                {
                                    break Some(j);
                                }
                                if g.closed {
                                    break None;
                                }
                                g = shared.cv.wait(g).unwrap();
                            }
                        };
                        match job {
                            Some(job) => {
                                job();
                                queued.fetch_sub(1, Ordering::Relaxed);
                            }
                            None => break, // pool dropped + lanes drained
                        }
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        ThreadPool { shared, workers, queued }
    }

    fn push(&self, job: Job, low: bool) {
        self.queued.fetch_add(1, Ordering::Relaxed);
        let mut g = self.shared.lanes.lock().unwrap();
        assert!(!g.closed, "pool alive");
        if low {
            g.low.push_back(job);
        } else {
            g.normal.push_back(job);
        }
        drop(g);
        self.shared.cv.notify_one();
    }

    /// Submit a job on the normal lane; never blocks.
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        self.push(Box::new(job), false);
    }

    /// Submit a background job on the low-priority lane: it runs only when
    /// no normal-lane job is waiting (template registration, prefetches).
    pub fn submit_low(&self, job: impl FnOnce() + Send + 'static) {
        self.push(Box::new(job), true);
    }

    /// Jobs submitted but not yet finished (approximate; for backpressure).
    pub fn in_flight(&self) -> usize {
        self.queued.load(Ordering::Relaxed)
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shared.lanes.lock().unwrap().closed = true;
        self.shared.cv.notify_all(); // workers drain both lanes, then exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// One-shot result slot: submit work to a pool, await the value elsewhere.
pub struct Promise<T> {
    rx: Receiver<T>,
}

impl<T: Send + 'static> Promise<T> {
    /// Run `f` on `pool`, returning a promise for its result.
    pub fn spawn<F>(pool: &ThreadPool, f: F) -> Promise<T>
    where
        F: FnOnce() -> T + Send + 'static,
    {
        let (tx, rx) = channel();
        pool.submit(move || {
            let _ = tx.send(f());
        });
        Promise { rx }
    }

    /// Block until the result is ready.
    pub fn wait(self) -> T {
        self.rx.recv().expect("promise completed")
    }

    /// Non-blocking poll.
    pub fn try_take(&self) -> Option<T> {
        self.rx.try_recv().ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new("t", 4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn promise_returns_value() {
        let pool = ThreadPool::new("p", 2);
        let p = Promise::spawn(&pool, || 21 * 2);
        assert_eq!(p.wait(), 42);
    }

    #[test]
    fn promises_run_concurrently() {
        let pool = ThreadPool::new("c", 2);
        let t0 = std::time::Instant::now();
        let a = Promise::spawn(&pool, || std::thread::sleep(std::time::Duration::from_millis(50)));
        let b = Promise::spawn(&pool, || std::thread::sleep(std::time::Duration::from_millis(50)));
        a.wait();
        b.wait();
        assert!(t0.elapsed() < std::time::Duration::from_millis(95));
    }

    #[test]
    fn low_lane_yields_to_normal_lane() {
        // one worker, blocked by a gate job; while it is blocked, enqueue a
        // low-lane job and then a normal-lane job — the normal one must run
        // first even though it was submitted second.
        let pool = ThreadPool::new("lanes", 1);
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        {
            let gate = Arc::clone(&gate);
            pool.submit(move || {
                let (lock, cv) = &*gate;
                let mut open = lock.lock().unwrap();
                while !*open {
                    open = cv.wait(open).unwrap();
                }
            });
        }
        let order = Arc::new(Mutex::new(Vec::new()));
        {
            let order = Arc::clone(&order);
            pool.submit_low(move || order.lock().unwrap().push("low"));
        }
        {
            let order = Arc::clone(&order);
            pool.submit(move || order.lock().unwrap().push("normal"));
        }
        {
            let (lock, cv) = &*gate;
            *lock.lock().unwrap() = true;
            cv.notify_all();
        }
        drop(pool); // join: all three jobs ran
        assert_eq!(*order.lock().unwrap(), vec!["normal", "low"]);
    }
}
