//! Host-side f32 tensor: shaped storage for latents, activation caches and
//! quality metrics. Deliberately small — the heavy math runs in XLA; this
//! type covers packing/gather/scatter on the coordinator hot path plus the
//! host-side VAE-analogue matmuls in pre/post-processing.

use anyhow::{bail, Result};

/// A dense row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elements, got {}", shape, n, data.len());
        }
        Ok(Tensor { shape: shape.to_vec(), data })
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn size_bytes(&self) -> usize {
        self.data.len() * 4
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Row view for a 2-D tensor (rows, cols).
    pub fn row(&self, r: usize) -> &[f32] {
        let cols = *self.shape.last().unwrap();
        &self.data[r * cols..(r + 1) * cols]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        let cols = *self.shape.last().unwrap();
        &mut self.data[r * cols..(r + 1) * cols]
    }

    /// Gather rows of a 2-D tensor into `out` (len(ids) x cols).
    pub fn gather_rows_into(&self, ids: &[usize], out: &mut [f32]) {
        let cols = *self.shape.last().unwrap();
        debug_assert_eq!(out.len(), ids.len() * cols);
        for (i, &id) in ids.iter().enumerate() {
            out[i * cols..(i + 1) * cols]
                .copy_from_slice(&self.data[id * cols..(id + 1) * cols]);
        }
    }

    /// Scatter rows from `src` (len(ids) x cols) into this 2-D tensor.
    pub fn scatter_rows_from(&mut self, ids: &[usize], src: &[f32]) {
        let cols = *self.shape.last().unwrap();
        debug_assert_eq!(src.len(), ids.len() * cols);
        for (i, &id) in ids.iter().enumerate() {
            self.data[id * cols..(id + 1) * cols]
                .copy_from_slice(&src[i * cols..(i + 1) * cols]);
        }
    }

    /// `self (R x K) @ other (K x C)` — host matmul for VAE-analogue
    /// encode/decode in pre/post-processing (deliberately CPU work,
    /// mirroring the paper's CPU-intensive image processing).
    pub fn matmul(&self, other: &Tensor) -> Result<Tensor> {
        let (r, k) = match self.shape[..] {
            [r, k] => (r, k),
            _ => bail!("matmul lhs must be 2-D, got {:?}", self.shape),
        };
        let (k2, c) = match other.shape[..] {
            [k2, c] => (k2, c),
            _ => bail!("matmul rhs must be 2-D, got {:?}", other.shape),
        };
        if k != k2 {
            bail!("matmul inner dims {k} vs {k2}");
        }
        let mut out = vec![0.0f32; r * c];
        for i in 0..r {
            let a_row = &self.data[i * k..(i + 1) * k];
            let o_row = &mut out[i * c..(i + 1) * c];
            for (kk, &a) in a_row.iter().enumerate() {
                let b_row = &other.data[kk * c..(kk + 1) * c];
                for (o, &b) in o_row.iter_mut().zip(b_row) {
                    *o += a * b;
                }
            }
        }
        Tensor::from_vec(&[r, c], out)
    }

    /// Elementwise `self += alpha * other`.
    pub fn axpy(&mut self, alpha: f32, other: &Tensor) {
        debug_assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    pub fn map_inplace(&mut self, mut f: impl FnMut(f32) -> f32) {
        for v in &mut self.data {
            *v = f(*v);
        }
    }

    /// Maximum absolute difference (test helper / quality metrics).
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_checked_construction() {
        assert!(Tensor::from_vec(&[2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::from_vec(&[2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn gather_scatter_round_trip() {
        let t = Tensor::from_vec(&[4, 2], (0..8).map(|x| x as f32).collect()).unwrap();
        let ids = [2usize, 0];
        let mut buf = vec![0.0; 4];
        t.gather_rows_into(&ids, &mut buf);
        assert_eq!(buf, vec![4.0, 5.0, 0.0, 1.0]);
        let mut t2 = Tensor::zeros(&[4, 2]);
        t2.scatter_rows_from(&ids, &buf);
        assert_eq!(t2.row(2), &[4.0, 5.0]);
        assert_eq!(t2.row(0), &[0.0, 1.0]);
        assert_eq!(t2.row(1), &[0.0, 0.0]);
    }

    #[test]
    fn matmul_known_values() {
        let a = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let b = Tensor::from_vec(&[2, 2], vec![1.0, 1.0, 1.0, 1.0]).unwrap();
        let c = a.matmul(&b).unwrap();
        assert_eq!(c.data(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_shape_errors() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[2, 3]);
        assert!(a.matmul(&b).is_err());
    }

    #[test]
    fn axpy_and_map() {
        let mut a = Tensor::from_vec(&[3], vec![1.0, 2.0, 3.0]).unwrap();
        let b = Tensor::from_vec(&[3], vec![1.0, 1.0, 1.0]).unwrap();
        a.axpy(2.0, &b);
        assert_eq!(a.data(), &[3.0, 4.0, 5.0]);
        a.map_inplace(|x| x * 0.5);
        assert_eq!(a.data(), &[1.5, 2.0, 2.5]);
    }
}
