//! In-tree substrates (DESIGN.md "Offline-crate substitution"): the cargo
//! registry available in this environment only carries the `xla` crate's
//! dependency closure, so the pieces a serving system would normally pull
//! from crates.io are implemented here, each with its own tests.

pub mod bench;
pub mod cli;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod tensor;
