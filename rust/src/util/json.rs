//! Minimal JSON parser/emitter (offline substitute for `serde_json`).
//!
//! Covers the full JSON grammar the repo needs: the AOT `manifest.json`,
//! trace files, bench CSV/JSON results, and HTTP request bodies. Numbers
//! are f64 (integers round-trip exactly up to 2^53, far beyond any offset
//! in the weight files).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {at}: {msg}")]
pub struct JsonError {
    pub at: usize,
    pub msg: String,
}

impl Json {
    // -- accessors ---------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` chained access; returns Null-ref on miss.
    pub fn at(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.get(key).unwrap_or(&NULL)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// usize list helper (bucket arrays, shapes).
    pub fn usize_list(&self) -> Vec<usize> {
        self.as_arr()
            .map(|v| v.iter().filter_map(Json::as_usize).collect())
            .unwrap_or_default()
    }

    // -- constructors ------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let bytes = text.as_bytes();
        let mut p = Parser { b: bytes, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }
}

impl fmt::Display for Json {
    /// Compact emission (valid JSON).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { at: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("eof"))? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            _ => Err(self.err("unexpected char")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = *self.b.get(self.i).ok_or_else(|| self.err("eof in string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = *self.b.get(self.i).ok_or_else(|| self.err("eof escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("short \\u"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i..self.i + 4])
                                    .map_err(|_| self.err("bad \\u"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u hex"))?;
                            self.i += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                c => {
                    // copy UTF-8 continuation bytes verbatim
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    if self.i > self.b.len() {
                        return Err(self.err("truncated utf8"));
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            out.insert(key, self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_manifest_like() {
        let src = r#"{"version": 3, "models": {"sd21m": {"tokens": 64,
            "buckets": [4, 8, 16, 32], "file": "w.bin", "ok": true,
            "ratio": 0.125, "none": null}}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.at("version").as_usize(), Some(3));
        let m = v.at("models").at("sd21m");
        assert_eq!(m.at("tokens").as_usize(), Some(64));
        assert_eq!(m.at("buckets").usize_list(), vec![4, 8, 16, 32]);
        assert_eq!(m.at("ratio").as_f64(), Some(0.125));
        assert_eq!(m.at("none"), &Json::Null);
        // re-parse of emission equals original value
        let emitted = v.to_string();
        assert_eq!(Json::parse(&emitted).unwrap(), v);
    }

    #[test]
    fn strings_escape_round_trip() {
        let v = Json::str("a\"b\\c\nd\te\u{1}é€");
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(back, v);
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["{", "[1,", "nul", "\"abc", "{\"a\" 1}", "1 2"] {
            assert!(Json::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn numbers() {
        for (s, want) in [("0", 0.0), ("-3.5", -3.5), ("1e3", 1000.0), ("2.5e-2", 0.025)] {
            assert_eq!(Json::parse(s).unwrap().as_f64(), Some(want));
        }
    }

    #[test]
    fn integers_emit_without_fraction() {
        assert_eq!(Json::num(42.0).to_string(), "42");
        assert_eq!(Json::num(0.5).to_string(), "0.5");
    }
}
