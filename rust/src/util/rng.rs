//! Deterministic PRNG + samplers (offline substitute for the `rand` crate).
//!
//! PCG-XSH-RR 64/32 with a splitmix64-seeded state; plus the samplers the
//! workload generator and mask synthesis need: uniform, exponential
//! (Poisson inter-arrivals), normal (latents/noise), Beta (mask-ratio
//! distributions fitted to the paper's Fig. 3) and Poisson counts.

/// PCG-XSH-RR 64/32 pseudo-random generator.
#[derive(Debug, Clone)]
pub struct Pcg {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg {
    /// Create a generator from a seed (stream id fixed).
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Create a generator with an explicit stream (sequence) id.
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let inc = (stream << 1) | 1;
        let mut rng = Self { state: 0, inc };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(inc);
        rng.state = rng.state.wrapping_add(splitmix64(seed));
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(inc);
        rng
    }

    /// Next raw 32-bit output.
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in [0, n) (Lemire's method, unbiased enough for sims).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.f64() * n as f64) as usize % n
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.f64()).max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Exponential with the given rate (mean 1/rate); Poisson inter-arrivals.
    pub fn exponential(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        -(1.0 - self.f64()).max(1e-300).ln() / rate
    }

    /// Poisson count with the given mean (Knuth for small, normal approx big).
    pub fn poisson(&mut self, mean: f64) -> u64 {
        if mean <= 0.0 {
            return 0;
        }
        if mean > 30.0 {
            let x = mean + mean.sqrt() * self.normal();
            return x.max(0.0).round() as u64;
        }
        let l = (-mean).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang (shape >= 0.01).
    pub fn gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            // boost: Gamma(a) = Gamma(a + 1) * U^(1/a)
            let g = self.gamma(shape + 1.0);
            return g * self.f64().powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v;
            }
        }
    }

    /// Beta(a, b) sample — the mask-ratio distribution family (Fig. 3).
    pub fn beta(&mut self, a: f64, b: f64) -> f64 {
        let x = self.gamma(a);
        let y = self.gamma(b);
        x / (x + y)
    }

    /// Fill a slice with scaled standard-normal f32s (latent noise).
    pub fn fill_normal_f32(&mut self, out: &mut [f32], scale: f32) {
        for v in out.iter_mut() {
            *v = self.normal() as f32 * scale;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

/// splitmix64 — seed spreader (also used for stable id hashing).
pub fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

/// Stable 64-bit hash of a string (template-id -> seed derivation).
pub fn hash_str(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64; // FNV-1a
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    splitmix64(h)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg::new(42);
        let mut b = Pcg::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Pcg::new(1);
        let mut b = Pcg::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_mean_and_bounds() {
        let mut rng = Pcg::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn exponential_mean_matches_rate() {
        let mut rng = Pcg::new(3);
        let rate = 2.5;
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| rng.exponential(rate)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / rate).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn poisson_mean() {
        let mut rng = Pcg::new(4);
        for &m in &[0.5, 3.0, 50.0] {
            let n = 20_000;
            let mean: f64 =
                (0..n).map(|_| rng.poisson(m) as f64).sum::<f64>() / n as f64;
            assert!((mean - m).abs() < 0.1 * m.max(1.0), "m={m} mean={mean}");
        }
    }

    #[test]
    fn beta_mean() {
        let mut rng = Pcg::new(5);
        let (a, b) = (2.0, 8.0);
        let n = 30_000;
        let mean: f64 = (0..n).map(|_| rng.beta(a, b)).sum::<f64>() / n as f64;
        assert!((mean - a / (a + b)).abs() < 0.01, "mean={mean}");
        let x = rng.beta(a, b);
        assert!((0.0..=1.0).contains(&x));
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg::new(6);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02);
        assert!((var - 1.0).abs() < 0.05);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg::new(9);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn hash_str_stable() {
        assert_eq!(hash_str("template-0"), hash_str("template-0"));
        assert_ne!(hash_str("template-0"), hash_str("template-1"));
    }
}
