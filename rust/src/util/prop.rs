//! Randomized property-testing driver (offline substitute for `proptest`).
//!
//! Usage:
//! ```ignore
//! prop_check("routing conserves requests", 200, |rng| {
//!     let n = 1 + rng.below(50);
//!     /* build random input, assert invariant, return Ok(()) or Err(msg) */
//!     Ok(())
//! });
//! ```
//! On failure it reports the failing case's seed so the case replays
//! deterministically (`PROP_SEED=<seed>` env var re-runs just that case).

use crate::util::rng::Pcg;

/// Run `cases` random cases of a property; panics with the failing seed.
pub fn prop_check<F>(name: &str, cases: usize, mut prop: F)
where
    F: FnMut(&mut Pcg) -> Result<(), String>,
{
    // replay mode: run a single seed
    if let Ok(seed) = std::env::var("PROP_SEED") {
        if let Ok(seed) = seed.parse::<u64>() {
            let mut rng = Pcg::new(seed);
            if let Err(msg) = prop(&mut rng) {
                panic!("property {name:?} failed on replay seed {seed}: {msg}");
            }
            return;
        }
    }
    let base = 0x5eed_0000u64;
    for case in 0..cases {
        let seed = base + case as u64;
        let mut rng = Pcg::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property {name:?} failed (case {case}, seed {seed}): {msg}\n\
                 replay with PROP_SEED={seed}"
            );
        }
    }
}

/// Assertion helper producing `Result<(), String>` for `prop_check`.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_for_true_property() {
        prop_check("sorting is idempotent", 50, |rng| {
            let mut v: Vec<u32> = (0..rng.below(20)).map(|_| rng.next_u32()).collect();
            v.sort_unstable();
            let w = {
                let mut w = v.clone();
                w.sort_unstable();
                w
            };
            prop_assert!(v == w, "idempotence violated");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "replay with PROP_SEED=")]
    fn fails_with_seed_report() {
        prop_check("always fails eventually", 10, |rng| {
            prop_assert!(rng.f64() < 0.5, "coin came up heads");
            Ok(())
        });
    }
}
