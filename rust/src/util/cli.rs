//! Tiny CLI flag parser (offline substitute for `clap`).
//!
//! Grammar: `instgenie <subcommand> [--flag value] [--switch]`.

use std::collections::BTreeMap;

/// Parsed command line: subcommand + flags.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: String,
    pub flags: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut it = raw.into_iter().peekable();
        let command = it.next().unwrap_or_default();
        let mut flags = BTreeMap::new();
        let mut positional = Vec::new();
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                // `--k=v`, `--k v`, or bare switch `--k`
                if let Some((k, v)) = name.split_once('=') {
                    flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    flags.insert(name.to_string(), it.next().unwrap());
                } else {
                    flags.insert(name.to_string(), "true".to_string());
                }
            } else {
                positional.push(arg);
            }
        }
        Args { command, flags, positional }
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn str(&self, k: &str, default: &str) -> String {
        self.flags.get(k).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn f64(&self, k: &str, default: f64) -> f64 {
        self.flags.get(k).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn usize(&self, k: &str, default: usize) -> usize {
        self.flags.get(k).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u64(&self, k: &str, default: u64) -> u64 {
        self.flags.get(k).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn bool(&self, k: &str) -> bool {
        matches!(self.flags.get(k).map(String::as_str), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("serve --model fluxm --rps 2.5 --workers 4 --disagg");
        assert_eq!(a.command, "serve");
        assert_eq!(a.str("model", "x"), "fluxm");
        assert_eq!(a.f64("rps", 0.0), 2.5);
        assert_eq!(a.usize("workers", 0), 4);
        assert!(a.bool("disagg"));
        assert!(!a.bool("missing"));
    }

    #[test]
    fn equals_syntax_and_positional() {
        let a = parse("bench --mode=static trace.jsonl");
        assert_eq!(a.str("mode", ""), "static");
        assert_eq!(a.positional, vec!["trace.jsonl"]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse("serve");
        assert_eq!(a.usize("workers", 8), 8);
        assert_eq!(a.str("model", "sdxlm"), "sdxlm");
    }
}
