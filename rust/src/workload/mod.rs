//! Workload synthesis — the paper's §2.2 characterization as generators.
//!
//! Mask-ratio distributions are Beta fits matching the trace statistics of
//! Fig. 3 (production mean 0.11, public trace [37] mean 0.19, VITON-HD
//! mean 0.35; all strongly right-skewed). Arrivals are Poisson (§6.1).
//! Template selection is heavily skewed (the production trace reuses 970
//! templates ~35 000 times each), modelled with a Zipf-like draw.
//! Mixed-priority traffic comes from [`ClassMix`] (`--class-mix
//! 0.2,0.5,0.3`): class draws use their own RNG stream, so changing the
//! mix never changes arrivals, masks, or prompt seeds.

use std::time::Duration;

use crate::model::MaskSpec;
use crate::qos::{Priority, CLASS_COUNT};
use crate::util::json::Json;
use crate::util::rng::Pcg;

/// RNG stream tag for class draws: priorities come from their own stream
/// so changing the mix never perturbs arrivals, masks, or seeds.
const CLASS_STREAM: u64 = 0x636c_6173; // "clas"

/// Mask-ratio distribution family (paper Fig. 3).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum MaskDist {
    /// Production face-swap trace: mean 0.11, long right tail.
    Production,
    /// Public trace [37]: mean 0.19.
    PublicTrace,
    /// VITON-HD virtual try-on benchmark: mean 0.35.
    VitonHD,
    /// Degenerate (kernel-sweep benches).
    Fixed(f64),
    /// Uniform in [lo, hi] (ablation stress).
    Uniform(f64, f64),
}

impl MaskDist {
    pub fn parse(s: &str) -> Option<MaskDist> {
        match s {
            "production" => Some(MaskDist::Production),
            "public" => Some(MaskDist::PublicTrace),
            "viton" => Some(MaskDist::VitonHD),
            other => other.parse::<f64>().ok().map(MaskDist::Fixed),
        }
    }

    /// Beta parameters matching the trace mean + skew.
    fn beta_params(&self) -> Option<(f64, f64)> {
        match self {
            MaskDist::Production => Some((1.1, 8.9)),  // mean 0.110
            MaskDist::PublicTrace => Some((1.3, 5.54)), // mean 0.190
            MaskDist::VitonHD => Some((2.2, 4.086)),    // mean 0.350
            _ => None,
        }
    }

    /// Nominal mean of the distribution.
    pub fn mean(&self) -> f64 {
        match self {
            MaskDist::Fixed(m) => *m,
            MaskDist::Uniform(lo, hi) => 0.5 * (lo + hi),
            d => {
                let (a, b) = d.beta_params().unwrap();
                a / (a + b)
            }
        }
    }

    /// Sample a mask ratio in (0, 1].
    pub fn sample(&self, rng: &mut Pcg) -> f64 {
        let r = match self {
            MaskDist::Fixed(m) => *m,
            MaskDist::Uniform(lo, hi) => rng.range_f64(*lo, *hi),
            d => {
                let (a, b) = d.beta_params().unwrap();
                rng.beta(a, b)
            }
        };
        r.clamp(1e-3, 1.0)
    }
}

/// Request-class mix: weights over (interactive, standard, batch),
/// e.g. `--class-mix 0.2,0.5,0.3`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClassMix {
    pub weights: [f64; CLASS_COUNT],
}

impl ClassMix {
    /// Everything `Standard` (the pre-QoS behaviour).
    pub fn all_standard() -> ClassMix {
        ClassMix { weights: [0.0, 1.0, 0.0] }
    }

    /// Parse `"0.2,0.5,0.3"` (interactive, standard, batch). Weights are
    /// relative (they need not sum to 1); negatives and all-zero reject.
    pub fn parse(s: &str) -> Option<ClassMix> {
        let parts: Vec<f64> = s
            .split(',')
            .map(|p| p.trim().parse::<f64>().ok())
            .collect::<Option<Vec<f64>>>()?;
        if parts.len() != CLASS_COUNT {
            return None;
        }
        let weights = [parts[0], parts[1], parts[2]];
        if weights.iter().any(|w| !w.is_finite() || *w < 0.0) {
            return None;
        }
        if weights.iter().sum::<f64>() <= 0.0 {
            return None;
        }
        Some(ClassMix { weights })
    }

    /// Draw a class proportional to the weights.
    pub fn sample(&self, rng: &mut Pcg) -> Priority {
        let total: f64 = self.weights.iter().sum();
        let mut x = rng.f64() * total;
        for p in Priority::ALL {
            x -= self.weights[p.rank()];
            if x < 0.0 {
                return p;
            }
        }
        Priority::Batch
    }
}

/// One generated request event.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    pub id: u64,
    /// Arrival offset from trace start, seconds.
    pub at: f64,
    pub template: String,
    pub mask_ratio: f64,
    pub prompt_seed: u64,
    /// Request class (QoS; `Standard` for legacy traces).
    pub priority: Priority,
    /// Optional completion deadline, ms after submission.
    pub deadline_ms: Option<u64>,
}

impl TraceEvent {
    /// Realize the mask on a given latent grid (deterministic per event).
    pub fn mask(&self, latent_hw: usize) -> MaskSpec {
        let mut rng = Pcg::with_stream(self.prompt_seed, 0x6d61_736b);
        MaskSpec::synth(latent_hw, self.mask_ratio, &mut rng)
    }
}

/// Poisson request-trace generator.
#[derive(Debug, Clone)]
pub struct TraceGen {
    pub rps: f64,
    pub dist: MaskDist,
    pub templates: usize,
    pub seed: u64,
    /// Request-class mix (all-`Standard` by default).
    pub mix: ClassMix,
    /// Per-class deadline defaults, ms (None = no deadline), indexed by
    /// [`Priority::rank`].
    pub deadlines_ms: [Option<u64>; CLASS_COUNT],
}

impl TraceGen {
    pub fn new(rps: f64, dist: MaskDist, templates: usize, seed: u64) -> TraceGen {
        assert!(rps > 0.0 && templates > 0);
        TraceGen {
            rps,
            dist,
            templates,
            seed,
            mix: ClassMix::all_standard(),
            deadlines_ms: [None; CLASS_COUNT],
        }
    }

    /// Mixed-priority traffic (satellite: `--class-mix 0.2,0.5,0.3`).
    pub fn with_mix(mut self, mix: ClassMix) -> TraceGen {
        self.mix = mix;
        self
    }

    /// Attach per-class deadlines to generated events.
    pub fn with_deadlines(mut self, deadlines_ms: [Option<u64>; CLASS_COUNT]) -> TraceGen {
        self.deadlines_ms = deadlines_ms;
        self
    }

    /// Generate `count` events with Poisson inter-arrivals.
    pub fn generate(&self, count: usize) -> Vec<TraceEvent> {
        let mut rng = Pcg::new(self.seed);
        // separate stream: the mix never perturbs arrivals/masks/seeds
        let mut crng = Pcg::with_stream(self.seed, CLASS_STREAM);
        let mut t = 0.0;
        (0..count)
            .map(|i| {
                t += rng.exponential(self.rps);
                // Zipf-ish template popularity: template 0 is hottest
                let z = rng.f64();
                let tpl = ((self.templates as f64) * z * z) as usize % self.templates;
                let priority = self.mix.sample(&mut crng);
                TraceEvent {
                    id: i as u64,
                    at: t,
                    template: format!("tpl-{tpl}"),
                    mask_ratio: self.dist.sample(&mut rng),
                    prompt_seed: rng.next_u64() >> 12, // 52 bits: JSON f64-exact
                    priority,
                    deadline_ms: self.deadlines_ms[priority.rank()],
                }
            })
            .collect()
    }

    /// Distinct template ids used by this generator.
    pub fn template_ids(&self) -> Vec<String> {
        (0..self.templates).map(|i| format!("tpl-{i}")).collect()
    }
}

/// Replay helper: sleep until each event is due, then hand it off.
pub fn replay<F: FnMut(&TraceEvent)>(events: &[TraceEvent], mut submit: F) {
    let start = std::time::Instant::now();
    for ev in events {
        let due = Duration::from_secs_f64(ev.at);
        let now = start.elapsed();
        if due > now {
            std::thread::sleep(due - now);
        }
        submit(ev);
    }
}

// -- JSONL trace record/replay ------------------------------------------------

pub fn to_jsonl(events: &[TraceEvent]) -> String {
    let mut out = String::new();
    for e in events {
        let mut pairs = vec![
            ("id", Json::num(e.id as f64)),
            ("at", Json::num(e.at)),
            ("template", Json::str(e.template.clone())),
            ("mask_ratio", Json::num(e.mask_ratio)),
            ("prompt_seed", Json::num(e.prompt_seed as f64)),
            ("priority", Json::str(e.priority.label())),
        ];
        if let Some(ms) = e.deadline_ms {
            pairs.push(("deadline_ms", Json::num(ms as f64)));
        }
        out.push_str(&Json::obj(pairs).to_string());
        out.push('\n');
    }
    out
}

pub fn from_jsonl(text: &str) -> anyhow::Result<Vec<TraceEvent>> {
    text.lines()
        .filter(|l| !l.trim().is_empty())
        .map(|l| {
            let j = Json::parse(l)?;
            Ok(TraceEvent {
                id: j.at("id").as_f64().unwrap_or(0.0) as u64,
                at: j.at("at").as_f64().unwrap_or(0.0),
                template: j.at("template").as_str().unwrap_or("tpl-0").to_string(),
                mask_ratio: j.at("mask_ratio").as_f64().unwrap_or(0.1),
                prompt_seed: j.at("prompt_seed").as_f64().unwrap_or(0.0) as u64,
                // legacy traces (no class field) default to Standard
                priority: j
                    .at("priority")
                    .as_str()
                    .and_then(Priority::parse)
                    .unwrap_or_default(),
                deadline_ms: j.at("deadline_ms").as_f64().map(|ms| ms as u64),
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::mean;

    #[test]
    fn beta_means_match_paper_fig3() {
        let mut rng = Pcg::new(1);
        for (dist, want) in [
            (MaskDist::Production, 0.11),
            (MaskDist::PublicTrace, 0.19),
            (MaskDist::VitonHD, 0.35),
        ] {
            let xs: Vec<f64> = (0..30_000).map(|_| dist.sample(&mut rng)).collect();
            let m = mean(&xs);
            assert!((m - want).abs() < 0.01, "{dist:?} mean {m} want {want}");
            assert!(xs.iter().all(|&x| (0.0..=1.0).contains(&x)));
        }
    }

    #[test]
    fn production_is_right_skewed() {
        let mut rng = Pcg::new(2);
        let d = MaskDist::Production;
        let xs: Vec<f64> = (0..20_000).map(|_| d.sample(&mut rng)).collect();
        let mut sorted = xs.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = sorted[sorted.len() / 2];
        assert!(median < mean(&xs), "right skew: median {median} < mean");
    }

    #[test]
    fn poisson_interarrival_rate() {
        let g = TraceGen::new(4.0, MaskDist::Fixed(0.1), 4, 7);
        let ev = g.generate(8_000);
        let total = ev.last().unwrap().at;
        let rate = ev.len() as f64 / total;
        assert!((rate - 4.0).abs() < 0.2, "rate {rate}");
        // arrival times strictly increase
        assert!(ev.windows(2).all(|w| w[0].at < w[1].at));
    }

    #[test]
    fn trace_is_seed_deterministic() {
        let a = TraceGen::new(1.0, MaskDist::Production, 8, 42).generate(100);
        let b = TraceGen::new(1.0, MaskDist::Production, 8, 42).generate(100);
        assert_eq!(a, b);
    }

    #[test]
    fn template_popularity_is_skewed() {
        let g = TraceGen::new(1.0, MaskDist::Fixed(0.1), 10, 3);
        let ev = g.generate(10_000);
        let mut counts = vec![0usize; 10];
        for e in &ev {
            let idx: usize = e.template[4..].parse().unwrap();
            counts[idx] += 1;
        }
        // hottest template should far exceed the uniform share
        let max = *counts.iter().max().unwrap();
        assert!(max > 2 * ev.len() / 10, "not skewed: {counts:?}");
    }

    #[test]
    fn jsonl_round_trip() {
        let g = TraceGen::new(2.0, MaskDist::PublicTrace, 4, 5)
            .with_mix(ClassMix::parse("0.2,0.5,0.3").unwrap())
            .with_deadlines([Some(1_500), None, None]);
        let ev = g.generate(50);
        let text = to_jsonl(&ev);
        let back = from_jsonl(&text).unwrap();
        assert_eq!(ev, back);
        // legacy lines without a class field default to Standard
        let legacy = r#"{"id":1,"at":0.5,"template":"tpl-0","mask_ratio":0.2,"prompt_seed":9}"#;
        let back = from_jsonl(legacy).unwrap();
        assert_eq!(back[0].priority, Priority::Standard);
        assert_eq!(back[0].deadline_ms, None);
    }

    #[test]
    fn class_mix_parses_and_samples_proportionally() {
        assert_eq!(ClassMix::parse("nope"), None);
        assert_eq!(ClassMix::parse("0.2,0.5"), None);
        assert_eq!(ClassMix::parse("-0.1,0.5,0.6"), None);
        assert_eq!(ClassMix::parse("0,0,0"), None);
        assert_eq!(ClassMix::parse("nan,1,1"), None, "NaN weights must reject");
        assert_eq!(ClassMix::parse("inf,1,1"), None);
        let mix = ClassMix::parse("0.2,0.5,0.3").unwrap();
        let mut rng = Pcg::new(11);
        let mut counts = [0usize; CLASS_COUNT];
        let n = 20_000;
        for _ in 0..n {
            counts[mix.sample(&mut rng).rank()] += 1;
        }
        for (p, want) in Priority::ALL.iter().zip([0.2, 0.5, 0.3]) {
            let got = counts[p.rank()] as f64 / n as f64;
            assert!((got - want).abs() < 0.02, "{p:?}: got {got}, want {want}");
        }
        // degenerate mix: everything is standard
        let std_only = ClassMix::all_standard();
        for _ in 0..100 {
            assert_eq!(std_only.sample(&mut rng), Priority::Standard);
        }
    }

    #[test]
    fn class_mix_does_not_perturb_arrivals_or_masks() {
        let base = TraceGen::new(2.0, MaskDist::Production, 4, 7).generate(200);
        let mixed = TraceGen::new(2.0, MaskDist::Production, 4, 7)
            .with_mix(ClassMix::parse("1,1,1").unwrap())
            .generate(200);
        for (a, b) in base.iter().zip(&mixed) {
            assert_eq!(a.at, b.at, "arrivals must be identical across mixes");
            assert_eq!(a.mask_ratio, b.mask_ratio);
            assert_eq!(a.prompt_seed, b.prompt_seed);
            assert_eq!(a.template, b.template);
        }
        // and the mixed trace actually contains several classes
        let interactive = mixed.iter().filter(|e| e.priority == Priority::Interactive);
        assert!(interactive.count() > 0);
        // class draws are seed-deterministic too
        let again = TraceGen::new(2.0, MaskDist::Production, 4, 7)
            .with_mix(ClassMix::parse("1,1,1").unwrap())
            .generate(200);
        assert_eq!(mixed, again);
    }

    #[test]
    fn event_mask_is_deterministic() {
        let e = TraceEvent {
            id: 1,
            at: 0.0,
            template: "tpl-0".into(),
            mask_ratio: 0.2,
            prompt_seed: 99,
            priority: Priority::Standard,
            deadline_ms: None,
        };
        assert_eq!(e.mask(8), e.mask(8));
        let got = e.mask(8).ratio();
        assert!((got - 0.2).abs() < 0.1);
    }
}
