//! Workload synthesis — the paper's §2.2 characterization as generators.
//!
//! Mask-ratio distributions are Beta fits matching the trace statistics of
//! Fig. 3 (production mean 0.11, public trace [37] mean 0.19, VITON-HD
//! mean 0.35; all strongly right-skewed). Arrivals are Poisson (§6.1).
//! Template selection is heavily skewed (the production trace reuses 970
//! templates ~35 000 times each), modelled with a Zipf-like draw.
//! Mixed-priority traffic comes from [`ClassMix`] (`--class-mix
//! 0.2,0.5,0.3`): class draws use their own RNG stream, so changing the
//! mix never changes arrivals, masks, or prompt seeds.
//!
//! For the distributed plane's million-template workloads, template
//! popularity is parameterized ([`Popularity`]): the legacy quadratic
//! draw (default, byte-identical to older traces) or a true Zipf(`s`)
//! inverse-CDF over up to 10⁶ templates. Arrival *shapes*
//! ([`ArrivalShape`]) warp the homogeneous Poisson arrivals through the
//! inverse cumulative rate Λ⁻¹ (time-rescaling), so diurnal and
//! burst-storm traffic consume exactly the same RNG draws as a steady
//! trace — changing the shape, the popularity law, or the template count
//! never perturbs masks, prompt seeds, or each event's Λ-coordinate.

use std::time::Duration;

use crate::model::MaskSpec;
use crate::qos::{Priority, CLASS_COUNT};
use crate::util::json::Json;
use crate::util::rng::Pcg;

/// RNG stream tag for class draws: priorities come from their own stream
/// so changing the mix never perturbs arrivals, masks, or seeds.
const CLASS_STREAM: u64 = 0x636c_6173; // "clas"

/// RNG stream tag for session-trace base draws (templates, first-round
/// masks).
const SESSION_STREAM: u64 = 0x7365_7373; // "sess"

/// RNG stream tag for session mask-drift draws: the drift coin and every
/// drifted mask come from their own stream, so changing `--mask-drift`
/// never perturbs which template a session pins or its first-round mask.
const DRIFT_STREAM: u64 = 0x6472_6966; // "drif"

/// Mask-ratio distribution family (paper Fig. 3).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum MaskDist {
    /// Production face-swap trace: mean 0.11, long right tail.
    Production,
    /// Public trace [37]: mean 0.19.
    PublicTrace,
    /// VITON-HD virtual try-on benchmark: mean 0.35.
    VitonHD,
    /// Degenerate (kernel-sweep benches).
    Fixed(f64),
    /// Uniform in [lo, hi] (ablation stress).
    Uniform(f64, f64),
}

impl MaskDist {
    pub fn parse(s: &str) -> Option<MaskDist> {
        match s {
            "production" => Some(MaskDist::Production),
            "public" => Some(MaskDist::PublicTrace),
            "viton" => Some(MaskDist::VitonHD),
            other => other.parse::<f64>().ok().map(MaskDist::Fixed),
        }
    }

    /// Beta parameters matching the trace mean + skew.
    fn beta_params(&self) -> Option<(f64, f64)> {
        match self {
            MaskDist::Production => Some((1.1, 8.9)),  // mean 0.110
            MaskDist::PublicTrace => Some((1.3, 5.54)), // mean 0.190
            MaskDist::VitonHD => Some((2.2, 4.086)),    // mean 0.350
            _ => None,
        }
    }

    /// Nominal mean of the distribution.
    pub fn mean(&self) -> f64 {
        match self {
            MaskDist::Fixed(m) => *m,
            MaskDist::Uniform(lo, hi) => 0.5 * (lo + hi),
            d => {
                let (a, b) = d.beta_params().unwrap();
                a / (a + b)
            }
        }
    }

    /// Sample a mask ratio in (0, 1].
    pub fn sample(&self, rng: &mut Pcg) -> f64 {
        let r = match self {
            MaskDist::Fixed(m) => *m,
            MaskDist::Uniform(lo, hi) => rng.range_f64(*lo, *hi),
            d => {
                let (a, b) = d.beta_params().unwrap();
                rng.beta(a, b)
            }
        };
        r.clamp(1e-3, 1.0)
    }
}

/// Template-popularity law: maps one uniform draw `z` in [0, 1) to a
/// template index, so swapping the law (or the template count) consumes
/// the same number of RNG draws and never perturbs the rest of the trace.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Popularity {
    /// Legacy quadratic skew (`(n·z²) mod n`) — the default; byte-
    /// identical to traces generated before popularity was parameterized.
    Quadratic,
    /// Zipf with exponent `s` (template 0 hottest), via the closed-form
    /// inverse CDF of the continuous Zipf approximation — O(1) per draw,
    /// no per-template tables, so it scales to 10⁶ templates.
    Zipf { s: f64 },
}

impl Popularity {
    /// Parse `"quadratic"` or `"zipf:<s>"` (e.g. `zipf:1.1`).
    pub fn parse(text: &str) -> Option<Popularity> {
        if text == "quadratic" {
            return Some(Popularity::Quadratic);
        }
        let s = text.strip_prefix("zipf:")?.parse::<f64>().ok()?;
        if !s.is_finite() || s < 0.0 {
            return None;
        }
        Some(Popularity::Zipf { s })
    }

    /// Template index for a uniform draw `z` in [0, 1) over `n` templates.
    pub fn index(&self, z: f64, n: usize) -> usize {
        match *self {
            Popularity::Quadratic => ((n as f64) * z * z) as usize % n,
            Popularity::Zipf { s } => {
                // invert F(k) = (k^(1-s) - 1) / (n^(1-s) - 1); s → 1
                // degenerates to F(k) = ln k / ln n, i.e. k = n^z
                let nf = n as f64;
                let k = if (s - 1.0).abs() < 1e-9 {
                    nf.powf(z)
                } else {
                    let a = 1.0 - s;
                    ((nf.powf(a) - 1.0) * z + 1.0).powf(1.0 / a)
                };
                (k.floor() as usize).clamp(1, n) - 1
            }
        }
    }
}

/// Arrival-rate shape: a cumulative intensity Λ the homogeneous Poisson
/// arrivals are warped through (time-rescaling). The homogeneous trace's
/// event at time `t` carries Λ-coordinate `rps·t`; the shaped arrival is
/// `Λ⁻¹(rps·t)`. [`ArrivalShape::Steady`] is the exact identity.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalShape {
    /// Constant rate (legacy behaviour).
    Steady,
    /// Sinusoidal rate `rps·(1 + depth·sin(2πt/period))`; `depth` in
    /// [0, 1) keeps the rate positive (Λ strictly increasing).
    Diurnal { period_secs: f64, depth: f64 },
    /// Periodic storms: rate `rps·(1 + amplitude)` during the first
    /// `width` fraction of each period, `rps` otherwise.
    Bursts { period_secs: f64, width: f64, amplitude: f64 },
}

impl ArrivalShape {
    /// Parse `"steady"`, `"diurnal:<period>:<depth>"`, or
    /// `"bursts:<period>:<width>:<amplitude>"`.
    pub fn parse(text: &str) -> Option<ArrivalShape> {
        if text == "steady" {
            return Some(ArrivalShape::Steady);
        }
        let parts: Vec<&str> = text.split(':').collect();
        let nums: Option<Vec<f64>> =
            parts[1..].iter().map(|p| p.parse::<f64>().ok()).collect();
        match (parts[0], nums?.as_slice()) {
            ("diurnal", [period, depth])
                if *period > 0.0 && (0.0..1.0).contains(depth) =>
            {
                Some(ArrivalShape::Diurnal { period_secs: *period, depth: *depth })
            }
            ("bursts", [period, width, amplitude])
                if *period > 0.0 && (0.0..=1.0).contains(width) && *amplitude >= 0.0 =>
            {
                Some(ArrivalShape::Bursts {
                    period_secs: *period,
                    width: *width,
                    amplitude: *amplitude,
                })
            }
            _ => None,
        }
    }

    /// Cumulative expected arrivals Λ(t) at base rate `rps`.
    pub fn cumulative(&self, rps: f64, t: f64) -> f64 {
        match *self {
            ArrivalShape::Steady => rps * t,
            ArrivalShape::Diurnal { period_secs, depth } => {
                // ∫₀ᵗ rps·(1 + depth·sin(2πu/P)) du
                let omega = std::f64::consts::TAU / period_secs;
                rps * (t + depth / omega * (1.0 - (omega * t).cos()))
            }
            ArrivalShape::Bursts { period_secs, width, amplitude } => {
                let burst_len = width * period_secs;
                let whole = (t / period_secs).floor();
                let frac = t - whole * period_secs;
                let in_burst = whole * burst_len + frac.min(burst_len);
                rps * (t + amplitude * in_burst)
            }
        }
    }

    /// Map a homogeneous arrival time `t` (rate `rps`) to the shaped
    /// timeline: solves Λ(x) = rps·t by bisection. Since every shape has
    /// rate ≥ rps·(something) with Λ(x) ≥ rps·x for the shapes above
    /// (the extra terms are non-negative), the solution lies in [0, t].
    pub fn warp(&self, rps: f64, t: f64) -> f64 {
        if matches!(self, ArrivalShape::Steady) || t <= 0.0 {
            return t; // exact identity: legacy traces stay byte-identical
        }
        let target = rps * t;
        let (mut lo, mut hi) = (0.0_f64, t);
        for _ in 0..200 {
            let mid = 0.5 * (lo + hi);
            if mid <= lo || mid >= hi {
                break; // interval exhausted at f64 precision
            }
            if self.cumulative(rps, mid) < target {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        0.5 * (lo + hi)
    }
}

/// Request-class mix: weights over (interactive, standard, batch),
/// e.g. `--class-mix 0.2,0.5,0.3`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClassMix {
    pub weights: [f64; CLASS_COUNT],
}

impl ClassMix {
    /// Everything `Standard` (the pre-QoS behaviour).
    pub fn all_standard() -> ClassMix {
        ClassMix { weights: [0.0, 1.0, 0.0] }
    }

    /// Parse `"0.2,0.5,0.3"` (interactive, standard, batch). Weights are
    /// relative (they need not sum to 1); negatives and all-zero reject.
    pub fn parse(s: &str) -> Option<ClassMix> {
        let parts: Vec<f64> = s
            .split(',')
            .map(|p| p.trim().parse::<f64>().ok())
            .collect::<Option<Vec<f64>>>()?;
        if parts.len() != CLASS_COUNT {
            return None;
        }
        let weights = [parts[0], parts[1], parts[2]];
        if weights.iter().any(|w| !w.is_finite() || *w < 0.0) {
            return None;
        }
        if weights.iter().sum::<f64>() <= 0.0 {
            return None;
        }
        Some(ClassMix { weights })
    }

    /// Draw a class proportional to the weights.
    pub fn sample(&self, rng: &mut Pcg) -> Priority {
        let total: f64 = self.weights.iter().sum();
        let mut x = rng.f64() * total;
        for p in Priority::ALL {
            x -= self.weights[p.rank()];
            if x < 0.0 {
                return p;
            }
        }
        Priority::Batch
    }
}

/// One generated request event.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    pub id: u64,
    /// Arrival offset from trace start, seconds.
    pub at: f64,
    pub template: String,
    pub mask_ratio: f64,
    pub prompt_seed: u64,
    /// Request class (QoS; `Standard` for legacy traces).
    pub priority: Priority,
    /// Optional completion deadline, ms after submission.
    pub deadline_ms: Option<u64>,
}

impl TraceEvent {
    /// Realize the mask on a given latent grid (deterministic per event).
    pub fn mask(&self, latent_hw: usize) -> MaskSpec {
        let mut rng = Pcg::with_stream(self.prompt_seed, 0x6d61_736b);
        MaskSpec::synth(latent_hw, self.mask_ratio, &mut rng)
    }
}

/// Poisson request-trace generator.
#[derive(Debug, Clone)]
pub struct TraceGen {
    pub rps: f64,
    pub dist: MaskDist,
    pub templates: usize,
    pub seed: u64,
    /// Request-class mix (all-`Standard` by default).
    pub mix: ClassMix,
    /// Per-class deadline defaults, ms (None = no deadline), indexed by
    /// [`Priority::rank`].
    pub deadlines_ms: [Option<u64>; CLASS_COUNT],
    /// Template-popularity law (legacy quadratic skew by default).
    pub popularity: Popularity,
    /// Arrival-rate shape (steady by default).
    pub shape: ArrivalShape,
}

impl TraceGen {
    pub fn new(rps: f64, dist: MaskDist, templates: usize, seed: u64) -> TraceGen {
        assert!(rps > 0.0 && templates > 0);
        TraceGen {
            rps,
            dist,
            templates,
            seed,
            mix: ClassMix::all_standard(),
            deadlines_ms: [None; CLASS_COUNT],
            popularity: Popularity::Quadratic,
            shape: ArrivalShape::Steady,
        }
    }

    /// Zipf(`s`) template popularity (tentpole: million-template sweeps).
    pub fn with_zipf(self, s: f64) -> TraceGen {
        self.with_popularity(Popularity::Zipf { s })
    }

    pub fn with_popularity(mut self, popularity: Popularity) -> TraceGen {
        self.popularity = popularity;
        self
    }

    /// Warp arrivals through a non-constant rate shape.
    pub fn with_shape(mut self, shape: ArrivalShape) -> TraceGen {
        self.shape = shape;
        self
    }

    /// Mixed-priority traffic (satellite: `--class-mix 0.2,0.5,0.3`).
    pub fn with_mix(mut self, mix: ClassMix) -> TraceGen {
        self.mix = mix;
        self
    }

    /// Attach per-class deadlines to generated events.
    pub fn with_deadlines(mut self, deadlines_ms: [Option<u64>; CLASS_COUNT]) -> TraceGen {
        self.deadlines_ms = deadlines_ms;
        self
    }

    /// Generate `count` events with Poisson inter-arrivals.
    pub fn generate(&self, count: usize) -> Vec<TraceEvent> {
        let mut rng = Pcg::new(self.seed);
        // separate stream: the mix never perturbs arrivals/masks/seeds
        let mut crng = Pcg::with_stream(self.seed, CLASS_STREAM);
        let mut t = 0.0;
        (0..count)
            .map(|i| {
                t += rng.exponential(self.rps);
                // skewed template popularity: template 0 is hottest; one
                // uniform draw regardless of law or template count
                let z = rng.f64();
                let tpl = self.popularity.index(z, self.templates);
                let priority = self.mix.sample(&mut crng);
                TraceEvent {
                    id: i as u64,
                    at: self.shape.warp(self.rps, t),
                    template: format!("tpl-{tpl}"),
                    mask_ratio: self.dist.sample(&mut rng),
                    prompt_seed: rng.next_u64() >> 12, // 52 bits: JSON f64-exact
                    priority,
                    deadline_ms: self.deadlines_ms[priority.rank()],
                }
            })
            .collect()
    }

    /// Distinct template ids used by this generator.
    pub fn template_ids(&self) -> Vec<String> {
        (0..self.templates).map(|i| format!("tpl-{i}")).collect()
    }
}

// -- interactive-session workload ---------------------------------------------

/// One round of a scripted editing session.
#[derive(Debug, Clone, PartialEq)]
pub struct SessionRound {
    /// 1-based round index.
    pub round: u64,
    pub mask_ratio: f64,
    pub prompt_seed: u64,
    /// Whether the mask drifted from the previous round's (round 1 never
    /// drifts — there is nothing to reuse yet). An undrifted round keeps
    /// the previous `(mask_ratio, prompt_seed)` verbatim, so its
    /// synthesized mask is bit-identical and the session plane classifies
    /// it *warm*.
    pub drifted: bool,
}

/// One scripted editing session: a pinned template plus an ordered round
/// sequence.
#[derive(Debug, Clone, PartialEq)]
pub struct SessionScript {
    /// Generator-local session index (frontends allocate the real ids).
    pub session: u64,
    pub template: String,
    pub rounds: Vec<SessionRound>,
}

/// Interactive-session workload generator (`--sessions N
/// --rounds-per-session K --mask-drift p`): each session pins one
/// popularity-drawn template and iterates K rounds; each round after the
/// first redraws its mask with probability `p` and otherwise repeats the
/// previous mask exactly (the steady-state the delta-mask reuse path is
/// built for).
#[derive(Debug, Clone)]
pub struct SessionGen {
    pub sessions: usize,
    pub rounds_per_session: usize,
    /// Per-round probability in [0, 1] that the mask drifts.
    pub mask_drift: f64,
    pub dist: MaskDist,
    pub templates: usize,
    pub seed: u64,
    /// Template-popularity law (legacy quadratic skew by default).
    pub popularity: Popularity,
}

impl SessionGen {
    pub fn new(
        sessions: usize,
        rounds_per_session: usize,
        mask_drift: f64,
        dist: MaskDist,
        templates: usize,
        seed: u64,
    ) -> SessionGen {
        assert!(sessions > 0 && rounds_per_session > 0 && templates > 0);
        assert!((0.0..=1.0).contains(&mask_drift));
        SessionGen {
            sessions,
            rounds_per_session,
            mask_drift,
            dist,
            templates,
            seed,
            popularity: Popularity::Quadratic,
        }
    }

    pub fn with_popularity(mut self, popularity: Popularity) -> SessionGen {
        self.popularity = popularity;
        self
    }

    /// Generate the session scripts. Base draws (template, first-round
    /// mask) and drift draws (coin + redrawn masks) use separate RNG
    /// streams, so sweeping `mask_drift` leaves the pinned templates and
    /// first rounds untouched.
    pub fn generate(&self) -> Vec<SessionScript> {
        let mut rng = Pcg::with_stream(self.seed, SESSION_STREAM);
        let mut drng = Pcg::with_stream(self.seed, DRIFT_STREAM);
        (0..self.sessions)
            .map(|s| {
                let z = rng.f64();
                let tpl = self.popularity.index(z, self.templates);
                let mut ratio = self.dist.sample(&mut rng);
                let mut seed = rng.next_u64() >> 12; // 52 bits: JSON f64-exact
                let rounds = (0..self.rounds_per_session)
                    .map(|r| {
                        let drifted = r > 0 && drng.f64() < self.mask_drift;
                        if drifted {
                            ratio = self.dist.sample(&mut drng);
                            seed = drng.next_u64() >> 12;
                        }
                        SessionRound {
                            round: r as u64 + 1,
                            mask_ratio: ratio,
                            prompt_seed: seed,
                            drifted,
                        }
                    })
                    .collect();
                SessionScript { session: s as u64, template: format!("tpl-{tpl}"), rounds }
            })
            .collect()
    }

    /// Distinct template ids used by this generator.
    pub fn template_ids(&self) -> Vec<String> {
        (0..self.templates).map(|i| format!("tpl-{i}")).collect()
    }
}

/// Replay helper: sleep until each event is due, then hand it off.
pub fn replay<F: FnMut(&TraceEvent)>(events: &[TraceEvent], mut submit: F) {
    let start = std::time::Instant::now();
    for ev in events {
        let due = Duration::from_secs_f64(ev.at);
        let now = start.elapsed();
        if due > now {
            std::thread::sleep(due - now);
        }
        submit(ev);
    }
}

// -- JSONL trace record/replay ------------------------------------------------

pub fn to_jsonl(events: &[TraceEvent]) -> String {
    let mut out = String::new();
    for e in events {
        let mut pairs = vec![
            ("id", Json::num(e.id as f64)),
            ("at", Json::num(e.at)),
            ("template", Json::str(e.template.clone())),
            ("mask_ratio", Json::num(e.mask_ratio)),
            ("prompt_seed", Json::num(e.prompt_seed as f64)),
            ("priority", Json::str(e.priority.label())),
        ];
        if let Some(ms) = e.deadline_ms {
            pairs.push(("deadline_ms", Json::num(ms as f64)));
        }
        out.push_str(&Json::obj(pairs).to_string());
        out.push('\n');
    }
    out
}

pub fn from_jsonl(text: &str) -> anyhow::Result<Vec<TraceEvent>> {
    text.lines()
        .filter(|l| !l.trim().is_empty())
        .map(|l| {
            let j = Json::parse(l)?;
            Ok(TraceEvent {
                id: j.at("id").as_f64().unwrap_or(0.0) as u64,
                at: j.at("at").as_f64().unwrap_or(0.0),
                template: j.at("template").as_str().unwrap_or("tpl-0").to_string(),
                mask_ratio: j.at("mask_ratio").as_f64().unwrap_or(0.1),
                prompt_seed: j.at("prompt_seed").as_f64().unwrap_or(0.0) as u64,
                // legacy traces (no class field) default to Standard
                priority: j
                    .at("priority")
                    .as_str()
                    .and_then(Priority::parse)
                    .unwrap_or_default(),
                deadline_ms: j.at("deadline_ms").as_f64().map(|ms| ms as u64),
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::mean;

    #[test]
    fn beta_means_match_paper_fig3() {
        let mut rng = Pcg::new(1);
        for (dist, want) in [
            (MaskDist::Production, 0.11),
            (MaskDist::PublicTrace, 0.19),
            (MaskDist::VitonHD, 0.35),
        ] {
            let xs: Vec<f64> = (0..30_000).map(|_| dist.sample(&mut rng)).collect();
            let m = mean(&xs);
            assert!((m - want).abs() < 0.01, "{dist:?} mean {m} want {want}");
            assert!(xs.iter().all(|&x| (0.0..=1.0).contains(&x)));
        }
    }

    #[test]
    fn production_is_right_skewed() {
        let mut rng = Pcg::new(2);
        let d = MaskDist::Production;
        let xs: Vec<f64> = (0..20_000).map(|_| d.sample(&mut rng)).collect();
        let mut sorted = xs.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = sorted[sorted.len() / 2];
        assert!(median < mean(&xs), "right skew: median {median} < mean");
    }

    #[test]
    fn poisson_interarrival_rate() {
        let g = TraceGen::new(4.0, MaskDist::Fixed(0.1), 4, 7);
        let ev = g.generate(8_000);
        let total = ev.last().unwrap().at;
        let rate = ev.len() as f64 / total;
        assert!((rate - 4.0).abs() < 0.2, "rate {rate}");
        // arrival times strictly increase
        assert!(ev.windows(2).all(|w| w[0].at < w[1].at));
    }

    #[test]
    fn trace_is_seed_deterministic() {
        let a = TraceGen::new(1.0, MaskDist::Production, 8, 42).generate(100);
        let b = TraceGen::new(1.0, MaskDist::Production, 8, 42).generate(100);
        assert_eq!(a, b);
    }

    #[test]
    fn template_popularity_is_skewed() {
        let g = TraceGen::new(1.0, MaskDist::Fixed(0.1), 10, 3);
        let ev = g.generate(10_000);
        let mut counts = vec![0usize; 10];
        for e in &ev {
            let idx: usize = e.template[4..].parse().unwrap();
            counts[idx] += 1;
        }
        // hottest template should far exceed the uniform share
        let max = *counts.iter().max().unwrap();
        assert!(max > 2 * ev.len() / 10, "not skewed: {counts:?}");
    }

    #[test]
    fn jsonl_round_trip() {
        let g = TraceGen::new(2.0, MaskDist::PublicTrace, 4, 5)
            .with_mix(ClassMix::parse("0.2,0.5,0.3").unwrap())
            .with_deadlines([Some(1_500), None, None]);
        let ev = g.generate(50);
        let text = to_jsonl(&ev);
        let back = from_jsonl(&text).unwrap();
        assert_eq!(ev, back);
        // legacy lines without a class field default to Standard
        let legacy = r#"{"id":1,"at":0.5,"template":"tpl-0","mask_ratio":0.2,"prompt_seed":9}"#;
        let back = from_jsonl(legacy).unwrap();
        assert_eq!(back[0].priority, Priority::Standard);
        assert_eq!(back[0].deadline_ms, None);
    }

    #[test]
    fn class_mix_parses_and_samples_proportionally() {
        assert_eq!(ClassMix::parse("nope"), None);
        assert_eq!(ClassMix::parse("0.2,0.5"), None);
        assert_eq!(ClassMix::parse("-0.1,0.5,0.6"), None);
        assert_eq!(ClassMix::parse("0,0,0"), None);
        assert_eq!(ClassMix::parse("nan,1,1"), None, "NaN weights must reject");
        assert_eq!(ClassMix::parse("inf,1,1"), None);
        let mix = ClassMix::parse("0.2,0.5,0.3").unwrap();
        let mut rng = Pcg::new(11);
        let mut counts = [0usize; CLASS_COUNT];
        let n = 20_000;
        for _ in 0..n {
            counts[mix.sample(&mut rng).rank()] += 1;
        }
        for (p, want) in Priority::ALL.iter().zip([0.2, 0.5, 0.3]) {
            let got = counts[p.rank()] as f64 / n as f64;
            assert!((got - want).abs() < 0.02, "{p:?}: got {got}, want {want}");
        }
        // degenerate mix: everything is standard
        let std_only = ClassMix::all_standard();
        for _ in 0..100 {
            assert_eq!(std_only.sample(&mut rng), Priority::Standard);
        }
    }

    #[test]
    fn class_mix_does_not_perturb_arrivals_or_masks() {
        let base = TraceGen::new(2.0, MaskDist::Production, 4, 7).generate(200);
        let mixed = TraceGen::new(2.0, MaskDist::Production, 4, 7)
            .with_mix(ClassMix::parse("1,1,1").unwrap())
            .generate(200);
        for (a, b) in base.iter().zip(&mixed) {
            assert_eq!(a.at, b.at, "arrivals must be identical across mixes");
            assert_eq!(a.mask_ratio, b.mask_ratio);
            assert_eq!(a.prompt_seed, b.prompt_seed);
            assert_eq!(a.template, b.template);
        }
        // and the mixed trace actually contains several classes
        let interactive = mixed.iter().filter(|e| e.priority == Priority::Interactive);
        assert!(interactive.count() > 0);
        // class draws are seed-deterministic too
        let again = TraceGen::new(2.0, MaskDist::Production, 4, 7)
            .with_mix(ClassMix::parse("1,1,1").unwrap())
            .generate(200);
        assert_eq!(mixed, again);
    }

    #[test]
    fn popularity_parses() {
        assert_eq!(Popularity::parse("quadratic"), Some(Popularity::Quadratic));
        assert_eq!(Popularity::parse("zipf:1.1"), Some(Popularity::Zipf { s: 1.1 }));
        assert_eq!(Popularity::parse("zipf:-1"), None);
        assert_eq!(Popularity::parse("zipf:nan"), None);
        assert_eq!(Popularity::parse("zip"), None);
        assert_eq!(ArrivalShape::parse("steady"), Some(ArrivalShape::Steady));
        assert_eq!(
            ArrivalShape::parse("diurnal:60:0.8"),
            Some(ArrivalShape::Diurnal { period_secs: 60.0, depth: 0.8 })
        );
        assert_eq!(ArrivalShape::parse("diurnal:60:1.5"), None, "depth must be < 1");
        assert_eq!(
            ArrivalShape::parse("bursts:10:0.1:9"),
            Some(ArrivalShape::Bursts { period_secs: 10.0, width: 0.1, amplitude: 9.0 })
        );
        assert_eq!(ArrivalShape::parse("bursts:10:2:9"), None, "width must be <= 1");
        assert_eq!(ArrivalShape::parse("diurnal"), None);
    }

    #[test]
    fn legacy_default_popularity_is_byte_identical() {
        // the parameterized draw with default knobs must reproduce the
        // pre-parameterization trace exactly
        let g = TraceGen::new(2.0, MaskDist::Production, 10, 42);
        assert_eq!(g.popularity, Popularity::Quadratic);
        assert_eq!(g.shape, ArrivalShape::Steady);
        let mut rng = Pcg::new(7);
        for _ in 0..10_000 {
            let z = rng.f64();
            let legacy = (10.0 * z * z) as usize % 10;
            assert_eq!(Popularity::Quadratic.index(z, 10), legacy);
        }
        assert_eq!(ArrivalShape::Steady.warp(3.0, 1.25), 1.25, "steady warp is exact");
    }

    #[test]
    fn zipf_skew_matches_exponent() {
        // empirical CDF at the decile must match the closed-form Zipf CDF
        // F(k) = (k^(1-s) - 1) / (n^(1-s) - 1) for the exponent used
        let n = 1_000usize;
        for s in [0.8, 1.3] {
            let ev = TraceGen::new(5.0, MaskDist::Fixed(0.1), n, 9)
                .with_zipf(s)
                .generate(50_000);
            let m = n / 10;
            let got = ev
                .iter()
                .filter(|e| e.template[4..].parse::<usize>().unwrap() < m)
                .count() as f64
                / ev.len() as f64;
            let a = 1.0 - s;
            let want = ((m as f64).powf(a) - 1.0) / ((n as f64).powf(a) - 1.0);
            assert!((got - want).abs() < 0.02, "s={s}: got {got}, want {want}");
        }
        // larger s concentrates more mass on the head
        let head_share = |s: f64| {
            let ev = TraceGen::new(5.0, MaskDist::Fixed(0.1), n, 9).with_zipf(s).generate(20_000);
            ev.iter()
                .filter(|e| e.template[4..].parse::<usize>().unwrap() < 10)
                .count()
        };
        assert!(head_share(1.4) > head_share(0.8));
    }

    #[test]
    fn arrivals_unperturbed_by_template_count_or_popularity() {
        // satellite property: scaling templates 100 → 10⁶ (or swapping
        // the popularity law) must leave arrivals, masks, and prompt
        // seeds untouched — the draw count per event is invariant
        let small = TraceGen::new(2.0, MaskDist::Production, 100, 11).with_zipf(1.1).generate(500);
        let huge = TraceGen::new(2.0, MaskDist::Production, 1_000_000, 11)
            .with_zipf(1.1)
            .generate(500);
        let legacy = TraceGen::new(2.0, MaskDist::Production, 100, 11).generate(500);
        for ((a, b), c) in small.iter().zip(&huge).zip(&legacy) {
            assert_eq!(a.at, b.at);
            assert_eq!(a.at, c.at, "popularity law must not perturb arrivals");
            assert_eq!(a.mask_ratio, b.mask_ratio);
            assert_eq!(a.mask_ratio, c.mask_ratio);
            assert_eq!(a.prompt_seed, b.prompt_seed);
            assert_eq!(a.prompt_seed, c.prompt_seed);
        }
        // and the huge trace actually uses deep-tail templates
        assert!(huge
            .iter()
            .any(|e| e.template[4..].parse::<usize>().unwrap() >= 100));
    }

    #[test]
    fn diurnal_warp_preserves_order_and_mean_rate() {
        let shape = ArrivalShape::Diurnal { period_secs: 60.0, depth: 0.8 };
        let ev = TraceGen::new(4.0, MaskDist::Fixed(0.1), 4, 13).with_shape(shape).generate(8_000);
        assert!(ev.windows(2).all(|w| w[0].at < w[1].at), "warp must preserve order");
        let rate = ev.len() as f64 / ev.last().unwrap().at;
        assert!((rate - 4.0).abs() < 0.4, "long-run mean rate ~rps, got {rate}");
        // arrivals pile up near the sine peak (phase ≈ P/4) vs the trough
        let phase = |t: f64| (t / 60.0).fract();
        let peak = ev.iter().filter(|e| (0.15..0.35).contains(&phase(e.at))).count();
        let trough = ev.iter().filter(|e| (0.65..0.85).contains(&phase(e.at))).count();
        assert!(peak as f64 > 1.5 * trough as f64, "peak {peak} vs trough {trough}");
    }

    #[test]
    fn burst_storms_concentrate_arrivals() {
        let shape = ArrivalShape::Bursts { period_secs: 10.0, width: 0.1, amplitude: 9.0 };
        let ev = TraceGen::new(2.0, MaskDist::Fixed(0.1), 4, 17).with_shape(shape).generate(4_000);
        assert!(ev.windows(2).all(|w| w[0].at < w[1].at));
        // storms carry rate 10·rps over 10% of each period → expected
        // in-burst share = 1.0/1.9 ≈ 0.53 (vs 0.10 for steady traffic)
        let in_burst =
            ev.iter().filter(|e| (e.at / 10.0).fract() < 0.1).count() as f64 / ev.len() as f64;
        assert!(in_burst > 0.35, "in-burst share {in_burst}");
    }

    #[test]
    fn session_gen_drift_controls_round_reuse() {
        // drift 0: every round repeats round 1's mask exactly
        let frozen = SessionGen::new(4, 5, 0.0, MaskDist::Production, 8, 21).generate();
        assert_eq!(frozen.len(), 4);
        for s in &frozen {
            assert_eq!(s.rounds.len(), 5);
            assert_eq!(s.rounds[0].round, 1);
            assert!(!s.rounds[0].drifted, "round 1 never drifts");
            for r in &s.rounds[1..] {
                assert!(!r.drifted);
                assert_eq!(r.mask_ratio, s.rounds[0].mask_ratio);
                assert_eq!(r.prompt_seed, s.rounds[0].prompt_seed);
            }
        }
        // drift 1: every round after the first redraws
        let churn = SessionGen::new(4, 5, 1.0, MaskDist::Production, 8, 21).generate();
        for s in &churn {
            for w in s.rounds.windows(2) {
                assert!(w[1].drifted);
                assert_ne!(w[0].prompt_seed, w[1].prompt_seed);
            }
        }
        // deterministic per seed
        let again = SessionGen::new(4, 5, 1.0, MaskDist::Production, 8, 21).generate();
        assert_eq!(churn, again);
    }

    #[test]
    fn session_drift_stream_is_isolated() {
        // sweeping --mask-drift must not perturb pinned templates or
        // first-round masks (they come from the base stream)
        let a = SessionGen::new(6, 4, 0.0, MaskDist::Production, 16, 33).generate();
        let b = SessionGen::new(6, 4, 0.7, MaskDist::Production, 16, 33).generate();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.template, y.template, "drift must not re-pin templates");
            assert_eq!(x.rounds[0], y.rounds[0], "round 1 is drift-invariant");
        }
        // and the drifted variant actually drifted somewhere
        assert!(b.iter().any(|s| s.rounds.iter().any(|r| r.drifted)));
        // an undrifted round realizes a bit-identical mask (the warm
        // invariant the session plane's delta check relies on)
        let s = &a[0];
        let ev = |r: &SessionRound| TraceEvent {
            id: 0,
            at: 0.0,
            template: s.template.clone(),
            mask_ratio: r.mask_ratio,
            prompt_seed: r.prompt_seed,
            priority: Priority::Interactive,
            deadline_ms: None,
        };
        assert_eq!(ev(&s.rounds[0]).mask(8), ev(&s.rounds[1]).mask(8));
    }

    #[test]
    fn event_mask_is_deterministic() {
        let e = TraceEvent {
            id: 1,
            at: 0.0,
            template: "tpl-0".into(),
            mask_ratio: 0.2,
            prompt_seed: 99,
            priority: Priority::Standard,
            deadline_ms: None,
        };
        assert_eq!(e.mask(8), e.mask(8));
        let got = e.mask(8).ratio();
        assert!((got - 0.2).abs() < 0.1);
    }
}
