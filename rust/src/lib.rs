//! # InstGenIE — mask-aware generative image-editing serving
//!
//! A reproduction of *"InstGenIE: Generative Image Editing Made Efficient
//! with Mask-aware Caching and Scheduling"* as a three-layer Rust + JAX +
//! Pallas system: this crate is the Layer-3 coordinator, executing
//! AOT-lowered XLA programs (Layer 2 model / Layer 1 Pallas kernels, built
//! by `python/compile/`) through the PJRT C API.
//!
//! Key subsystems (paper section in parentheses):
//! - [`runtime`]: PJRT client, artifact registry, block executor.
//! - [`model`]: masks, latents, masked-first permutation, noise schedule.
//! - [`cache`]: activation store, tiered storage, loader stream, the
//!   bubble-free pipeline DP (§4.2, Algo 1), latency regressions (§4.4).
//! - [`engine`]: worker step loop, continuous batching + disaggregated
//!   pre/post-processing (§4.3), baseline modes (Diffusers / FISEdit /
//!   TeaCache).
//! - [`scheduler`]: mask-aware load balancing (§4.4, Algo 2) with a
//!   cache-load penalty, plus residency-first (`cache-aware`), class-aware
//!   (`qos-aware`) and blind baselines.
//! - [`qos`]: quality of service — `Priority` classes with aging credit,
//!   per-request deadlines, and the deadline-aware `AdmissionController`
//!   that sheds over-capacity work with a retry estimate (429) instead of
//!   growing queues unboundedly.
//! - [`templates`]: the cluster-wide online template lifecycle —
//!   `TemplateRegistry` owns the authoritative template set (registering
//!   → ready → retired), in-flight reference counts, and registration
//!   epochs; per-worker residency lives in each worker's tier.
//! - [`cluster`]: multi-worker deployment glue and the handle-based
//!   request lifecycle — `Cluster::submit` returns an `EditTicket`
//!   resolved per-id by the collector (`cluster::lifecycle`), with typed
//!   `EditError`s, queued-request cancellation, and online template
//!   registration/retirement over per-worker cache tiers.
//! - [`dist`]: the distributed serving plane — a router process and N
//!   worker processes over a keep-alive HTTP/JSON RPC data plane, with
//!   membership/epochs, heartbeat failure detection, live drain, and
//!   queued-work failover (`WorkerLost` for in-flight casualties).
//! - [`durable`]: the durable control plane — a checksummed segmented
//!   write-ahead journal with snapshot compaction, crash-recovery replay,
//!   warm-standby journal tailing, step-boundary latent checkpoints, and
//!   bounded wire-id / idempotency-key dedupe.
//! - [`faults`]: deterministic fault injection (`--faults <spec>`) across
//!   storage / transport / engine, plus the degradation-ladder
//!   primitives: per-tier circuit breakers, router retry budgets with
//!   jittered backoff, and checksummed spill artifacts — cache faults
//!   demote device → host → disk → full recompute, never a request
//!   failure.
//! - [`session`]: the interactive session serving plane — session
//!   lifecycle + template pinning, sticky-affinity ownership with
//!   failover re-homing, delta-mask round reuse, and SSE progress
//!   streaming from per-round engine event buffers.
//! - [`workload`]: Fig.-3 mask-ratio distributions, Zipf/quadratic
//!   template popularity, diurnal / burst-storm arrival shaping, Poisson
//!   traffic, trace record/replay.
//! - [`metrics`], [`quality`], [`server`]: observability, image-quality
//!   metrics (Table 2), and the HTTP frontend (async `/v1/edits` submit /
//!   poll / cancel endpoints plus a synchronous `/edit` wrapper).
//! - [`util`]: in-tree substrates (RNG, JSON, stats, thread pool, bench
//!   harness, property testing) — see DESIGN.md "Offline-crate
//!   substitution".

pub mod cache;
pub mod cluster;
pub mod config;
pub mod dist;
pub mod durable;
pub mod engine;
pub mod faults;
pub mod metrics;
pub mod model;
pub mod qos;
pub mod quality;
pub mod runtime;
pub mod scheduler;
pub mod server;
pub mod session;
pub mod templates;
pub mod util;
pub mod workload;

/// Repository-relative default artifact directory.
pub const DEFAULT_ARTIFACT_DIR: &str = "artifacts";
