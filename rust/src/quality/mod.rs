//! Image-quality metrics (paper Table 2), adapted to the mini testbed.
//!
//! - **SSIM**: the standard windowed structural-similarity index over the
//!   decoded patch images (full implementation).
//! - **Fréchet feature distance**: the FID construction — Fréchet distance
//!   between Gaussian fits of two feature-vector sets — with our
//!   VAE-analogue encoder as the feature network and diagonal covariance
//!   (documented substitution: real FID uses InceptionV3 + full
//!   covariance).
//! - **Conditioning alignment**: CLIP-score analogue — cosine similarity
//!   between the output's pooled feature and the request's conditioning
//!   vector (both live in the model's hidden space).

use crate::util::tensor::Tensor;

/// Windowed SSIM between two images shaped (hw*hw, C), gridded to
/// hw x hw per channel. Returns the mean SSIM over windows and channels.
pub fn ssim(a: &Tensor, b: &Tensor, hw: usize, window: usize) -> f64 {
    assert_eq!(a.shape(), b.shape(), "ssim shape mismatch");
    let c = *a.shape().last().unwrap();
    assert_eq!(a.shape()[0], hw * hw, "ssim grid mismatch");
    let win = window.min(hw).max(1);
    // dynamic range of tanh-decoded images is [-1, 1] -> L = 2
    let (c1, c2) = ((0.01f64 * 2.0).powi(2), (0.03f64 * 2.0).powi(2));

    let mut total = 0.0;
    let mut count = 0usize;
    for ch in 0..c {
        let pix = |t: &Tensor, r: usize, col: usize| t.data()[(r * hw + col) * c + ch] as f64;
        for r0 in 0..=(hw - win) {
            for c0 in 0..=(hw - win) {
                let mut ma = 0.0;
                let mut mb = 0.0;
                let n = (win * win) as f64;
                for r in r0..r0 + win {
                    for cc in c0..c0 + win {
                        ma += pix(a, r, cc);
                        mb += pix(b, r, cc);
                    }
                }
                ma /= n;
                mb /= n;
                let (mut va, mut vb, mut cov) = (0.0, 0.0, 0.0);
                for r in r0..r0 + win {
                    for cc in c0..c0 + win {
                        let da = pix(a, r, cc) - ma;
                        let db = pix(b, r, cc) - mb;
                        va += da * da;
                        vb += db * db;
                        cov += da * db;
                    }
                }
                va /= n - 1.0;
                vb /= n - 1.0;
                cov /= n - 1.0;
                let s = ((2.0 * ma * mb + c1) * (2.0 * cov + c2))
                    / ((ma * ma + mb * mb + c1) * (va + vb + c2));
                total += s;
                count += 1;
            }
        }
    }
    total / count as f64
}

/// Fréchet distance between diagonal-Gaussian fits of two feature sets.
/// Lower = more similar (FID-style; 0 for identical sets).
pub fn frechet_distance(a: &[Vec<f32>], b: &[Vec<f32>]) -> f64 {
    assert!(!a.is_empty() && !b.is_empty());
    let d = a[0].len();
    let fit = |xs: &[Vec<f32>]| {
        let n = xs.len() as f64;
        let mut mu = vec![0.0f64; d];
        for x in xs {
            for (m, v) in mu.iter_mut().zip(x) {
                *m += *v as f64 / n;
            }
        }
        let mut var = vec![0.0f64; d];
        for x in xs {
            for i in 0..d {
                var[i] += (x[i] as f64 - mu[i]).powi(2) / n;
            }
        }
        (mu, var)
    };
    let (mu1, v1) = fit(a);
    let (mu2, v2) = fit(b);
    let mut dist = 0.0;
    for i in 0..d {
        dist += (mu1[i] - mu2[i]).powi(2);
        dist += v1[i] + v2[i] - 2.0 * (v1[i] * v2[i]).sqrt();
    }
    dist.max(0.0)
}

/// Pooled image feature: mean over tokens of (image @ encoder), living in
/// the model's hidden space (the feature net of our FID/CLIP analogues).
pub fn image_feature(image: &Tensor, encoder: &Tensor) -> Vec<f32> {
    let feat = image.matmul(encoder).expect("encoder shape");
    let (rows, h) = (feat.shape()[0], feat.shape()[1]);
    let mut pooled = vec![0f32; h];
    for r in 0..rows {
        for (p, v) in pooled.iter_mut().zip(feat.row(r)) {
            *p += v / rows as f32;
        }
    }
    pooled
}

/// CLIP-score analogue: cosine(pooled output feature, conditioning).
pub fn alignment_score(image: &Tensor, encoder: &Tensor, conditioning: &[f32]) -> f64 {
    let feat = image_feature(image, encoder);
    cosine(&feat, conditioning)
}

fn cosine(a: &[f32], b: &[f32]) -> f64 {
    let dot: f64 = a.iter().zip(b).map(|(x, y)| (*x as f64) * (*y as f64)).sum();
    let na: f64 = a.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
    let nb: f64 = b.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
    if na == 0.0 || nb == 0.0 {
        0.0
    } else {
        dot / (na * nb)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn img(hw: usize, c: usize, seed: u64) -> Tensor {
        let mut rng = Pcg::new(seed);
        let mut t = Tensor::zeros(&[hw * hw, c]);
        rng.fill_normal_f32(t.data_mut(), 0.4);
        t.map_inplace(|v| v.tanh());
        t
    }

    #[test]
    fn ssim_identity_is_one() {
        let a = img(8, 4, 1);
        let s = ssim(&a, &a, 8, 4);
        assert!((s - 1.0).abs() < 1e-9, "ssim {s}");
    }

    #[test]
    fn ssim_decreases_with_noise() {
        let a = img(8, 4, 1);
        let mut slight = a.clone();
        let mut rng = Pcg::new(2);
        slight.map_inplace(|v| v + 0.05 * rng.normal() as f32);
        let mut heavy = a.clone();
        heavy.map_inplace(|v| v + 0.5 * rng.normal() as f32);
        let s1 = ssim(&a, &slight, 8, 4);
        let s2 = ssim(&a, &heavy, 8, 4);
        assert!(s1 > s2, "slight {s1} heavy {s2}");
        assert!(s1 > 0.7 && s2 < s1);
    }

    #[test]
    fn ssim_symmetry() {
        let a = img(8, 4, 3);
        let b = img(8, 4, 4);
        assert!((ssim(&a, &b, 8, 4) - ssim(&b, &a, 8, 4)).abs() < 1e-12);
    }

    #[test]
    fn frechet_zero_for_identical_sets() {
        let set: Vec<Vec<f32>> = (0..20)
            .map(|i| {
                let mut rng = Pcg::new(i);
                (0..8).map(|_| rng.normal() as f32).collect()
            })
            .collect();
        assert!(frechet_distance(&set, &set) < 1e-9);
    }

    #[test]
    fn frechet_grows_with_mean_shift() {
        let base: Vec<Vec<f32>> = (0..50)
            .map(|i| {
                let mut rng = Pcg::new(i);
                (0..8).map(|_| rng.normal() as f32).collect()
            })
            .collect();
        let near: Vec<Vec<f32>> = base
            .iter()
            .map(|v| v.iter().map(|x| x + 0.1).collect())
            .collect();
        let far: Vec<Vec<f32>> = base
            .iter()
            .map(|v| v.iter().map(|x| x + 1.0).collect())
            .collect();
        let dn = frechet_distance(&base, &near);
        let df = frechet_distance(&base, &far);
        assert!(df > dn, "near {dn} far {df}");
    }

    #[test]
    fn alignment_favors_matching_conditioning() {
        let hw = 8;
        let c = 4;
        let h = 16;
        let mut rng = Pcg::new(9);
        let mut enc = Tensor::zeros(&[c, h]);
        rng.fill_normal_f32(enc.data_mut(), 0.5);
        let image = img(hw, c, 10);
        let feat = image_feature(&image, &enc);
        // conditioning equal to the feature scores ~1; random scores lower
        let aligned = alignment_score(&image, &enc, &feat);
        let mut other = vec![0f32; h];
        rng.fill_normal_f32(&mut other, 1.0);
        let misaligned = alignment_score(&image, &enc, &other);
        assert!((aligned - 1.0).abs() < 1e-6);
        assert!(misaligned < aligned);
    }
}
