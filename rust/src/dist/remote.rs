//! [`RemoteWorker`]: the router's handle to one worker process, speaking
//! the `/rpc/*` wire protocol over a keep-alive [`RpcClient`]. It mirrors
//! the surface the in-process worker exposes to the cluster — submit,
//! poll, cancel, template register/purge, snapshot, drain — so the
//! router's scheduler/admission/registry plumbing is backend-agnostic.

use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::engine::request::EditError;
use crate::faults::FaultInjector;
use crate::engine::worker::WorkerSnapshot;
use crate::util::json::Json;

use super::proto::{self, PollState, SubmitWire};
use super::rpc::{RpcClient, RpcError};

/// How a remote submit landed.
#[derive(Debug)]
pub enum SubmitOutcome {
    /// The worker queued the request.
    Accepted,
    /// Typed worker-side reject (template unknown/retired, draining,
    /// overload) — the router may route elsewhere or surface the error.
    Rejected(EditError),
    /// Transport failure: the worker is unreachable.
    Unreachable(RpcError),
}

pub struct RemoteWorker {
    name: String,
    addr: String,
    client: Mutex<RpcClient>,
}

impl RemoteWorker {
    pub fn new(name: impl Into<String>, addr: impl Into<String>, timeout: Duration) -> RemoteWorker {
        let addr = addr.into();
        RemoteWorker {
            name: name.into(),
            client: Mutex::new(RpcClient::new(addr.clone(), timeout)),
            addr,
        }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Attach a fault injector to the underlying RPC client (transport
    /// drops/delays/truncations per its seeded plan).
    pub fn with_faults(self, faults: Arc<FaultInjector>) -> RemoteWorker {
        self.client.lock().unwrap().set_faults(faults);
        self
    }

    fn call(&self, method: &str, path: &str, body: Option<&Json>) -> Result<(u16, Json), RpcError> {
        self.client.lock().unwrap().call(method, path, body)
    }

    /// Submit one edit.
    pub fn submit(&self, wire: &SubmitWire) -> SubmitOutcome {
        match self.call("POST", "/rpc/submit", Some(&wire.to_json())) {
            Ok((status, _)) if (200..300).contains(&status) => SubmitOutcome::Accepted,
            Ok((_, body)) => SubmitOutcome::Rejected(proto::decode_error(&body)),
            Err(e) => SubmitOutcome::Unreachable(e),
        }
    }

    /// Poll one request's remote state.
    pub fn poll(&self, id: u64) -> Result<PollState, RpcError> {
        let (_, body) = self.call("GET", &format!("/rpc/poll/{id}"), None)?;
        Ok(proto::poll_state_from_json(&body))
    }

    /// Cancel (or evict, if already terminal) one request.
    pub fn cancel(&self, id: u64) -> Result<(u16, Json), RpcError> {
        self.call("DELETE", &format!("/rpc/cancel/{id}"), None)
    }

    /// Drop a terminal request's retained result on the worker.
    pub fn evict(&self, id: u64) -> Result<(u16, Json), RpcError> {
        self.call("DELETE", &format!("/rpc/evict/{id}"), None)
    }

    /// The worker's live load snapshot.
    pub fn snapshot(&self) -> Result<WorkerSnapshot, RpcError> {
        let (_, body) = self.call("GET", "/rpc/snapshot", None)?;
        proto::snapshot_from_json(&body)
            .ok_or_else(|| RpcError::Proto("bad snapshot body".into()))
    }

    /// Kick off a background template registration on the worker.
    pub fn register_template(&self, template_id: &str) -> Result<(u16, Json), RpcError> {
        let body = Json::obj(vec![("template", Json::str(template_id))]);
        self.call("POST", "/rpc/template/register", Some(&body))
    }

    /// Retire/purge a template on the worker.
    pub fn purge_template(&self, template_id: &str) -> Result<(u16, Json), RpcError> {
        self.call("DELETE", &format!("/rpc/template/purge/{template_id}"), None)
    }

    /// Ask the worker to drain: finish held work, accept no more.
    pub fn drain(&self) -> Result<(u16, Json), RpcError> {
        self.call("POST", "/rpc/drain", None)
    }

    /// Liveness probe.
    pub fn health(&self) -> bool {
        matches!(self.call("GET", "/rpc/health", None), Ok((200, _)))
    }
}
