//! [`WorkerNode`]: one worker process of the distributed plane.
//!
//! A node wraps a **single-worker** [`Cluster`] — reusing the whole
//! engine/registry/template/cache stack unchanged — behind the `/rpc/*`
//! endpoints, served through the hardened
//! [`serve_connection`] loop (same slowloris limits as the public API
//! port). It announces itself to the router and heartbeats its
//! [`WorkerSnapshot`](crate::engine::worker::WorkerSnapshot) on the
//! configured cadence.
//!
//! Endpoints:
//!
//! | method & path                    | meaning                                  |
//! |----------------------------------|------------------------------------------|
//! | `POST /rpc/submit`               | queue one [`SubmitWire`] edit            |
//! | `GET /rpc/poll/{id}`             | request state (+ full result when done)  |
//! | `DELETE /rpc/cancel/{id}`        | cancel queued / evict terminal           |
//! | `DELETE /rpc/evict/{id}`         | drop a terminal result                   |
//! | `GET /rpc/snapshot`              | live load snapshot                       |
//! | `POST /rpc/template/register`    | background template registration         |
//! | `DELETE /rpc/template/purge/{id}`| retire + free the template               |
//! | `POST /rpc/drain`                | finish held work, accept no more         |
//! | `GET /rpc/health`                | liveness + accepting flag                |
//! | `GET /v1/healthz`                | liveness (alias of `/rpc/health`)        |
//! | `GET /v1/readyz`                 | readiness: 503 when draining/stopping    |
//!
//! Draining reuses the same semantics as template retirement: held work
//! drains to completion, new submissions get a typed 503 reject.

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::cluster::{CancelOutcome, Cluster, ClusterOpts, RequestState};
use crate::durable::BoundedDedupe;
use crate::scheduler::RoundRobin;
use crate::server::{edit_error_reply, error_obj, serve_connection};
use crate::templates::{RegisterAdmission, RetireOutcome};
use crate::util::json::Json;

use super::proto::{self, Announce, PollState, SubmitWire};
use super::rpc::RpcClient;
use super::DistConfig;

/// Wire-id dedupe window: ids remembered (count cap + TTL) after their
/// result was consumed and evicted, so a late duplicate submit — a
/// dropped ack retried, or a recovered router re-placing journaled work —
/// acks instead of recomputing.
const DEDUPE_CAP: usize = 4096;
const DEDUPE_TTL: Duration = Duration::from_secs(600);

/// Consecutive announce/heartbeat failures before the node rotates to the
/// next router address (primary -> standby and back).
const ROTATE_AFTER_MISSES: u32 = 3;

pub struct WorkerNode {
    name: String,
    cluster: Arc<Cluster>,
    /// New submissions accepted? Cleared by `/rpc/drain` and `stop`.
    accepting: AtomicBool,
    /// Process-wide stop: ends the accept and heartbeat loops.
    stopping: AtomicBool,
    /// Bound RPC address (set by [`WorkerNode::start`]).
    addr: Mutex<Option<SocketAddr>>,
    /// Bounded wire-id dedupe (see [`DEDUPE_CAP`]): the registry forgets
    /// an id once its result is evicted; this window keeps the
    /// at-least-once contract honest past that point.
    dedupe: BoundedDedupe,
}

impl WorkerNode {
    /// Launch the node's engine. The cluster is forced to a single
    /// worker: process separation is the dist plane's job, and the
    /// router's book has exactly one lane per node.
    pub fn launch(name: impl Into<String>, mut opts: ClusterOpts) -> Result<WorkerNode> {
        opts.workers = 1;
        let cluster = Cluster::launch(opts, Box::new(RoundRobin::new()))?;
        // long-lived serving: results live in the registry until the
        // router consumes + evicts them
        cluster.set_retain_responses(false);
        Ok(WorkerNode {
            name: name.into(),
            cluster: Arc::new(cluster),
            accepting: AtomicBool::new(true),
            stopping: AtomicBool::new(false),
            addr: Mutex::new(None),
            dedupe: BoundedDedupe::new(DEDUPE_CAP, DEDUPE_TTL),
        })
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn cluster(&self) -> &Arc<Cluster> {
        &self.cluster
    }

    pub fn is_accepting(&self) -> bool {
        self.accepting.load(Ordering::SeqCst)
    }

    /// The bound RPC address (None before [`WorkerNode::start`]).
    pub fn rpc_addr(&self) -> Option<SocketAddr> {
        *self.addr.lock().unwrap()
    }

    /// Bind the RPC listener (use port 0 for an OS-assigned port) and
    /// serve it on a background thread. Returns the bound address.
    pub fn start(self: &Arc<Self>, bind_addr: &str) -> Result<SocketAddr> {
        let listener =
            TcpListener::bind(bind_addr).with_context(|| format!("bind rpc {bind_addr}"))?;
        let addr = listener.local_addr()?;
        *self.addr.lock().unwrap() = Some(addr);
        let this = Arc::clone(self);
        std::thread::spawn(move || {
            for stream in listener.incoming() {
                if this.stopping.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = stream else { continue };
                let node = Arc::clone(&this);
                std::thread::spawn(move || {
                    let _ = serve_connection(stream, |m, p, b| node.route(m, p, b));
                });
            }
        });
        Ok(addr)
    }

    /// Announce to the router and heartbeat until stopped. Re-announces
    /// whenever the router refuses a heartbeat (it declared us dead, or
    /// restarted and lost the membership table).
    ///
    /// `router_addr` may be a comma-separated list: the node talks to one
    /// address at a time and rotates to the next after
    /// [`ROTATE_AFTER_MISSES`] consecutive failures. Listing the primary
    /// router first and a warm standby second makes workers re-announce to
    /// the standby once it takes over the primary's write path.
    pub fn announce_to(self: &Arc<Self>, router_addr: &str, cfg: &DistConfig) {
        let this = Arc::clone(self);
        let routers: Vec<String> = router_addr
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect();
        let cadence = Duration::from_millis(cfg.heartbeat_ms.max(1));
        let timeout = Duration::from_millis(cfg.rpc_timeout_ms.max(1));
        std::thread::spawn(move || {
            if routers.is_empty() {
                return;
            }
            let mut which = 0usize;
            let mut client = RpcClient::new(routers[which].clone(), timeout);
            let mut announced = false;
            let mut misses = 0u32;
            // rotate to the next configured router address; a standby
            // refuses writes (503) until takeover, so the node keeps
            // cycling primary -> standby -> primary until one accepts
            let mut rotate = |which: &mut usize, client: &mut RpcClient, misses: &mut u32| {
                *misses = 0;
                if routers.len() > 1 {
                    *which = (*which + 1) % routers.len();
                    *client = RpcClient::new(routers[*which].clone(), timeout);
                }
            };
            while !this.stopping.load(Ordering::SeqCst) {
                if !announced {
                    let body = this.announce_body();
                    match client.call("POST", "/rpc/announce", Some(&body)) {
                        Ok((200, _)) => {
                            announced = true;
                            misses = 0;
                        }
                        // refused (standby) or unreachable (dead)
                        _ => {
                            misses += 1;
                            if misses >= ROTATE_AFTER_MISSES {
                                rotate(&mut which, &mut client, &mut misses);
                            }
                        }
                    }
                }
                if announced {
                    let snap = this.cluster.worker_snapshots().into_iter().next();
                    let mut pairs = vec![("name", Json::str(this.name.clone()))];
                    if let Some(s) = snap {
                        pairs.push(("snapshot", proto::snapshot_to_json(&s)));
                    }
                    // live residency: templates registered or retired
                    // since the announce reach the router's RouteCtx on
                    // the next beat
                    pairs.push((
                        "templates",
                        Json::arr(this.serveable_templates().iter().map(Json::str).collect()),
                    ));
                    match client.call("POST", "/rpc/heartbeat", Some(&Json::obj(pairs))) {
                        Ok((200, _)) => misses = 0,
                        Ok(_) => announced = false, // router wants a re-announce
                        Err(_) => {
                            // router unreachable: after enough silence,
                            // fail over to the next address
                            misses += 1;
                            if misses >= ROTATE_AFTER_MISSES {
                                announced = false;
                                rotate(&mut which, &mut client, &mut misses);
                            }
                        }
                    }
                }
                std::thread::sleep(cadence);
            }
        });
    }

    /// Templates this node can serve right now (announce + heartbeat
    /// residency payloads).
    fn serveable_templates(&self) -> Vec<String> {
        self.cluster
            .templates_status()
            .into_iter()
            .map(|s| s.info.template_id)
            .filter(|id| self.cluster.has_template(id))
            .collect()
    }

    fn announce_body(&self) -> Json {
        let templates = self.serveable_templates();
        Announce {
            name: self.name.clone(),
            rpc_addr: self
                .rpc_addr()
                .map(|a| a.to_string())
                .unwrap_or_default(),
            templates,
        }
        .to_json()
    }

    /// Stop serving: refuse new work, stop the engine after its current
    /// batch, and unblock the accept loop. Idempotent. The node's engine
    /// threads wind down on their own; RPC peers see connection failures
    /// and the router's failure detector takes it from there.
    pub fn stop(&self) {
        self.accepting.store(false, Ordering::SeqCst);
        if self.stopping.swap(true, Ordering::SeqCst) {
            return;
        }
        self.cluster.request_stop();
        // dial ourselves so the blocking accept() wakes up and exits
        if let Some(addr) = self.rpc_addr() {
            let _ = TcpStream::connect_timeout(&addr, Duration::from_millis(200));
        }
    }

    /// Route one RPC request (separated from IO for unit testing).
    pub fn route(&self, method: &str, path: &str, body: &str) -> (u16, Json) {
        if let Some(rest) = path.strip_prefix("/rpc/poll/") {
            return match rest.parse::<u64>() {
                Ok(id) if method == "GET" => (200, proto::poll_state_to_json(&self.poll(id))),
                Ok(_) => (405, error_obj("method not allowed")),
                Err(_) => (400, error_obj(&format!("bad request id {rest:?}"))),
            };
        }
        if let Some(rest) = path.strip_prefix("/rpc/cancel/") {
            return match rest.parse::<u64>() {
                Ok(id) if method == "DELETE" => self.cancel(id),
                Ok(_) => (405, error_obj("method not allowed")),
                Err(_) => (400, error_obj(&format!("bad request id {rest:?}"))),
            };
        }
        if let Some(rest) = path.strip_prefix("/rpc/evict/") {
            return match rest.parse::<u64>() {
                Ok(id) if method == "DELETE" => (
                    200,
                    Json::obj(vec![("evicted", Json::Bool(self.cluster.evict(id)))]),
                ),
                Ok(_) => (405, error_obj("method not allowed")),
                Err(_) => (400, error_obj(&format!("bad request id {rest:?}"))),
            };
        }
        if let Some(rest) = path.strip_prefix("/rpc/template/purge/") {
            if method != "DELETE" {
                return (405, error_obj("method not allowed"));
            }
            return self.purge_template(rest);
        }
        match (method, path) {
            ("GET", "/rpc/health") | ("GET", "/healthz") | ("GET", "/v1/healthz") => (
                200,
                Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("name", Json::str(self.name.clone())),
                    ("accepting", Json::Bool(self.is_accepting())),
                    ("completed", Json::num(self.cluster.completed() as f64)),
                ]),
            ),
            ("GET", "/v1/readyz") => self.readyz(),
            ("POST", "/rpc/submit") => self.submit(body),
            ("GET", "/rpc/snapshot") => match self.cluster.worker_snapshots().into_iter().next() {
                Some(s) => (200, proto::snapshot_to_json(&s)),
                None => (500, error_obj("no worker snapshot")),
            },
            ("POST", "/rpc/template/register") => self.register_template(body),
            ("POST", "/rpc/drain") => {
                self.accepting.store(false, Ordering::SeqCst);
                (
                    200,
                    Json::obj(vec![
                        ("name", Json::str(self.name.clone())),
                        ("draining", Json::Bool(true)),
                    ]),
                )
            }
            _ => (404, error_obj("not found")),
        }
    }

    /// `GET /v1/readyz`: ready to take *new* work — alive (healthz) but
    /// draining or stopping reads 503, so the router/LB steers around a
    /// node that is winding down without killing its in-flight requests.
    fn readyz(&self) -> (u16, Json) {
        let ok = self.is_accepting() && !self.stopping.load(Ordering::SeqCst);
        (
            if ok { 200 } else { 503 },
            Json::obj(vec![
                ("ready", Json::Bool(ok)),
                ("name", Json::str(self.name.clone())),
                ("accepting", Json::Bool(self.is_accepting())),
            ]),
        )
    }

    fn submit(&self, body: &str) -> (u16, Json) {
        if !self.is_accepting() {
            return (
                503,
                Json::obj(vec![
                    ("error", Json::str("worker is draining")),
                    ("error_kind", Json::str("draining")),
                ]),
            );
        }
        let parsed = match Json::parse(body) {
            Ok(j) => j,
            Err(e) => return (400, error_obj(&format!("invalid JSON body: {e}"))),
        };
        let Some(wire) = SubmitWire::parse(&parsed) else {
            return (400, error_obj("malformed submit wire"));
        };
        // at-least-once delivery: a router whose reply was dropped in
        // flight retries the same wire id. The first copy is
        // authoritative — acknowledge instead of double-queueing. The
        // registry answers while the result is live; the bounded dedupe
        // window answers after eviction (and after a recovered router
        // re-places journaled work that already ran here).
        if self.dedupe.contains(wire.id) || self.cluster.status(wire.id).is_some() {
            return (
                202,
                Json::obj(vec![
                    ("id", Json::num(wire.id as f64)),
                    ("status", Json::str("duplicate")),
                ]),
            );
        }
        match self.cluster.submit_checked(wire.into_request()) {
            Ok(ticket) => {
                self.dedupe.insert(ticket.id());
                (
                    202,
                    Json::obj(vec![
                        ("id", Json::num(ticket.id() as f64)),
                        ("status", Json::str("queued")),
                    ]),
                )
            }
            Err(e) => edit_error_reply(&e),
        }
    }

    fn poll(&self, id: u64) -> PollState {
        match self.cluster.status(id) {
            None => PollState::Unknown,
            Some(st) => match st.state {
                RequestState::Queued => PollState::Queued,
                RequestState::Running => PollState::Running,
                RequestState::Done(resp) => PollState::Done(Box::new((*resp).clone())),
                RequestState::Failed(e) => PollState::Failed(e),
            },
        }
    }

    fn cancel(&self, id: u64) -> (u16, Json) {
        let reply = |status: u16, label: &str| {
            (
                status,
                Json::obj(vec![
                    ("id", Json::num(id as f64)),
                    ("status", Json::str(label)),
                ]),
            )
        };
        match self.cluster.cancel(id) {
            CancelOutcome::Cancelled => reply(200, "cancelled"),
            CancelOutcome::Cancelling => reply(202, "cancelling"),
            CancelOutcome::TooLate if self.cluster.evict(id) => reply(200, "evicted"),
            CancelOutcome::TooLate => (409, error_obj("too late to cancel: request is running")),
            CancelOutcome::NotFound => (404, error_obj(&format!("no such request {id}"))),
        }
    }

    fn register_template(&self, body: &str) -> (u16, Json) {
        let parsed = match Json::parse(body) {
            Ok(j) => j,
            Err(e) => return (400, error_obj(&format!("invalid JSON body: {e}"))),
        };
        let Some(template) = parsed.at("template").as_str() else {
            return (400, error_obj("missing \"template\" field"));
        };
        let reply = |status: u16, state: &str| {
            (
                status,
                Json::obj(vec![
                    ("template", Json::str(template)),
                    ("state", Json::str(state)),
                ]),
            )
        };
        match self.cluster.register_template_async(template) {
            RegisterAdmission::AlreadyReady => reply(200, "ready"),
            RegisterAdmission::Started { .. } | RegisterAdmission::InProgress => {
                reply(202, "registering")
            }
        }
    }

    fn purge_template(&self, template_id: &str) -> (u16, Json) {
        let reply = |status: u16, state: &str| {
            (
                status,
                Json::obj(vec![
                    ("template", Json::str(template_id)),
                    ("state", Json::str(state)),
                ]),
            )
        };
        match self.cluster.retire_template(template_id) {
            RetireOutcome::Retired => reply(200, "retired"),
            RetireOutcome::Draining { .. } => reply(202, "retiring"),
            RetireOutcome::NotFound => {
                (404, error_obj(&format!("no such template {template_id:?}")))
            }
        }
    }
}
