//! [`Router`]: the distributed plane's front process.
//!
//! The router owns the public `/v1/*` API, the membership table, the
//! request registry, and the routing book; worker processes own the
//! engines. The same [`Scheduler`] policies and optional
//! [`AdmissionController`] that drive the in-process [`Cluster`] drive
//! the router unchanged — the book's lanes are membership slots instead
//! of thread indices, and availability (ready members only) is what makes
//! a dead or draining remote read as *infinite cost* rather than as its
//! stale snapshot.
//!
//! ## Failover invariants
//!
//! Every accepted submission resolves — completed, failed over, or a
//! typed [`EditError::WorkerLost`]; **no ticket ever hangs**:
//!
//! * a ticket is registered only after some worker accepted the wire, so
//!   there is no window where a ticket exists but no worker holds it;
//! * the supervisor polls every booked request each cycle; `Done`/`Failed`
//!   resolve the ticket and evict the remote copy;
//! * when the failure detector declares a member dead, its still-queued
//!   requests are re-submitted to residency-compatible ready peers
//!   (deterministic engine ⇒ identical result), and requests it was
//!   already running resolve to `WorkerLost`;
//! * a worker that forgets an id (restart, epoch bump) triggers the same
//!   per-request failover path;
//! * router shutdown fails all remaining tickets with `WorkerShutdown`.
//!
//! ## Retry budgets
//!
//! Transport retries live *here*, not in the RPC client: each member gets
//! a token bucket ([`RetryBudget`]) refilled at a configured rate, and an
//! unreachable submit is retried in place — jittered exponential backoff
//! between attempts — only while tokens remain. A drained budget bans the
//! member for that placement; if no member accepts and some budget ran
//! dry, the caller sees a typed `Overloaded` whose `Retry-After` is the
//! earliest instant a token exists again. A persistently flapping worker
//! therefore drains its own budget instead of amplifying load cluster-wide.
//!
//! ## Sessions
//!
//! The router hosts the same `/v1/sessions` lifecycle API as the
//! in-process frontend, backed by its own [`SessionRegistry`]: rounds
//! carry their session id on the wire, the scheduler sees the owner slot
//! through [`RouteCtx::session_owner`], and a member death orphans its
//! sessions so the next round re-homes (epoch bump) on whatever slot the
//! fallback policy picks — failover re-submission re-homes in-flight
//! rounds the same way. Template pinning stays per-round at the workers
//! (the router has no template registry), and SSE progress streams are
//! *not* proxied — they are served by the worker-local frontend that owns
//! the engine's event buffers.
//!
//! ## Durability ([`crate::durable`])
//!
//! With `--journal <dir>` the router writes every externally visible
//! state transition to a checksummed write-ahead journal *before* acking
//! it, and a restarted router adopts the replayed state: still-queued
//! work is re-placed on residency-compatible members (worker-side
//! wire-id dedupe makes re-submission safe), in-flight work reconciles
//! against `/rpc/poll`, and repeated `Idempotency-Key`s return the
//! original ticket even across the crash. A warm standby
//! ([`Router::start_standby`]) tails the journal over
//! `GET /rpc/journal/tail`, treats tail success as the primary's
//! heartbeat, and takes over on silence.
//!
//! [`Cluster`]: crate::cluster::Cluster

use std::collections::HashMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::cache::tier::Residency;
use crate::cluster::{EditTicket, RequestRegistry, RequestState};
use crate::config::ModelConfig;
use crate::durable::{self, DurableLog, IdemKeys, RecoveredState};
use crate::engine::request::{EditError, EditRequest, EditRequestBuilder};
use crate::faults::{jittered_backoff, FaultInjector, RetryBudget};
use crate::qos::{Admission, AdmissionController, Priority};
use crate::scheduler::{Outstanding, RouteCtx, Scheduler};
use crate::server::{
    done_body, edit_error_reply, error_obj, push_qos_pairs, serve_connection_ext,
    session_error_reply, session_status_body, status_pairs,
};
use crate::session::{SessionError, SessionRegistry};
use crate::util::json::Json;
use crate::workload::TraceEvent;

use super::membership::{MemberState, Membership};
use super::proto::{self, Announce, PollState, SubmitWire};
use super::remote::{RemoteWorker, SubmitOutcome};
use super::rpc::RpcClient;
use super::DistConfig;

/// First id handed to HTTP submissions (same convention as
/// [`crate::server::HttpServer`]).
const FIRST_HTTP_ID: u64 = 1_000_000;

pub struct Router {
    cfg: DistConfig,
    model: ModelConfig,
    membership: Mutex<Membership>,
    /// Slot-aligned RPC handles (same index space as membership slots and
    /// book lanes). A re-announce replaces the slot's handle in place.
    workers: Mutex<Vec<Arc<RemoteWorker>>>,
    /// Slot-aligned retry budgets (token bucket per worker): a flapping
    /// member drains its own budget without starving retries toward
    /// healthy peers. Survives re-announces — a restart does not refill
    /// the bucket.
    budgets: Mutex<Vec<Arc<RetryBudget>>>,
    /// Transport fault injection for the router's RPC clients (None in
    /// production).
    faults: Option<Arc<FaultInjector>>,
    /// Outstanding sets per member slot — the scheduler's world view.
    book: Mutex<Vec<Vec<Outstanding>>>,
    scheduler: Mutex<Box<dyn Scheduler>>,
    admission: Option<AdmissionController>,
    /// Serializes guarded submissions so `max_pending` holds under
    /// concurrent frontends (same role as the cluster's gate).
    admission_gate: Mutex<()>,
    registry: Arc<RequestRegistry>,
    /// Wire payloads of non-terminal requests, kept for failover
    /// re-submission. Removed when the request resolves.
    pending: Mutex<HashMap<u64, SubmitWire>>,
    /// Interactive sessions fronted by this router (sticky affinity over
    /// membership slots; failover orphans → re-home).
    sessions: SessionRegistry,
    /// Write-ahead journal + state mirror (None: volatile, the
    /// pre-journal behavior).
    durable: Option<Arc<DurableLog>>,
    /// `Idempotency-Key` -> original request id, hot-path view; the
    /// journal's accepted records are the durable copy.
    idem: IdemKeys,
    /// True while this process is a warm standby tailing a primary
    /// (mutating endpoints answer 503 until takeover).
    standby: AtomicBool,
    /// Journal-recovered requests awaiting re-placement; the supervisor
    /// retries them each tick until workers re-announce.
    replay: Mutex<Vec<u64>>,
    next_id: AtomicU64,
    stopping: AtomicBool,
    addr: Mutex<Option<SocketAddr>>,
    started: Instant,
}

impl Router {
    pub fn new(
        model: ModelConfig,
        scheduler: Box<dyn Scheduler>,
        admission: Option<AdmissionController>,
        cfg: DistConfig,
    ) -> Arc<Router> {
        let faults = FaultInjector::from_plan(cfg.faults.as_ref());
        let (durable, recovered) = match cfg.journal_config() {
            None => (None, None),
            Some(jc) => match DurableLog::open(jc) {
                Ok((log, state)) => (Some(log), Some(state)),
                Err(e) => {
                    eprintln!("[router] journal open failed ({e:#}); running volatile");
                    (None, None)
                }
            },
        };
        let router = Arc::new(Router {
            membership: Mutex::new(Membership::new(
                Duration::from_millis(cfg.suspect_after_ms.max(1)),
                Duration::from_millis(cfg.dead_after_ms.max(1)),
            )),
            workers: Mutex::new(Vec::new()),
            budgets: Mutex::new(Vec::new()),
            faults,
            book: Mutex::new(Vec::new()),
            scheduler: Mutex::new(scheduler),
            admission,
            admission_gate: Mutex::new(()),
            registry: RequestRegistry::new(),
            pending: Mutex::new(HashMap::new()),
            sessions: SessionRegistry::default(),
            durable,
            idem: IdemKeys::new(4096),
            standby: AtomicBool::new(false),
            replay: Mutex::new(Vec::new()),
            next_id: AtomicU64::new(FIRST_HTTP_ID),
            stopping: AtomicBool::new(false),
            addr: Mutex::new(None),
            started: Instant::now(),
            model,
            cfg,
        });
        if let Some(state) = recovered {
            router.adopt(&state);
        }
        router
    }

    pub fn registry(&self) -> &Arc<RequestRegistry> {
        &self.registry
    }

    /// Requests that reached a terminal state (success, failure, cancel).
    pub fn completed(&self) -> usize {
        self.registry.finished()
    }

    pub fn await_finished(&self, n: usize, timeout: Duration) -> bool {
        self.registry.await_finished(n, timeout)
    }

    pub fn elapsed(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Members currently in the `Ready` state.
    pub fn ready_count(&self) -> usize {
        self.membership
            .lock()
            .unwrap()
            .available()
            .iter()
            .filter(|&&a| a)
            .count()
    }

    pub fn bound_addr(&self) -> Option<SocketAddr> {
        *self.addr.lock().unwrap()
    }

    /// Bind the listener (serves both the public `/v1/*` API and the
    /// worker-facing `/rpc/*` control endpoints) and spawn the accept
    /// loop + supervisor. Returns the bound address.
    pub fn start(self: &Arc<Self>, bind_addr: &str) -> Result<SocketAddr> {
        let addr = self.bind_and_accept(bind_addr)?;
        let this = Arc::clone(self);
        std::thread::spawn(move || this.supervise());
        Ok(addr)
    }

    /// Start as a warm standby of the primary at `primary`: serve reads
    /// (mutations get 503), tail the primary's journal stream, and take
    /// over — adopt the tailed state, start supervising — once the tail
    /// is silent longer than `standby_takeover_ms`.
    pub fn start_standby(self: &Arc<Self>, bind_addr: &str, primary: &str) -> Result<SocketAddr> {
        self.standby.store(true, Ordering::SeqCst);
        let addr = self.bind_and_accept(bind_addr)?;
        let this = Arc::clone(self);
        let primary = primary.to_string();
        std::thread::spawn(move || this.standby_tail(primary));
        Ok(addr)
    }

    fn bind_and_accept(self: &Arc<Self>, bind_addr: &str) -> Result<SocketAddr> {
        let listener =
            TcpListener::bind(bind_addr).with_context(|| format!("bind router {bind_addr}"))?;
        let addr = listener.local_addr()?;
        *self.addr.lock().unwrap() = Some(addr);
        let this = Arc::clone(self);
        std::thread::spawn(move || {
            for stream in listener.incoming() {
                if this.stopping.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = stream else { continue };
                let router = Arc::clone(&this);
                std::thread::spawn(move || {
                    let _ = serve_connection_ext(stream, |m, p, b, k| {
                        router.route_with_headers(m, p, b, k)
                    });
                });
            }
        });
        Ok(addr)
    }

    /// Stop serving and resolve every live ticket with `WorkerShutdown`.
    pub fn shutdown(&self) {
        if self.stopping.swap(true, Ordering::SeqCst) {
            return;
        }
        self.registry.fail_all_pending(EditError::WorkerShutdown);
        if let Some(log) = &self.durable {
            log.flush();
        }
        if let Some(addr) = self.bound_addr() {
            let _ = TcpStream::connect_timeout(&addr, Duration::from_millis(200));
        }
    }

    /// Graceful SIGTERM path: stop accepting, let the workers finish what
    /// is in flight (bounded by `drain`), journal the leftovers as failed,
    /// flush, then resolve them with `WorkerShutdown`.
    pub fn graceful_shutdown(&self, drain: Duration) {
        if self.stopping.swap(true, Ordering::SeqCst) {
            return;
        }
        let deadline = Instant::now() + drain;
        while Instant::now() < deadline && !self.pending.lock().unwrap().is_empty() {
            self.pump();
            std::thread::sleep(Duration::from_millis(self.cfg.poll_ms.max(1)));
        }
        let leftovers: Vec<u64> = self.pending.lock().unwrap().keys().copied().collect();
        for id in leftovers {
            self.journal(durable::rec_req_state(id, "failed"));
        }
        if let Some(log) = &self.durable {
            log.flush();
        }
        self.registry.fail_all_pending(EditError::WorkerShutdown);
        if let Some(addr) = self.bound_addr() {
            let _ = TcpStream::connect_timeout(&addr, Duration::from_millis(200));
        }
    }

    /// Test hook simulating `kill -9`: stop this process's loops without
    /// draining, flushing, or resolving anything — exactly the state a
    /// crash leaves behind. (Per-record appends are already flushed to
    /// the OS, so a *process* kill loses nothing; the fsync policy only
    /// matters for host crashes.)
    pub fn halt_for_test(&self) {
        if self.stopping.swap(true, Ordering::SeqCst) {
            return;
        }
        if let Some(addr) = self.bound_addr() {
            let _ = TcpStream::connect_timeout(&addr, Duration::from_millis(200));
        }
    }

    // ------------------------------------------------------------------
    // supervisor: failure detection, result pump, failover
    // ------------------------------------------------------------------

    fn supervise(self: Arc<Self>) {
        let cadence = Duration::from_millis(self.cfg.poll_ms.max(1));
        while !self.stopping.load(Ordering::SeqCst) {
            let newly_dead: Vec<(usize, String)> = {
                let mut ms = self.membership.lock().unwrap();
                ms.expire(Instant::now())
                    .into_iter()
                    .map(|slot| {
                        let name = ms.get(slot).map(|m| m.name.clone()).unwrap_or_default();
                        (slot, name)
                    })
                    .collect()
            };
            for (slot, name) in newly_dead {
                eprintln!("[router] member {name:?} (slot {slot}) declared dead; failing over");
                // sessions homed there lose their owner: the next round
                // re-homes (epoch bump) wherever the fallback routes it
                self.sessions.orphan_worker(slot);
            }
            // sweep every dead slot that still holds work — covers both
            // fresh deaths and submissions that raced the declaration
            for slot in self.dead_slots_with_work() {
                self.fail_over_slot(slot);
            }
            self.drain_replay();
            self.pump();
            std::thread::sleep(cadence);
        }
    }

    fn dead_slots_with_work(&self) -> Vec<usize> {
        let ms = self.membership.lock().unwrap();
        let book = self.book.lock().unwrap();
        ms.members()
            .iter()
            .enumerate()
            .filter(|(slot, m)| {
                m.state == MemberState::Dead
                    && book.get(*slot).map(|lane| !lane.is_empty()).unwrap_or(false)
            })
            .map(|(slot, _)| slot)
            .collect()
    }

    /// Poll every booked request on every live member and sync the
    /// registry. Transport errors are ignored here — the failure detector
    /// owns the liveness verdict.
    fn pump(&self) {
        let live: Vec<(usize, Arc<RemoteWorker>)> = {
            let ms = self.membership.lock().unwrap();
            let ws = self.workers.lock().unwrap();
            ms.members()
                .iter()
                .enumerate()
                .filter(|(_, m)| m.state != MemberState::Dead)
                .filter_map(|(slot, _)| ws.get(slot).cloned().map(|w| (slot, w)))
                .collect()
        };
        for (slot, remote) in live {
            let ids: Vec<u64> = {
                let book = self.book.lock().unwrap();
                book.get(slot)
                    .map(|lane| lane.iter().map(|o| o.id).collect())
                    .unwrap_or_default()
            };
            for id in ids {
                match remote.poll(id) {
                    Err(_) => break, // unreachable: expiry decides its fate
                    Ok(PollState::Queued) => {}
                    Ok(PollState::Running) => {
                        let already = self
                            .registry
                            .status(id)
                            .map(|s| matches!(s.state, RequestState::Running))
                            .unwrap_or(false);
                        if !already {
                            self.journal(durable::rec_req_state(id, "running"));
                        }
                        self.registry.mark_running(id);
                    }
                    Ok(PollState::Done(resp)) => {
                        self.journal(durable::rec_req_state(id, "done"));
                        self.sessions.complete_round(id, true, Some(resp.timing.e2e));
                        self.registry.fulfill(id, Ok(Arc::new(*resp)));
                        let _ = remote.evict(id);
                        self.clear_entry(slot, id);
                    }
                    Ok(PollState::Failed(e)) => {
                        self.journal(durable::rec_req_state(id, "failed"));
                        self.sessions.complete_round(id, false, None);
                        self.registry.fulfill(id, Err(e));
                        let _ = remote.evict(id);
                        self.clear_entry(slot, id);
                    }
                    Ok(PollState::Unknown) => {
                        // the worker forgot the id (restart/epoch bump):
                        // same recovery as a dead member, per request
                        self.clear_entry(slot, id);
                        self.fail_over_request(id);
                    }
                }
            }
        }
    }

    /// Drain a dead member's lane and recover each request.
    fn fail_over_slot(&self, slot: usize) {
        // idempotent: covers submissions that raced the death declaration
        self.sessions.orphan_worker(slot);
        let drained: Vec<Outstanding> = {
            let mut book = self.book.lock().unwrap();
            match book.get_mut(slot) {
                Some(lane) => std::mem::take(lane),
                None => Vec::new(),
            }
        };
        for o in drained {
            self.fail_over_request(o.id);
        }
    }

    /// Recover one request whose worker is gone: still-queued work is
    /// re-placed on a ready peer (the engine is deterministic, so the
    /// re-run yields the identical result); work the lost member was
    /// already running resolves to [`EditError::WorkerLost`].
    fn fail_over_request(&self, id: u64) {
        let wire = self.pending.lock().unwrap().remove(&id);
        match self.registry.status(id).map(|s| s.state) {
            None => {}                    // evicted: nothing to recover
            Some(s) if s.is_terminal() => {}
            Some(RequestState::Running) => {
                self.journal(durable::rec_req_state(id, "failed"));
                self.sessions.complete_round(id, false, None);
                self.registry.fulfill(id, Err(EditError::WorkerLost));
            }
            Some(_) => {
                let Some(wire) = wire else {
                    self.journal(durable::rec_req_state(id, "failed"));
                    self.sessions.complete_round(id, false, None);
                    self.registry.fulfill(id, Err(EditError::WorkerLost));
                    return;
                };
                let outstanding = self.outstanding_from_wire(&wire);
                let session = wire.session;
                match self.try_place(&wire, &outstanding) {
                    Ok(slot) => {
                        eprintln!("[router] request {id} failed over to slot {slot}");
                        self.journal(durable::rec_req_placed(id, slot));
                        self.track(slot, outstanding, wire);
                        // re-home the session on the failover target
                        if let Some(sid) = session {
                            self.sessions.assign_owner(sid, id, slot);
                        }
                    }
                    Err(_) => {
                        self.journal(durable::rec_req_state(id, "failed"));
                        self.sessions.complete_round(id, false, None);
                        self.registry.fulfill(id, Err(EditError::WorkerLost));
                    }
                }
            }
        }
    }

    fn clear_entry(&self, slot: usize, id: u64) {
        let mut book = self.book.lock().unwrap();
        if let Some(lane) = book.get_mut(slot) {
            if let Some(pos) = lane.iter().position(|o| o.id == id) {
                lane.swap_remove(pos);
            }
        }
        drop(book);
        self.pending.lock().unwrap().remove(&id);
    }

    // ------------------------------------------------------------------
    // durability: journal, recovery adoption, standby tail
    // ------------------------------------------------------------------

    /// Append one control-plane record (no-op without a journal).
    fn journal(&self, rec: Json) {
        if let Some(log) = &self.durable {
            log.record(rec);
        }
    }

    /// Fold a recovered state into this (empty) router: re-seat members
    /// on their journaled slots, restore sessions and idempotency keys,
    /// re-register every non-terminal request, and queue never-placed
    /// ones for re-placement. Restored members come back `Suspect` — a
    /// live worker's next heartbeat (or re-announce) proves it; a dead
    /// one expires and its booked work fails over normally.
    fn adopt(&self, state: &RecoveredState) {
        let now = Instant::now();
        let timeout = Duration::from_millis(self.cfg.rpc_timeout_ms.max(1));
        {
            let mut ms = self.membership.lock().unwrap();
            let mut ws = self.workers.lock().unwrap();
            let mut book = self.book.lock().unwrap();
            let mut budgets = self.budgets.lock().unwrap();
            for m in &state.members {
                let slot = ms.restore(&m.name, &m.addr, Vec::new(), m.epoch, now);
                let mut remote = RemoteWorker::new(m.name.clone(), m.addr.clone(), timeout);
                if let Some(f) = &self.faults {
                    remote = remote.with_faults(Arc::clone(f));
                }
                let remote = Arc::new(remote);
                if slot < ws.len() {
                    ws[slot] = remote;
                } else {
                    ws.push(remote);
                }
                while book.len() <= slot {
                    book.push(Vec::new());
                }
                while budgets.len() <= slot {
                    budgets.push(Arc::new(RetryBudget::new(
                        self.cfg.retry_budget.max(1.0),
                        self.cfg.retry_refill_per_sec.max(1e-6),
                    )));
                }
            }
        }
        for (sid, s) in &state.sessions {
            self.sessions
                .restore(*sid, &s.template, s.closed, s.epoch, s.owner, s.rounds, &s.inflight);
        }
        for (key, id) in &state.idempotency {
            self.idem.put(key, *id);
        }
        let mut recovered = 0usize;
        for (id, r) in &state.requests {
            if r.is_terminal() {
                continue;
            }
            self.registry
                .register(*id, r.slot.unwrap_or(0), r.wire.priority, r.wire.deadline_ms);
            if r.running {
                self.registry.mark_running(*id);
            }
            match r.slot {
                // booked: the pump reconciles against the worker (done /
                // still queued / forgotten -> per-request failover)
                Some(slot) => {
                    let outstanding = self.outstanding_from_wire(&r.wire);
                    self.track(slot, outstanding, r.wire.clone());
                }
                // accepted but never placed: re-place once members rejoin
                None => {
                    self.pending.lock().unwrap().insert(*id, r.wire.clone());
                    self.replay.lock().unwrap().push(*id);
                }
            }
            recovered += 1;
        }
        self.next_id
            .fetch_max(state.next_request_id.max(FIRST_HTTP_ID), Ordering::SeqCst);
        if recovered > 0 || !state.members.is_empty() {
            eprintln!(
                "[router] journal recovery: {} in-flight request(s), {} member slot(s), {} session(s)",
                recovered,
                state.members.len(),
                state.sessions.len()
            );
        }
    }

    /// Re-place journal-recovered requests that never reached a worker.
    /// Placement failure (no ready members yet — workers re-announce
    /// after a restart) keeps the id queued for the next supervisor tick
    /// rather than failing it: an accepted request is never lost to a
    /// slow rejoin.
    fn drain_replay(&self) {
        let ids: Vec<u64> = std::mem::take(&mut *self.replay.lock().unwrap());
        if ids.is_empty() {
            return;
        }
        let mut keep = Vec::new();
        for id in ids {
            match self.registry.status(id).map(|s| s.state) {
                None => continue,
                Some(s) if s.is_terminal() => continue,
                _ => {}
            }
            let Some(wire) = self.pending.lock().unwrap().get(&id).cloned() else {
                continue;
            };
            let outstanding = self.outstanding_from_wire(&wire);
            match self.try_place(&wire, &outstanding) {
                Ok(slot) => {
                    eprintln!("[router] recovered request {id} re-placed on slot {slot}");
                    self.journal(durable::rec_req_placed(id, slot));
                    if let Some(sid) = wire.session {
                        self.sessions.assign_owner(sid, id, slot);
                    }
                    self.track(slot, outstanding, wire);
                }
                Err(_) => keep.push(id),
            }
        }
        if !keep.is_empty() {
            self.replay.lock().unwrap().extend(keep);
        }
    }

    /// `GET /rpc/journal/tail?from=N`: the standby replication stream.
    fn journal_tail(&self, query: &str) -> (u16, Json) {
        let Some(log) = &self.durable else {
            return (404, error_obj("no journal configured"));
        };
        let from = query
            .strip_prefix("?from=")
            .and_then(|v| v.parse::<u64>().ok())
            .unwrap_or(1);
        (200, log.tail(from))
    }

    /// Warm-standby loop: tail the primary's journal, fold each record
    /// into a shadow state, and treat tail success as the primary's
    /// heartbeat. Silence beyond `standby_takeover_ms` promotes this
    /// process.
    fn standby_tail(self: Arc<Self>, primary: String) {
        let client = RpcClient::new(
            primary.clone(),
            Duration::from_millis(self.cfg.rpc_timeout_ms.max(1)),
        );
        let takeover = Duration::from_millis(self.cfg.standby_takeover_ms.max(1));
        let cadence = Duration::from_millis(self.cfg.heartbeat_ms.max(1));
        let mut state = RecoveredState::new();
        let mut next = 1u64;
        let mut last_ok = Instant::now();
        while !self.stopping.load(Ordering::SeqCst) {
            match client.call("GET", &format!("/rpc/journal/tail?from={next}"), None) {
                Ok((200, body)) => {
                    last_ok = Instant::now();
                    if let Some(snap) = body.get("snapshot") {
                        // ring fell behind (or first contact): full resync
                        state = RecoveredState::from_snapshot_json(snap);
                        next = state.last_seq + 1;
                    }
                    if let Some(records) = body.at("records").as_arr() {
                        for entry in records {
                            let Some(seq) = entry.at("seq").as_f64().map(|x| x as u64) else {
                                continue;
                            };
                            state.apply(seq, entry.at("rec"));
                            next = seq + 1;
                        }
                    }
                }
                Ok(_) | Err(_) => {
                    if last_ok.elapsed() >= takeover {
                        eprintln!(
                            "[router] primary {primary} silent past the takeover window; \
                             standby promoting (seq {})",
                            state.last_seq
                        );
                        self.take_over(state);
                        return;
                    }
                }
            }
            std::thread::sleep(cadence);
        }
    }

    /// Promote the standby: continue the primary's journal sequence in
    /// our own journal, adopt the tailed state, open for mutations, and
    /// start supervising. Workers rotate their announce/heartbeat here
    /// once the primary stops answering, landing on their journaled slots.
    fn take_over(self: &Arc<Self>, state: RecoveredState) {
        if let Some(log) = &self.durable {
            log.adopt_state(&state);
        }
        self.adopt(&state);
        self.standby.store(false, Ordering::SeqCst);
        let this = Arc::clone(self);
        std::thread::spawn(move || this.supervise());
    }

    // ------------------------------------------------------------------
    // submission path
    // ------------------------------------------------------------------

    fn outstanding_for(&self, req: &EditRequest) -> Outstanding {
        Outstanding {
            id: req.id,
            masked_tokens: req.mask.masked_count(),
            remaining_steps: self.model.steps,
            priority: req.priority,
        }
    }

    fn outstanding_from_wire(&self, wire: &SubmitWire) -> Outstanding {
        Outstanding {
            id: wire.id,
            masked_tokens: wire.masked.len(),
            remaining_steps: self.model.steps,
            priority: wire.priority,
        }
    }

    /// Routing context from the membership table: residency is derived
    /// from each member's live template set — announced, then refreshed
    /// by every heartbeat that carries one, so registrations and
    /// retirements steer routing within a beat (bytes unknown at the
    /// router: 0). Availability comes from the member's state.
    fn route_ctx_locked(&self, ms: &Membership, template: &str) -> RouteCtx {
        RouteCtx {
            residency: ms
                .members()
                .iter()
                .map(|m| {
                    if m.templates.iter().any(|t| t == template) {
                        Residency::Host
                    } else {
                        Residency::Absent
                    }
                })
                .collect(),
            template_bytes: 0,
            available: ms.available(),
            session_owner: None,
        }
    }

    /// Pick an available member for `outstanding` (scheduler preference,
    /// minus `banned` slots) and return its RPC handle. `owner` is the
    /// sticky-affinity hint for session rounds.
    fn pick(
        &self,
        outstanding: &Outstanding,
        template: &str,
        owner: Option<usize>,
        banned: &[usize],
    ) -> Option<(usize, Arc<RemoteWorker>)> {
        let mut ctx = {
            let ms = self.membership.lock().unwrap();
            self.route_ctx_locked(&ms, template)
        };
        ctx.session_owner = owner;
        for &b in banned {
            if b < ctx.available.len() {
                ctx.available[b] = false;
            }
        }
        if !ctx.available.iter().any(|&a| a) {
            return None;
        }
        let slot = {
            let book = self.book.lock().unwrap();
            if book.is_empty() {
                return None;
            }
            let mut sched = self.scheduler.lock().unwrap();
            let w = sched.pick(outstanding, &book, &ctx);
            w.min(book.len() - 1)
        };
        if !ctx.is_available(slot) {
            return None;
        }
        let remote = self.workers.lock().unwrap().get(slot).cloned()?;
        Some((slot, remote))
    }

    /// This slot's retry budget (None until the member announced).
    fn budget_for(&self, slot: usize) -> Option<Arc<RetryBudget>> {
        self.budgets.lock().unwrap().get(slot).cloned()
    }

    /// Place `wire` on some available member over RPC.
    ///
    /// An *unreachable* member is retried in place — jittered exponential
    /// backoff between attempts, each retry paid from the member's token
    /// bucket — up to `retry_attempts` per placement, then banned for
    /// this request and placement moves on. Members that *reject* are
    /// banned immediately (a typed verdict is not a transport blip). If
    /// nobody accepts: the last typed reject wins; otherwise, if any
    /// budget ran dry, a typed `Overloaded` carrying the earliest instant
    /// a retry token exists again (surfaced as `Retry-After`); else
    /// `WorkerShutdown`. Bookkeeping is the caller's job — see
    /// [`Router::track`].
    fn try_place(&self, wire: &SubmitWire, outstanding: &Outstanding) -> Result<usize, EditError> {
        let mut reject: Option<EditError> = None;
        let mut banned: Vec<usize> = Vec::new();
        let mut budget_dry_after_ms: Option<u64> = None;
        let base = Duration::from_millis(self.cfg.retry_backoff_base_ms.max(1));
        let cap = Duration::from_millis(
            self.cfg.retry_backoff_cap_ms.max(self.cfg.retry_backoff_base_ms.max(1)),
        );
        // session rounds prefer their owner slot (sticky affinity); a
        // dead/draining/banned owner falls back to the policy's pick
        let owner = wire.session.and_then(|sid| self.sessions.owner_of(sid));
        while let Some((slot, remote)) = self.pick(outstanding, &wire.template, owner, &banned) {
            let mut attempt: u32 = 0;
            loop {
                match remote.submit(wire) {
                    SubmitOutcome::Accepted => return Ok(slot),
                    SubmitOutcome::Rejected(e) => {
                        reject = Some(e);
                        banned.push(slot);
                        break;
                    }
                    SubmitOutcome::Unreachable(_) => {
                        if attempt >= self.cfg.retry_attempts {
                            banned.push(slot);
                            break;
                        }
                        let budget = self.budget_for(slot);
                        let spent = budget.as_ref().is_some_and(|b| b.try_spend());
                        if !spent {
                            if let Some(b) = &budget {
                                let after = b.retry_after_ms();
                                budget_dry_after_ms = Some(
                                    budget_dry_after_ms.map_or(after, |a| a.min(after)),
                                );
                            }
                            banned.push(slot);
                            break;
                        }
                        let salt = wire.id
                            ^ ((slot as u64) << 32)
                            ^ ((u64::from(attempt) + 1) << 48);
                        std::thread::sleep(jittered_backoff(base, cap, attempt, salt));
                        attempt += 1;
                    }
                }
            }
        }
        if let Some(e) = reject {
            return Err(e);
        }
        match budget_dry_after_ms {
            Some(retry_after_ms) => Err(EditError::Overloaded {
                retry_after_ms: retry_after_ms.max(1),
            }),
            None => Err(EditError::WorkerShutdown),
        }
    }

    /// Record an accepted placement in the book + pending map. Ordered
    /// after ticket registration so every booked id is registered — the
    /// pump relies on that.
    fn track(&self, slot: usize, outstanding: Outstanding, wire: SubmitWire) {
        let mut book = self.book.lock().unwrap();
        if let Some(lane) = book.get_mut(slot) {
            lane.push(outstanding);
        }
        drop(book);
        self.pending.lock().unwrap().insert(wire.id, wire);
    }

    /// Route + submit one request. The ticket is created only after a
    /// worker accepted the submission, so a returned ticket always has an
    /// owner and will resolve (completion, failover, or `WorkerLost`).
    pub fn submit(&self, req: EditRequest) -> Result<EditTicket, EditError> {
        self.submit_inner(req, None)
    }

    fn submit_inner(&self, req: EditRequest, idem: Option<&str>) -> Result<EditTicket, EditError> {
        if self.stopping.load(Ordering::SeqCst) {
            return Err(EditError::WorkerShutdown);
        }
        let wire = SubmitWire::from_request(&req);
        let outstanding = self.outstanding_for(&req);
        let slot = self.try_place(&wire, &outstanding)?;
        // journal before the ticket exists: a crash from here on re-places
        // the request on recovery instead of losing an acked submission
        self.journal(durable::rec_req_accepted(&wire, idem));
        self.journal(durable::rec_req_placed(req.id, slot));
        if let Some(key) = idem {
            self.idem.put(key, req.id);
        }
        let ticket = self
            .registry
            .register(req.id, slot, req.priority, req.deadline_ms());
        self.track(slot, outstanding, wire);
        if let Some(sid) = req.session {
            self.sessions.assign_owner(sid, req.id, slot);
        }
        Ok(ticket)
    }

    fn assess_admission(&self, req: &EditRequest, outstanding: &Outstanding) -> Result<(), EditError> {
        let Some(ctl) = &self.admission else {
            return Ok(());
        };
        let ctx = {
            let ms = self.membership.lock().unwrap();
            self.route_ctx_locked(&ms, &req.template_id)
        };
        let remaining = req
            .deadline
            .map(|d| d.saturating_duration_since(Instant::now()));
        let book = self.book.lock().unwrap();
        match ctl.assess(outstanding, remaining, &book, &ctx) {
            Admission::Admit => Ok(()),
            Admission::Overloaded { retry_after, .. } => Err(EditError::Overloaded {
                retry_after_ms: (retry_after * 1e3).ceil() as u64,
            }),
            Admission::DeadlineInfeasible { estimate, deadline } => {
                Err(EditError::DeadlineInfeasible(format!(
                    "estimated completion {estimate:.3}s exceeds deadline {deadline:.3}s"
                )))
            }
        }
    }

    /// The guarded path the HTTP frontend uses: QoS admission (when
    /// enabled), then route + submit. Template admission happens at the
    /// workers — an unknown template comes back as their typed reject.
    pub fn submit_guarded(&self, req: EditRequest) -> Result<EditTicket, EditError> {
        self.submit_guarded_inner(req, None)
    }

    fn submit_guarded_inner(
        &self,
        req: EditRequest,
        idem: Option<&str>,
    ) -> Result<EditTicket, EditError> {
        let outstanding = self.outstanding_for(&req);
        let _gate = self.admission_gate.lock().unwrap();
        self.assess_admission(&req, &outstanding)?;
        self.submit_inner(req, idem)
    }

    /// Realize a trace event into a request (same semantics as
    /// [`crate::cluster::Cluster::event_request`]).
    pub fn event_request(&self, ev: &TraceEvent) -> EditRequest {
        let mask = ev.mask(self.model.latent_hw);
        let mut req = EditRequest::new(ev.id, ev.template.clone(), mask, ev.prompt_seed);
        req.priority = ev.priority;
        req.deadline = ev
            .deadline_ms
            .map(|ms| req.arrival + Duration::from_millis(ms));
        req
    }

    /// Convenience: realize and submit a trace event.
    pub fn submit_event(&self, ev: &TraceEvent) -> Result<EditTicket, EditError> {
        self.submit(self.event_request(ev))
    }

    // ------------------------------------------------------------------
    // HTTP surface
    // ------------------------------------------------------------------

    /// Route one request (separated from IO for unit testing).
    pub fn route(&self, method: &str, path: &str, body: &str) -> (u16, Json) {
        self.route_with_headers(method, path, body, None)
    }

    /// [`Router::route`] plus the request's `Idempotency-Key` (when sent):
    /// a repeated key on `POST /v1/edits` or a round submit returns the
    /// original ticket instead of minting a duplicate.
    pub fn route_with_headers(
        &self,
        method: &str,
        path: &str,
        body: &str,
        idem: Option<&str>,
    ) -> (u16, Json) {
        if let Some(query) = path.strip_prefix("/rpc/journal/tail") {
            if method != "GET" {
                return (405, error_obj("method not allowed"));
            }
            return self.journal_tail(query);
        }
        if self.standby.load(Ordering::SeqCst) && method != "GET" {
            // mutations belong to the primary until takeover
            return (
                503,
                Json::obj(vec![
                    ("error", Json::str("standby: primary still holds the write path")),
                    ("standby", Json::Bool(true)),
                ]),
            );
        }
        if let Some(rest) = path.strip_prefix("/v1/edits/") {
            return match rest.parse::<u64>() {
                Ok(id) => self.edit_by_id(method, id),
                Err(_) => (400, error_obj(&format!("bad request id {rest:?}"))),
            };
        }
        if let Some(rest) = path.strip_prefix("/v1/sessions") {
            if rest.is_empty() || rest.starts_with('/') {
                return self.sessions_route(method, rest, body, idem);
            }
        }
        if let Some(rest) = path.strip_prefix("/v1/drain/") {
            if rest.is_empty() {
                return (400, error_obj("empty member name"));
            }
            if method != "POST" {
                return (405, error_obj("method not allowed"));
            }
            return self.drain(rest);
        }
        if let Some(rest) = path.strip_prefix("/v1/templates/") {
            if rest.is_empty() {
                return (400, error_obj("empty template id"));
            }
            if method != "DELETE" {
                return (405, error_obj("method not allowed"));
            }
            return self.template_purge(rest);
        }
        match (method, path) {
            ("POST", "/rpc/announce") => self.announce(body),
            ("POST", "/rpc/heartbeat") => self.heartbeat(body),
            ("GET", "/healthz") | ("GET", "/v1/healthz") => {
                (200, Json::obj(vec![("ok", Json::Bool(true))]))
            }
            ("GET", "/v1/readyz") => self.readyz(),
            ("GET", "/v1/cluster") => self.cluster_body(),
            ("GET", "/stats") | ("GET", "/v1/stats") => self.stats_body(),
            ("POST", "/v1/edits") => self.edit_async(body, idem),
            ("POST", "/v1/templates") => self.template_register(body),
            _ => (404, error_obj("not found")),
        }
    }

    fn announce(&self, body: &str) -> (u16, Json) {
        let parsed = match Json::parse(body) {
            Ok(j) => j,
            Err(e) => return (400, error_obj(&format!("invalid JSON body: {e}"))),
        };
        let Some(a) = Announce::parse(&parsed) else {
            return (400, error_obj("malformed announce"));
        };
        if a.rpc_addr.is_empty() {
            return (400, error_obj("announce without rpc_addr"));
        }
        let timeout = Duration::from_millis(self.cfg.rpc_timeout_ms.max(1));
        let (slot, epoch) = self.membership.lock().unwrap().announce(
            &a.name,
            &a.rpc_addr,
            a.templates.clone(),
            Instant::now(),
        );
        {
            let mut ws = self.workers.lock().unwrap();
            let mut remote = RemoteWorker::new(a.name.clone(), a.rpc_addr.clone(), timeout);
            if let Some(f) = &self.faults {
                remote = remote.with_faults(Arc::clone(f));
            }
            let remote = Arc::new(remote);
            if slot < ws.len() {
                ws[slot] = remote;
            } else {
                ws.push(remote);
            }
        }
        {
            let mut book = self.book.lock().unwrap();
            while book.len() <= slot {
                book.push(Vec::new());
            }
        }
        {
            // budgets survive re-announces: a flapping worker that keeps
            // restarting does not refill its own retry tokens
            let mut budgets = self.budgets.lock().unwrap();
            while budgets.len() <= slot {
                budgets.push(Arc::new(RetryBudget::new(
                    self.cfg.retry_budget.max(1.0),
                    self.cfg.retry_refill_per_sec.max(1e-6),
                )));
            }
        }
        self.journal(durable::rec_member(&a.name, &a.rpc_addr, slot, epoch));
        eprintln!(
            "[router] member {:?} announced at {} (slot {slot}, epoch {epoch})",
            a.name, a.rpc_addr
        );
        (
            200,
            Json::obj(vec![
                ("slot", Json::num(slot as f64)),
                ("epoch", Json::num(epoch as f64)),
            ]),
        )
    }

    fn heartbeat(&self, body: &str) -> (u16, Json) {
        let parsed = match Json::parse(body) {
            Ok(j) => j,
            Err(e) => return (400, error_obj(&format!("invalid JSON body: {e}"))),
        };
        let Some(name) = parsed.at("name").as_str() else {
            return (400, error_obj("missing \"name\" field"));
        };
        let snapshot = parsed.get("snapshot").and_then(proto::snapshot_from_json);
        // live residency refresh (absent field = legacy beat: keep the
        // announce-time template set)
        let templates = parsed.get("templates").and_then(|t| {
            t.as_arr().map(|v| {
                v.iter()
                    .filter_map(|t| t.as_str().map(String::from))
                    .collect::<Vec<String>>()
            })
        });
        if self
            .membership
            .lock()
            .unwrap()
            .heartbeat(name, snapshot, templates, Instant::now())
        {
            (200, Json::obj(vec![("ok", Json::Bool(true))]))
        } else {
            (410, error_obj("unknown or dead member: re-announce"))
        }
    }

    /// `GET /v1/readyz`: readiness — liveness is not enough to serve.
    /// Ready means the router is not draining and at least one member is
    /// available to the scheduler; 503 otherwise so load balancers steer
    /// traffic away without tearing the process down.
    fn readyz(&self) -> (u16, Json) {
        let ready_members = self.ready_count();
        let ok = !self.stopping.load(Ordering::SeqCst) && ready_members >= 1;
        (
            if ok { 200 } else { 503 },
            Json::obj(vec![
                ("ready", Json::Bool(ok)),
                ("ready_members", Json::num(ready_members as f64)),
            ]),
        )
    }

    /// `GET /v1/cluster`: the membership table + aggregate load. Session
    /// ownership is overlaid per slot from the router's registry (the
    /// heartbeat snapshots are session-blind), and `retry_budget_spent`
    /// counts transport retries paid from the per-worker token buckets.
    fn cluster_body(&self) -> (u16, Json) {
        let ms = self.membership.lock().unwrap();
        let session_load = self.sessions.worker_load(ms.len());
        let mut queued = 0usize;
        let mut running = 0usize;
        let members: Vec<Json> = ms
            .members()
            .iter()
            .enumerate()
            .map(|(slot, m)| {
                let mut pairs = vec![
                    ("name", Json::str(m.name.clone())),
                    ("slot", Json::num(slot as f64)),
                    ("state", Json::str(m.state.label())),
                    ("epoch", Json::num(m.epoch as f64)),
                    ("rpc_addr", Json::str(m.rpc_addr.clone())),
                    (
                        "heartbeat_age_ms",
                        Json::num(proto::age_ms(m.last_heartbeat) as f64),
                    ),
                    ("templates", Json::num(m.templates.len() as f64)),
                ];
                let (s_open, s_rounds) = session_load.get(slot).copied().unwrap_or((0, 0));
                pairs.push(("sessions_open", Json::num(s_open as f64)));
                pairs.push(("session_rounds", Json::num(s_rounds as f64)));
                if let Some(s) = &m.snapshot {
                    queued += s.queued;
                    running += s.running;
                    pairs.push(("queued", Json::num(s.queued as f64)));
                    pairs.push(("running", Json::num(s.running as f64)));
                }
                Json::obj(pairs)
            })
            .collect();
        let ready = ms.available().iter().filter(|&&a| a).count();
        drop(ms);
        let retry_spent: u64 = self
            .budgets
            .lock()
            .unwrap()
            .iter()
            .map(|b| b.spent())
            .sum();
        (
            200,
            Json::obj(vec![
                ("members", Json::arr(members)),
                ("ready", Json::num(ready as f64)),
                ("queued", Json::num(queued as f64)),
                ("running", Json::num(running as f64)),
                (
                    "inflight",
                    Json::num(self.pending.lock().unwrap().len() as f64),
                ),
                ("completed", Json::num(self.completed() as f64)),
                ("sessions_open", Json::num(self.sessions.open_count() as f64)),
                ("retry_budget_spent", Json::num(retry_spent as f64)),
            ]),
        )
    }

    fn stats_body(&self) -> (u16, Json) {
        (
            200,
            Json::obj(vec![
                ("completed", Json::num(self.completed() as f64)),
                ("uptime_secs", Json::num(self.elapsed())),
                (
                    "members",
                    Json::num(self.membership.lock().unwrap().len() as f64),
                ),
                ("ready", Json::num(self.ready_count() as f64)),
                (
                    "inflight",
                    Json::num(self.pending.lock().unwrap().len() as f64),
                ),
                ("sessions_open", Json::num(self.sessions.open_count() as f64)),
            ]),
        )
    }

    /// Parse + validate a submit body (same schema as the in-process
    /// frontend's `POST /v1/edits`). `default_priority` applies when the
    /// body names none — session rounds default to interactive.
    fn build_request(
        &self,
        body: &str,
        default_priority: Priority,
    ) -> Result<EditRequest, (u16, Json)> {
        let j = Json::parse(body)
            .map_err(|e| (400, error_obj(&format!("invalid JSON body: {e}"))))?;
        let template = j.at("template").as_str().unwrap_or("tpl-0").to_string();
        let ratio = j.at("mask_ratio").as_f64().unwrap_or(0.15);
        let seed = j.at("prompt_seed").as_f64().unwrap_or(0.0) as u64;
        let priority = match j.at("priority").as_str() {
            None => default_priority,
            Some(s) => Priority::parse(s).ok_or_else(|| {
                (
                    400,
                    error_obj(&format!(
                        "unknown priority {s:?} (interactive | standard | batch)"
                    )),
                )
            })?,
        };
        let deadline_ms = j.at("deadline_ms").as_f64().map(|ms| ms.max(0.0) as u64);
        let hw = self.model.latent_hw;
        let mut builder = EditRequestBuilder::new(0)
            .template(template)
            .prompt_seed(seed)
            .priority(priority);
        if let Some(ms) = deadline_ms {
            builder = builder.deadline_ms(ms);
        }
        let mut req = builder
            .synth_mask(hw, ratio)
            .and_then(|b| b.expect_tokens(hw * hw).build())
            .map_err(|e| edit_error_reply(&e))?;
        req.id = self.next_id.fetch_add(1, Ordering::Relaxed);
        Ok(req)
    }

    /// A repeated `Idempotency-Key` replays the original ticket (202 with
    /// `idempotent: true` and the request's current status). The journal's
    /// accepted records rebuild the key map on recovery, so the replay
    /// survives a router crash or standby failover.
    fn idempotent_replay(&self, idem: Option<&str>, sid: Option<u64>) -> Option<(u16, Json)> {
        let id = self.idem.get(idem?)?;
        let label = self
            .registry
            .status(id)
            .map(|s| s.state.label().to_string())
            .unwrap_or_else(|| "queued".to_string());
        let mut pairs = vec![
            ("id", Json::num(id as f64)),
            ("status", Json::str(label)),
            ("status_url", Json::str(format!("/v1/edits/{id}"))),
            ("idempotent", Json::Bool(true)),
        ];
        if let Some(sid) = sid {
            pairs.push(("session", Json::num(sid as f64)));
        }
        Some((202, Json::obj(pairs)))
    }

    fn edit_async(&self, body: &str, idem: Option<&str>) -> (u16, Json) {
        if let Some(reply) = self.idempotent_replay(idem, None) {
            return reply;
        }
        let req = match self.build_request(body, Priority::default()) {
            Ok(r) => r,
            Err(reply) => return reply,
        };
        match self.submit_guarded_inner(req, idem) {
            Ok(t) => (
                202,
                Json::obj(vec![
                    ("id", Json::num(t.id() as f64)),
                    ("status", Json::str("queued")),
                    ("status_url", Json::str(format!("/v1/edits/{}", t.id()))),
                ]),
            ),
            Err(e) => edit_error_reply(&e),
        }
    }

    /// `/v1/sessions*` dispatch (`rest` is `""` or starts with `/`).
    /// Same surface as the in-process frontend, minus SSE (not proxied).
    fn sessions_route(&self, method: &str, rest: &str, body: &str, idem: Option<&str>) -> (u16, Json) {
        if rest.is_empty() {
            return match method {
                "POST" => self.session_open(body),
                _ => (405, error_obj("method not allowed")),
            };
        }
        let rest = &rest[1..]; // strip the leading '/'
        let (sid_str, tail) = match rest.find('/') {
            Some(i) => (&rest[..i], &rest[i..]),
            None => (rest, ""),
        };
        let Ok(sid) = sid_str.parse::<u64>() else {
            return (400, error_obj(&format!("bad session id {sid_str:?}")));
        };
        match (method, tail) {
            ("GET", "") => match self.sessions.status(sid) {
                Some(st) => (200, session_status_body(&st)),
                None => (404, error_obj(&format!("no such session {sid}"))),
            },
            ("DELETE", "") => self.session_close(sid),
            ("POST", "/rounds") => self.session_round(sid, body, idem),
            ("GET", t) if t.starts_with("/rounds/") && t.ends_with("/events") => (
                501,
                error_obj(
                    "progress streams are served by the worker-local frontend; \
                     the router does not proxy SSE",
                ),
            ),
            _ => (404, error_obj("not found")),
        }
    }

    /// `POST /v1/sessions`: open a session. The router keeps no template
    /// registry — template admission (and residency) is the workers' job,
    /// surfaced as a typed reject when the first round lands.
    fn session_open(&self, body: &str) -> (u16, Json) {
        let j = match Json::parse(body) {
            Ok(j) => j,
            Err(e) => return (400, error_obj(&format!("invalid JSON body: {e}"))),
        };
        let template = j.at("template").as_str().unwrap_or("tpl-0").to_string();
        let sid = self.sessions.open(&template);
        self.journal(durable::rec_session_open(sid, &template));
        (
            201,
            Json::obj(vec![
                ("session", Json::num(sid as f64)),
                ("template", Json::str(template)),
                ("state", Json::str("open")),
                ("status_url", Json::str(format!("/v1/sessions/{sid}"))),
            ]),
        )
    }

    /// `POST /v1/sessions/{id}/rounds`: admit one round against the
    /// session (delta-mask verdict, affinity hint), then place it through
    /// the guarded submit path. Priority defaults to `interactive`.
    fn session_round(&self, sid: u64, body: &str, idem: Option<&str>) -> (u16, Json) {
        if let Some(reply) = self.idempotent_replay(idem, Some(sid)) {
            return reply;
        }
        let mut req = match self.build_request(body, Priority::Interactive) {
            Ok(r) => r,
            Err(reply) => return reply,
        };
        let Some(st) = self.sessions.status(sid) else {
            return session_error_reply(&SessionError::Unknown(sid));
        };
        req.template_id = st.template;
        req.session = Some(sid);
        let plan = match self.sessions.begin_round(sid, req.id, &req.mask) {
            Ok(p) => p,
            Err(e) => return session_error_reply(&e),
        };
        let rid = req.id;
        let outstanding = self.outstanding_for(&req);
        let _gate = self.admission_gate.lock().unwrap();
        if let Err(e) = self.assess_admission(&req, &outstanding) {
            self.sessions.abort_round(rid);
            return edit_error_reply(&e);
        }
        match self.submit_inner(req, idem) {
            Ok(ticket) => {
                self.journal(durable::rec_session_round(sid, rid));
                (
                    202,
                    Json::obj(vec![
                        ("id", Json::num(rid as f64)),
                        ("session", Json::num(sid as f64)),
                        ("round", Json::num(plan.round as f64)),
                        ("warm", Json::Bool(plan.warm)),
                        ("worker", Json::num(ticket.worker() as f64)),
                        ("status_url", Json::str(format!("/v1/edits/{rid}"))),
                    ]),
                )
            }
            Err(e) => {
                self.sessions.abort_round(rid);
                edit_error_reply(&e)
            }
        }
    }

    /// `DELETE /v1/sessions/{id}`: refuse further rounds immediately.
    /// In-flight rounds resolve through the pump — the router holds no
    /// template pin, so there is nothing to release synchronously.
    fn session_close(&self, sid: u64) -> (u16, Json) {
        match self.sessions.close(sid) {
            Err(e) => session_error_reply(&e),
            Ok((template, inflight)) => {
                self.journal(durable::rec_session_close(sid));
                (
                    200,
                    Json::obj(vec![
                        ("session", Json::num(sid as f64)),
                        ("template", Json::str(template)),
                        ("state", Json::str("closed")),
                        ("inflight", Json::num(inflight as f64)),
                    ]),
                )
            }
        }
    }

    /// The slot currently holding `id` (follows failovers, unlike the
    /// registry's original worker field).
    fn slot_of_request(&self, id: u64) -> Option<usize> {
        let book = self.book.lock().unwrap();
        book.iter().position(|lane| lane.iter().any(|o| o.id == id))
    }

    fn edit_by_id(&self, method: &str, id: u64) -> (u16, Json) {
        match method {
            "GET" => match self.registry.status(id) {
                None => (404, error_obj(&format!("no such request {id}"))),
                Some(st) => {
                    let reply = match &st.state {
                        RequestState::Done(resp) => {
                            done_body(id, st.worker, st.age_secs, st.deadline_ms, resp)
                        }
                        RequestState::Failed(err) => {
                            let mut pairs =
                                status_pairs(id, st.state.label(), st.worker, st.age_secs);
                            push_qos_pairs(&mut pairs, st.priority, st.deadline_ms);
                            if *err != EditError::Cancelled {
                                pairs.push(("error", Json::str(err.to_string())));
                                pairs.push(("error_kind", Json::str(err.kind())));
                            }
                            Json::obj(pairs)
                        }
                        _ => {
                            let mut pairs =
                                status_pairs(id, st.state.label(), st.worker, st.age_secs);
                            push_qos_pairs(&mut pairs, st.priority, st.deadline_ms);
                            Json::obj(pairs)
                        }
                    };
                    (200, reply)
                }
            },
            "DELETE" => self.cancel(id),
            _ => (405, error_obj("method not allowed")),
        }
    }

    fn cancel(&self, id: u64) -> (u16, Json) {
        let Some(st) = self.registry.status(id) else {
            return (404, error_obj(&format!("no such request {id}")));
        };
        if st.state.is_terminal() {
            // result already delivered: evict the retained entry
            return if self.registry.evict_terminal(id) {
                (
                    200,
                    Json::obj(vec![
                        ("id", Json::num(id as f64)),
                        ("status", Json::str("evicted")),
                    ]),
                )
            } else {
                (404, error_obj(&format!("no such request {id}")))
            };
        }
        let slot = self.slot_of_request(id).unwrap_or(st.worker);
        let Some(remote) = self.workers.lock().unwrap().get(slot).cloned() else {
            return (404, error_obj(&format!("no member holds request {id}")));
        };
        match remote.cancel(id) {
            Err(_) => (
                502,
                error_obj("member unreachable; the failure detector will resolve the request"),
            ),
            Ok((status, reply)) => match reply.at("status").as_str() {
                // the worker dropped it (cancelled while queued, or its
                // terminal copy was evicted): resolve our ticket now
                Some("cancelled") | Some("evicted") => {
                    self.journal(durable::rec_req_state(id, "cancelled"));
                    self.sessions.complete_round(id, false, None);
                    self.registry.fulfill(id, Err(EditError::Cancelled));
                    self.clear_entry(slot, id);
                    (
                        200,
                        Json::obj(vec![
                            ("id", Json::num(id as f64)),
                            ("status", Json::str("cancelled")),
                        ]),
                    )
                }
                // "cancelling" (or a refusal): the pump picks up the
                // worker's verdict on a later cycle
                _ => (status, reply),
            },
        }
    }

    /// `POST /v1/drain/{name}`: live drain — the member finishes what it
    /// holds, receives no new work, and keeps heartbeating.
    fn drain(&self, name: &str) -> (u16, Json) {
        let slot = {
            let mut ms = self.membership.lock().unwrap();
            if !ms.begin_drain(name) {
                return (404, error_obj(&format!("no such member {name:?}")));
            }
            ms.slot_of(name)
        };
        let remote = slot.and_then(|s| self.workers.lock().unwrap().get(s).cloned());
        let acked = remote.map(|r| r.drain().is_ok()).unwrap_or(false);
        (
            200,
            Json::obj(vec![
                ("name", Json::str(name)),
                ("state", Json::str("draining")),
                ("worker_acked", Json::Bool(acked)),
            ]),
        )
    }

    fn live_remotes(&self) -> Vec<Arc<RemoteWorker>> {
        let ms = self.membership.lock().unwrap();
        let ws = self.workers.lock().unwrap();
        ms.members()
            .iter()
            .enumerate()
            .filter(|(_, m)| m.state != MemberState::Dead)
            .filter_map(|(slot, _)| ws.get(slot).cloned())
            .collect()
    }

    /// `POST /v1/templates`: fan a registration out to every live member.
    fn template_register(&self, body: &str) -> (u16, Json) {
        let parsed = match Json::parse(body) {
            Ok(j) => j,
            Err(e) => return (400, error_obj(&format!("invalid JSON body: {e}"))),
        };
        let Some(template) = parsed.at("template").as_str() else {
            return (400, error_obj("missing \"template\" field"));
        };
        let mut reached = 0usize;
        for remote in self.live_remotes() {
            if remote.register_template(template).is_ok() {
                reached += 1;
            }
        }
        self.journal(durable::rec_template(template, "registering"));
        (
            202,
            Json::obj(vec![
                ("template", Json::str(template)),
                ("state", Json::str("registering")),
                ("members", Json::num(reached as f64)),
            ]),
        )
    }

    /// `DELETE /v1/templates/{id}`: fan a purge out to every live member.
    fn template_purge(&self, template_id: &str) -> (u16, Json) {
        let mut reached = 0usize;
        for remote in self.live_remotes() {
            if remote.purge_template(template_id).is_ok() {
                reached += 1;
            }
        }
        self.journal(durable::rec_template(template_id, "retiring"));
        (
            200,
            Json::obj(vec![
                ("template", Json::str(template_id)),
                ("state", Json::str("retiring")),
                ("members", Json::num(reached as f64)),
            ]),
        )
    }
}
