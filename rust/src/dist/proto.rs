//! Wire protocol for the dist data plane: typed request/response structs
//! serialized over the repo's own JSON ([`crate::util::json`]).
//!
//! Bit-identity across the wire is load-bearing: `Json` prints `f64`s with
//! shortest-roundtrip formatting, so an `f32` widened to `f64`, printed,
//! parsed, and narrowed back is *exactly* the original bits. Submissions
//! carry the explicit masked token ids (not the mask ratio), and poll
//! replies carry the full latent/image tensors, so a remote cluster's
//! results compare equal (`max_abs_diff == 0`) to the in-process one.
//!
//! Errors cross the wire as their stable [`EditError::kind`] tag plus the
//! display message; [`decode_error`] maps the tag back to the typed
//! variant, so the router's tickets resolve with the same `EditError` the
//! worker produced.

use std::time::{Duration, Instant};

use crate::engine::request::{EditError, EditRequest, EditResponse, RequestTiming};
use crate::engine::worker::WorkerSnapshot;
use crate::model::MaskSpec;
use crate::qos::{ClassDepth, Priority, CLASS_COUNT};
use crate::runtime::TransferTotals;
use crate::util::json::Json;
use crate::util::tensor::Tensor;

/// One edit submission on the wire (`POST /rpc/submit`). Carries the
/// explicit masked ids so the worker reconstructs the *identical*
/// [`MaskSpec`] — no re-sampling, no drift.
#[derive(Debug, Clone, PartialEq)]
pub struct SubmitWire {
    pub id: u64,
    pub template: String,
    pub masked: Vec<usize>,
    pub tokens: usize,
    pub prompt_seed: u64,
    pub priority: Priority,
    pub deadline_ms: Option<u64>,
    /// Session the request is a round of (absent for plain edits; older
    /// peers ignore the field, so the wire stays parse-tolerant).
    pub session: Option<u64>,
}

impl SubmitWire {
    pub fn from_request(req: &EditRequest) -> SubmitWire {
        SubmitWire {
            id: req.id,
            template: req.template_id.clone(),
            masked: req.mask.masked_ids().to_vec(),
            tokens: req.mask.tokens(),
            prompt_seed: req.prompt_seed,
            priority: req.priority,
            deadline_ms: req.deadline_ms(),
            session: req.session,
        }
    }

    /// Rebuild the request on the worker side. The deadline restarts from
    /// the worker's arrival instant (queue time on the router side is not
    /// double-counted against it).
    pub fn into_request(&self) -> EditRequest {
        let mask = MaskSpec::new(self.masked.clone(), self.tokens);
        let mut req = EditRequest::new(self.id, self.template.clone(), mask, self.prompt_seed);
        req.priority = self.priority;
        req.deadline = self
            .deadline_ms
            .map(|ms| req.arrival + Duration::from_millis(ms));
        req.session = self.session;
        req
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("id", Json::num(self.id as f64)),
            ("template", Json::str(self.template.clone())),
            (
                "masked",
                Json::arr(self.masked.iter().map(|&m| Json::num(m as f64)).collect()),
            ),
            ("tokens", Json::num(self.tokens as f64)),
            ("prompt_seed", Json::num(self.prompt_seed as f64)),
            ("priority", Json::str(self.priority.label())),
        ];
        if let Some(ms) = self.deadline_ms {
            pairs.push(("deadline_ms", Json::num(ms as f64)));
        }
        if let Some(sid) = self.session {
            pairs.push(("session", Json::num(sid as f64)));
        }
        Json::obj(pairs)
    }

    pub fn parse(j: &Json) -> Option<SubmitWire> {
        let tokens = j.at("tokens").as_usize()?;
        let masked = j.at("masked").usize_list();
        if masked.is_empty() || masked.iter().any(|&m| m >= tokens) {
            return None;
        }
        Some(SubmitWire {
            id: j.at("id").as_f64()? as u64,
            template: j.at("template").as_str()?.to_string(),
            masked,
            tokens,
            prompt_seed: j.at("prompt_seed").as_f64()? as u64,
            priority: j
                .at("priority")
                .as_str()
                .and_then(Priority::parse)
                .unwrap_or_default(),
            deadline_ms: j.at("deadline_ms").as_f64().map(|ms| ms as u64),
            session: j.at("session").as_f64().map(|s| s as u64),
        })
    }
}

/// Exact tensor round-trip: `{"shape": [...], "data": [...]}` with
/// shortest-roundtrip floats.
pub fn tensor_to_json(t: &Tensor) -> Json {
    Json::obj(vec![
        (
            "shape",
            Json::arr(t.shape().iter().map(|&d| Json::num(d as f64)).collect()),
        ),
        (
            "data",
            Json::arr(t.data().iter().map(|&v| Json::num(v as f64)).collect()),
        ),
    ])
}

pub fn tensor_from_json(j: &Json) -> Option<Tensor> {
    let shape = j.at("shape").usize_list();
    let data: Vec<f32> = j
        .at("data")
        .as_arr()?
        .iter()
        .map(|v| v.as_f64().map(|x| x as f32))
        .collect::<Option<Vec<f32>>>()?;
    Tensor::from_vec(&shape, data).ok()
}

/// Encode a typed failure for the wire: stable tag + message (+ the
/// overload retry hint).
pub fn encode_error(e: &EditError) -> Json {
    let mut pairs = vec![
        ("error", Json::str(e.to_string())),
        ("error_kind", Json::str(e.kind())),
    ];
    if let EditError::Overloaded { retry_after_ms } = e {
        pairs.push(("retry_after_ms", Json::num(*retry_after_ms as f64)));
    }
    Json::obj(pairs)
}

/// Decode a wire failure back into the typed variant (unknown tags fall
/// back to `Internal` so a newer peer can't wedge an older router).
pub fn decode_error(j: &Json) -> EditError {
    let msg = j.at("error").as_str().unwrap_or("remote error").to_string();
    match j.at("error_kind").as_str().unwrap_or("internal") {
        "unknown_template" => EditError::UnknownTemplate(msg),
        "template_retired" => EditError::TemplateRetired(msg),
        "invalid_mask" => EditError::InvalidMask(msg),
        "cancelled" => EditError::Cancelled,
        "timeout" => EditError::Timeout,
        "overloaded" => EditError::Overloaded {
            retry_after_ms: j.at("retry_after_ms").as_f64().unwrap_or(1000.0) as u64,
        },
        "deadline_infeasible" => EditError::DeadlineInfeasible(msg),
        "deadline_exceeded" => EditError::DeadlineExceeded,
        "worker_shutdown" => EditError::WorkerShutdown,
        "worker_lost" => EditError::WorkerLost,
        _ => EditError::Internal(msg),
    }
}

/// A polled request's remote state (`GET /rpc/poll/{id}`).
#[derive(Debug, Clone)]
pub enum PollState {
    Queued,
    Running,
    Done(Box<EditResponse>),
    Failed(EditError),
    /// The worker has no entry for the id (restarted, or already
    /// evicted) — the router treats it like a lost request.
    Unknown,
}

/// Encode one response payload (timing + full tensors).
pub fn response_to_json(resp: &EditResponse) -> Json {
    let t = &resp.timing;
    Json::obj(vec![
        ("id", Json::num(resp.id as f64)),
        ("template", Json::str(resp.template_id.clone())),
        ("mask_ratio", Json::num(resp.mask_ratio)),
        ("priority", Json::str(resp.priority.label())),
        (
            "timing",
            Json::obj(vec![
                ("queue", Json::num(t.queue)),
                ("inference", Json::num(t.inference)),
                ("e2e", Json::num(t.e2e)),
                ("interruptions", Json::num(t.interruptions as f64)),
                ("steps_computed", Json::num(t.steps_computed as f64)),
            ]),
        ),
        ("latent", tensor_to_json(&resp.latent)),
        ("image", tensor_to_json(&resp.image)),
    ])
}

pub fn response_from_json(j: &Json) -> Option<EditResponse> {
    let t = j.at("timing");
    Some(EditResponse {
        id: j.at("id").as_f64()? as u64,
        template_id: j.at("template").as_str()?.to_string(),
        image: tensor_from_json(j.at("image"))?,
        latent: tensor_from_json(j.at("latent"))?,
        timing: RequestTiming {
            queue: t.at("queue").as_f64().unwrap_or(0.0),
            inference: t.at("inference").as_f64().unwrap_or(0.0),
            e2e: t.at("e2e").as_f64().unwrap_or(0.0),
            interruptions: t.at("interruptions").as_f64().unwrap_or(0.0) as u32,
            steps_computed: t.at("steps_computed").as_f64().unwrap_or(0.0) as u32,
        },
        mask_ratio: j.at("mask_ratio").as_f64().unwrap_or(0.0),
        priority: j
            .at("priority")
            .as_str()
            .and_then(Priority::parse)
            .unwrap_or_default(),
    })
}

/// Encode a poll reply from the worker's local registry state.
pub fn poll_state_to_json(state: &PollState) -> Json {
    match state {
        PollState::Queued => Json::obj(vec![("status", Json::str("queued"))]),
        PollState::Running => Json::obj(vec![("status", Json::str("running"))]),
        PollState::Done(resp) => Json::obj(vec![
            ("status", Json::str("done")),
            ("response", response_to_json(resp)),
        ]),
        PollState::Failed(e) => Json::obj(vec![
            ("status", Json::str("failed")),
            ("failure", encode_error(e)),
        ]),
        PollState::Unknown => Json::obj(vec![("status", Json::str("unknown"))]),
    }
}

pub fn poll_state_from_json(j: &Json) -> PollState {
    match j.at("status").as_str().unwrap_or("unknown") {
        "queued" => PollState::Queued,
        "running" => PollState::Running,
        "done" => match response_from_json(j.at("response")) {
            Some(resp) => PollState::Done(Box::new(resp)),
            None => PollState::Failed(EditError::Internal(
                "undecodable response payload".into(),
            )),
        },
        "failed" => PollState::Failed(decode_error(j.at("failure"))),
        _ => PollState::Unknown,
    }
}

/// [`WorkerSnapshot`] on the wire (heartbeat payload / `GET /rpc/snapshot`).
pub fn snapshot_to_json(s: &WorkerSnapshot) -> Json {
    let classes = s
        .class_depths
        .iter()
        .map(|c| {
            Json::obj(vec![
                ("queued", Json::num(c.queued as f64)),
                ("oldest_wait_secs", Json::num(c.oldest_wait_secs)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("worker_id", Json::num(s.worker_id as f64)),
        ("queued", Json::num(s.queued as f64)),
        ("running", Json::num(s.running as f64)),
        ("queued_masked_tokens", Json::num(s.queued_masked_tokens as f64)),
        (
            "mask_ratios",
            Json::arr(s.mask_ratios.iter().map(|&r| Json::num(r)).collect()),
        ),
        ("class_depths", Json::arr(classes)),
        ("steps_executed", Json::num(s.steps_executed as f64)),
        ("sessions_open", Json::num(s.sessions_open as f64)),
        ("session_rounds", Json::num(s.session_rounds as f64)),
        (
            "transfers",
            Json::obj(vec![
                ("h2d_ops", Json::num(s.transfers.h2d_ops as f64)),
                ("d2h_ops", Json::num(s.transfers.d2h_ops as f64)),
                ("h2d_bytes", Json::num(s.transfers.h2d_bytes as f64)),
                ("d2h_bytes", Json::num(s.transfers.d2h_bytes as f64)),
                ("kv_h2d_bytes", Json::num(s.transfers.kv_h2d_bytes as f64)),
                ("kv_dev_hits", Json::num(s.transfers.kv_dev_hits as f64)),
                ("kv_dev_misses", Json::num(s.transfers.kv_dev_misses as f64)),
                ("kv_prefetch_overlap_us", Json::num(s.transfers.kv_prefetch_overlap_us as f64)),
                ("cache_degraded_disk", Json::num(s.transfers.cache_degraded_disk as f64)),
                ("cache_degraded_device", Json::num(s.transfers.cache_degraded_device as f64)),
                ("cache_degraded_loader", Json::num(s.transfers.cache_degraded_loader as f64)),
            ]),
        ),
    ])
}

pub fn snapshot_from_json(j: &Json) -> Option<WorkerSnapshot> {
    let mut class_depths = [ClassDepth::default(); CLASS_COUNT];
    if let Some(arr) = j.at("class_depths").as_arr() {
        for (slot, c) in class_depths.iter_mut().zip(arr) {
            slot.queued = c.at("queued").as_usize().unwrap_or(0);
            slot.oldest_wait_secs = c.at("oldest_wait_secs").as_f64().unwrap_or(0.0);
        }
    }
    let t = j.at("transfers");
    Some(WorkerSnapshot {
        worker_id: j.at("worker_id").as_usize()?,
        queued: j.at("queued").as_usize().unwrap_or(0),
        running: j.at("running").as_usize().unwrap_or(0),
        queued_masked_tokens: j.at("queued_masked_tokens").as_usize().unwrap_or(0),
        mask_ratios: j
            .at("mask_ratios")
            .as_arr()
            .map(|v| v.iter().filter_map(Json::as_f64).collect())
            .unwrap_or_default(),
        class_depths,
        steps_executed: j.at("steps_executed").as_usize().unwrap_or(0),
        // absent on older peers: default to 0 (parse-tolerant both ways)
        sessions_open: j.at("sessions_open").as_usize().unwrap_or(0),
        session_rounds: j.at("session_rounds").as_usize().unwrap_or(0),
        transfers: TransferTotals {
            h2d_ops: t.at("h2d_ops").as_f64().unwrap_or(0.0) as u64,
            d2h_ops: t.at("d2h_ops").as_f64().unwrap_or(0.0) as u64,
            h2d_bytes: t.at("h2d_bytes").as_f64().unwrap_or(0.0) as u64,
            d2h_bytes: t.at("d2h_bytes").as_f64().unwrap_or(0.0) as u64,
            kv_h2d_bytes: t.at("kv_h2d_bytes").as_f64().unwrap_or(0.0) as u64,
            kv_dev_hits: t.at("kv_dev_hits").as_f64().unwrap_or(0.0) as u64,
            kv_dev_misses: t.at("kv_dev_misses").as_f64().unwrap_or(0.0) as u64,
            kv_prefetch_overlap_us: t.at("kv_prefetch_overlap_us").as_f64().unwrap_or(0.0)
                as u64,
            // absent on older peers: the ladder never fired there
            cache_degraded_disk: t.at("cache_degraded_disk").as_f64().unwrap_or(0.0) as u64,
            cache_degraded_device: t.at("cache_degraded_device").as_f64().unwrap_or(0.0) as u64,
            cache_degraded_loader: t.at("cache_degraded_loader").as_f64().unwrap_or(0.0) as u64,
        },
    })
}

/// Worker → router announce body (`POST /rpc/announce`).
#[derive(Debug, Clone)]
pub struct Announce {
    pub name: String,
    pub rpc_addr: String,
    /// Templates the worker can serve right now (router-side residency).
    pub templates: Vec<String>,
}

impl Announce {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("rpc_addr", Json::str(self.rpc_addr.clone())),
            (
                "templates",
                Json::arr(self.templates.iter().map(Json::str).collect()),
            ),
        ])
    }

    pub fn parse(j: &Json) -> Option<Announce> {
        Some(Announce {
            name: j.at("name").as_str()?.to_string(),
            rpc_addr: j.at("rpc_addr").as_str()?.to_string(),
            templates: j
                .at("templates")
                .as_arr()
                .map(|v| v.iter().filter_map(|t| t.as_str().map(String::from)).collect())
                .unwrap_or_default(),
        })
    }
}

/// Milliseconds elapsed on an `Instant`, for heartbeat-age reporting.
pub fn age_ms(at: Instant) -> u64 {
    at.elapsed().as_millis() as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    #[test]
    fn submit_wire_round_trips() {
        let mut rng = Pcg::new(5);
        let mask = MaskSpec::synth(8, 0.2, &mut rng);
        let mut req = EditRequest::new(42, "tpl-3", mask, 99);
        req.priority = Priority::Interactive;
        req.session = Some(6);
        let wire = SubmitWire::from_request(&req);
        let text = wire.to_json().to_string();
        let back = SubmitWire::parse(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(wire, back);
        let rebuilt = back.into_request();
        assert_eq!(rebuilt.mask, req.mask, "mask must be identical, not re-sampled");
        assert_eq!(rebuilt.prompt_seed, 99);
        assert_eq!(rebuilt.priority, Priority::Interactive);
        assert_eq!(rebuilt.session, Some(6));
        // sessionless submissions omit the field entirely
        let plain = SubmitWire::from_request(&EditRequest::new(
            1,
            "t",
            MaskSpec::new(vec![0], 64),
            0,
        ));
        assert!(!plain.to_json().to_string().contains("session"));
        // malformed: masked id out of range
        let bad = Json::parse(
            r#"{"id":1,"template":"t","masked":[64],"tokens":64,"prompt_seed":1}"#,
        )
        .unwrap();
        assert!(SubmitWire::parse(&bad).is_none());
    }

    #[test]
    fn tensor_round_trip_is_bit_exact() {
        let data: Vec<f32> = (0..64)
            .map(|i| (i as f32 * 0.37).sin() * 1e-3 + f32::EPSILON * i as f32)
            .collect();
        let t = Tensor::from_vec(&[8, 8], data).unwrap();
        let text = tensor_to_json(&t).to_string();
        let back = tensor_from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(t, back, "f32 -> JSON -> f32 must round-trip exactly");
        assert_eq!(t.max_abs_diff(&back), 0.0);
    }

    #[test]
    fn errors_round_trip_typed() {
        for e in [
            EditError::UnknownTemplate("tpl-9".into()),
            EditError::Cancelled,
            EditError::Overloaded { retry_after_ms: 750 },
            EditError::WorkerShutdown,
            EditError::WorkerLost,
        ] {
            let text = encode_error(&e).to_string();
            let back = decode_error(&Json::parse(&text).unwrap());
            assert_eq!(back.kind(), e.kind(), "{e:?}");
            if let EditError::Overloaded { retry_after_ms } = back {
                assert_eq!(retry_after_ms, 750);
            }
        }
    }

    #[test]
    fn poll_and_snapshot_round_trip() {
        let resp = EditResponse {
            id: 7,
            template_id: "tpl-1".into(),
            image: Tensor::from_vec(&[2, 2], vec![0.1, -0.2, 0.3, 0.4]).unwrap(),
            latent: Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.5]).unwrap(),
            timing: RequestTiming { queue: 0.1, inference: 0.2, e2e: 0.3, interruptions: 1, steps_computed: 8 },
            mask_ratio: 0.25,
            priority: Priority::Batch,
        };
        let text = poll_state_to_json(&PollState::Done(Box::new(resp.clone()))).to_string();
        match poll_state_from_json(&Json::parse(&text).unwrap()) {
            PollState::Done(back) => {
                assert_eq!(back.latent, resp.latent);
                assert_eq!(back.image, resp.image);
                assert_eq!(back.priority, Priority::Batch);
                assert_eq!(back.timing.steps_computed, 8);
            }
            other => panic!("expected Done, got {other:?}"),
        }
        let snap = WorkerSnapshot {
            worker_id: 0,
            queued: 3,
            running: 2,
            queued_masked_tokens: 77,
            mask_ratios: vec![0.1, 0.4],
            class_depths: [
                ClassDepth { queued: 1, oldest_wait_secs: 0.5 },
                ClassDepth::default(),
                ClassDepth { queued: 2, oldest_wait_secs: 1.5 },
            ],
            steps_executed: 123,
            sessions_open: 2,
            session_rounds: 1,
            transfers: TransferTotals {
                h2d_ops: 4,
                d2h_ops: 5,
                h2d_bytes: 6,
                d2h_bytes: 7,
                kv_h2d_bytes: 8,
                kv_dev_hits: 9,
                kv_dev_misses: 10,
                kv_prefetch_overlap_us: 11,
                cache_degraded_disk: 12,
                cache_degraded_device: 13,
                cache_degraded_loader: 14,
            },
        };
        let text = snapshot_to_json(&snap).to_string();
        let back = snapshot_from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.queued, 3);
        assert_eq!(back.class_depths[2].queued, 2);
        assert_eq!(back.transfers, snap.transfers);
        assert_eq!(back.mask_ratios, snap.mask_ratios);
        assert_eq!((back.sessions_open, back.session_rounds), (2, 1));
        // a snapshot from an older peer (no session fields) still parses
        let legacy = Json::parse(r#"{"worker_id":0,"queued":1}"#).unwrap();
        let back = snapshot_from_json(&legacy).unwrap();
        assert_eq!((back.sessions_open, back.session_rounds), (0, 0));
    }
}
