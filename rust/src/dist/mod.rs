//! Distributed serving plane: process-separated workers over an RPC data
//! plane, with membership, live drain, and failover.
//!
//! The in-process [`Cluster`](crate::cluster::Cluster) multiplexes worker
//! *threads* inside one address space. This module splits the same
//! serving stack across *processes*:
//!
//! * [`Router`] — the front process. Owns the public `/v1/*` API, the
//!   scheduler/QoS admission (unchanged from the in-process plane), the
//!   request registry, and the [`Membership`] table with its failure
//!   detector and failover logic.
//! * [`WorkerNode`] — a worker process. Wraps a single-worker cluster
//!   (engine, caches, template lifecycle all unchanged) behind `/rpc/*`
//!   endpoints, announces itself to the router, and heartbeats its load
//!   snapshot.
//! * The wire layer — [`proto`] (typed JSON encodings: [`SubmitWire`],
//!   [`PollState`], snapshots, typed errors) over [`rpc`] (a keep-alive
//!   HTTP/1.1 client, [`RpcClient`]). Everything rides the existing
//!   pure-Rust HTTP server and JSON codec; no new dependencies, and the
//!   shortest-roundtrip float encoding makes remote results **bit
//!   identical** to in-process ones.
//!
//! The deterministic engine is what makes failover cheap: a still-queued
//! request lost with its worker is simply re-submitted to a
//! residency-compatible peer and recomputes the identical result; only
//! work that was already *running* on the lost member resolves to the
//! typed [`WorkerLost`](crate::engine::request::EditError::WorkerLost)
//! error. No ticket ever hangs.

pub mod membership;
pub mod node;
pub mod proto;
pub mod remote;
pub mod router;
pub mod rpc;

pub use membership::{Member, MemberState, Membership};
pub use node::WorkerNode;
pub use proto::{Announce, PollState, SubmitWire};
pub use remote::{RemoteWorker, SubmitOutcome};
pub use router::Router;
pub use rpc::{RpcClient, RpcError};

/// Timing knobs of the distributed plane. The defaults suit a LAN
/// deployment; tests shrink them to keep the failure-injection paths
/// fast.
#[derive(Debug, Clone)]
pub struct DistConfig {
    /// Worker → router heartbeat cadence.
    pub heartbeat_ms: u64,
    /// Heartbeat silence after which a member is suspect (unavailable to
    /// the scheduler, not yet failed over).
    pub suspect_after_ms: u64,
    /// Heartbeat silence after which a member is declared dead and its
    /// requests fail over. Must be ≥ `suspect_after_ms`.
    pub dead_after_ms: u64,
    /// Router supervisor cadence (failure detection + result pump).
    pub poll_ms: u64,
    /// Per-call RPC read/write timeout.
    pub rpc_timeout_ms: u64,
    /// Per-worker retry-budget capacity (tokens; one token = one
    /// transport retry). A flapping worker drains its own budget without
    /// starving retries toward healthy peers.
    pub retry_budget: f64,
    /// Budget refill rate, tokens per second.
    pub retry_refill_per_sec: f64,
    /// Jittered-backoff base between retries toward the same worker.
    pub retry_backoff_base_ms: u64,
    /// Backoff ceiling (the exponential doubling saturates here).
    pub retry_backoff_cap_ms: u64,
    /// Max budgeted retries per placement attempt before the worker is
    /// banned for this request and placement moves on.
    pub retry_attempts: u32,
    /// Deterministic transport fault injection on the router's RPC
    /// clients (None in production).
    pub faults: Option<crate::faults::FaultPlan>,
    /// Write-ahead journal directory for the router's durable control
    /// plane (None: volatile, the pre-journal behavior).
    pub journal_dir: Option<std::path::PathBuf>,
    /// When acknowledged journal appends reach the platter.
    pub journal_fsync: crate::durable::FsyncPolicy,
    /// Journal segment rotation threshold.
    pub journal_segment_bytes: u64,
    /// Compact the journal into a snapshot every this many records.
    pub journal_snapshot_every: u64,
    /// Max unsynced window under the batched fsync policy.
    pub journal_batch_ms: u64,
    /// Standby: journal-tail silence from the primary after which the
    /// standby takes over.
    pub standby_takeover_ms: u64,
}

impl Default for DistConfig {
    fn default() -> DistConfig {
        DistConfig {
            heartbeat_ms: 500,
            suspect_after_ms: 2_000,
            dead_after_ms: 5_000,
            poll_ms: 100,
            rpc_timeout_ms: 10_000,
            retry_budget: 10.0,
            retry_refill_per_sec: 1.0,
            retry_backoff_base_ms: 10,
            retry_backoff_cap_ms: 500,
            retry_attempts: 3,
            faults: None,
            journal_dir: None,
            journal_fsync: crate::durable::FsyncPolicy::Batched,
            journal_segment_bytes: 1 << 20,
            journal_snapshot_every: 4096,
            journal_batch_ms: 20,
            standby_takeover_ms: 3_000,
        }
    }
}

impl DistConfig {
    /// Aggressive timings for tests: sub-second failure detection.
    pub fn fast() -> DistConfig {
        DistConfig {
            heartbeat_ms: 100,
            suspect_after_ms: 400,
            dead_after_ms: 800,
            poll_ms: 50,
            rpc_timeout_ms: 2_000,
            retry_budget: 8.0,
            retry_refill_per_sec: 4.0,
            retry_backoff_base_ms: 5,
            retry_backoff_cap_ms: 100,
            retry_attempts: 3,
            faults: None,
            journal_dir: None,
            journal_fsync: crate::durable::FsyncPolicy::Batched,
            journal_segment_bytes: 1 << 20,
            journal_snapshot_every: 4096,
            journal_batch_ms: 20,
            standby_takeover_ms: 600,
        }
    }

    /// The journal configuration these knobs describe (None when no
    /// journal directory is set — the volatile pre-journal behavior).
    pub fn journal_config(&self) -> Option<crate::durable::JournalConfig> {
        self.journal_dir.as_ref().map(|dir| crate::durable::JournalConfig {
            dir: dir.clone(),
            fsync: self.journal_fsync,
            segment_bytes: self.journal_segment_bytes,
            snapshot_every: self.journal_snapshot_every,
            batch_ms: self.journal_batch_ms,
        })
    }
}
