//! Keep-alive HTTP/1.1 JSON client for the dist data plane.
//!
//! One [`RpcClient`] owns one TCP connection to one peer. Requests are
//! sent with `Connection: keep-alive` so the server's
//! [`crate::server::serve_connection`] loop reuses the socket. The client
//! itself takes **no** retries: a transport failure surfaces immediately
//! as a typed [`RpcError`], and the *router* decides — against its
//! per-worker retry budget and jittered backoff
//! ([`crate::faults::RetryBudget`] / [`crate::faults::jittered_backoff`])
//! — whether the call is worth re-issuing. Centralizing the policy keeps
//! a flapping peer from multiplying hidden low-level retries under the
//! router's own ones. Read/write timeouts bound every call, so a hung
//! peer turns into a typed [`RpcError::Io`] instead of a stuck thread.
//!
//! A [`FaultInjector`] can be attached ([`RpcClient::with_faults`]) to
//! exercise the transport failure paths deterministically: connect
//! refusals, dropped replies, truncated bodies and injected delays.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use crate::faults::{FaultInjector, FaultSite};
use crate::util::json::Json;

/// Largest accepted RPC response body (tensor payloads are bounded by the
/// model's latent size; 64 MiB is far above any real reply).
pub const MAX_RESPONSE_BYTES: usize = 64 << 20;

/// Why an RPC call failed at the transport/protocol layer. HTTP-level
/// failures (4xx/5xx) are *not* errors here — they come back as the
/// status + body for the caller to interpret.
#[derive(Debug, Clone, PartialEq)]
pub enum RpcError {
    /// Connect/read/write failure.
    Io(String),
    /// The peer spoke something that isn't the expected HTTP/JSON.
    Proto(String),
}

impl std::fmt::Display for RpcError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RpcError::Io(m) => write!(f, "rpc io error: {m}"),
            RpcError::Proto(m) => write!(f, "rpc protocol error: {m}"),
        }
    }
}

/// A single keep-alive connection to one RPC peer.
pub struct RpcClient {
    addr: String,
    timeout: Duration,
    conn: Option<BufReader<TcpStream>>,
    /// Deterministic transport fault injection (None in production).
    faults: Option<Arc<FaultInjector>>,
}

impl RpcClient {
    pub fn new(addr: impl Into<String>, timeout: Duration) -> RpcClient {
        RpcClient { addr: addr.into(), timeout, conn: None, faults: None }
    }

    /// Attach a fault injector: calls may now fail or stall per its
    /// seeded plan, before or after the real network exchange.
    pub fn with_faults(mut self, faults: Arc<FaultInjector>) -> RpcClient {
        self.faults = Some(faults);
        self
    }

    /// In-place variant of [`RpcClient::with_faults`] for clients already
    /// behind a lock.
    pub fn set_faults(&mut self, faults: Arc<FaultInjector>) {
        self.faults = Some(faults);
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    fn connect(&mut self) -> std::io::Result<()> {
        let stream = TcpStream::connect(&self.addr)?;
        stream.set_read_timeout(Some(self.timeout))?;
        stream.set_write_timeout(Some(self.timeout))?;
        stream.set_nodelay(true)?;
        self.conn = Some(BufReader::new(stream));
        Ok(())
    }

    /// One request/response exchange on the current connection.
    fn exchange(
        &mut self,
        method: &str,
        path: &str,
        body: &str,
    ) -> std::io::Result<Result<(u16, Json), RpcError>> {
        if self.conn.is_none() {
            self.connect()?;
        }
        let reader = self.conn.as_mut().expect("connected");
        {
            let stream = reader.get_mut();
            write!(
                stream,
                "{method} {path} HTTP/1.1\r\nHost: {}\r\nConnection: keep-alive\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n{body}",
                self.addr,
                body.len()
            )?;
            stream.flush()?;
        }
        // status line
        let mut line = String::new();
        if reader.read_line(&mut line)? == 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "peer closed before status line",
            ));
        }
        let status: u16 = match line.split_whitespace().nth(1).and_then(|s| s.parse().ok()) {
            Some(s) => s,
            None => return Ok(Err(RpcError::Proto(format!("bad status line {line:?}")))),
        };
        // headers
        let mut content_length = 0usize;
        let mut server_closes = false;
        loop {
            let mut h = String::new();
            if reader.read_line(&mut h)? == 0 {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "peer closed mid-headers",
                ));
            }
            let h = h.trim();
            if h.is_empty() {
                break;
            }
            let lower = h.to_ascii_lowercase();
            if let Some(v) = lower.strip_prefix("content-length:") {
                content_length = v.trim().parse().unwrap_or(usize::MAX);
            } else if let Some(v) = lower.strip_prefix("connection:") {
                server_closes = v.trim() == "close";
            }
        }
        if content_length > MAX_RESPONSE_BYTES {
            self.conn = None;
            return Ok(Err(RpcError::Proto(format!(
                "response of {content_length} bytes exceeds the {MAX_RESPONSE_BYTES}-byte cap"
            ))));
        }
        let mut raw = vec![0u8; content_length];
        reader.read_exact(&mut raw)?;
        if server_closes {
            self.conn = None; // e.g. a 431/413 refusal: don't reuse
        }
        let text = String::from_utf8_lossy(&raw);
        match Json::parse(&text) {
            Ok(j) => Ok(Ok((status, j))),
            Err(e) => Ok(Err(RpcError::Proto(format!("bad JSON body: {e}")))),
        }
    }

    /// Issue one call — exactly one attempt. A transport error drops the
    /// connection (the next call reconnects) and surfaces as
    /// [`RpcError::Io`]; retrying is the caller's decision, made against
    /// the router's per-worker retry budget.
    pub fn call(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&Json>,
    ) -> Result<(u16, Json), RpcError> {
        if let Some(inj) = self.faults.clone() {
            if inj.should(FaultSite::RpcDelay) {
                std::thread::sleep(inj.delay());
            }
            if inj.should(FaultSite::RpcConnect) {
                self.conn = None;
                return Err(RpcError::Io("injected connect failure".into()));
            }
        }
        let body = body.map(|j| j.to_string()).unwrap_or_default();
        let result = match self.exchange(method, path, &body) {
            Ok(result) => result,
            Err(e) => {
                self.conn = None;
                return Err(RpcError::Io(e.to_string()));
            }
        };
        // post-exchange faults model a reply lost or mangled on the way
        // back: the peer may have applied the request (at-least-once
        // delivery), so retried submits must stay idempotent worker-side.
        if let Some(inj) = self.faults.clone() {
            if inj.should(FaultSite::RpcDrop) {
                self.conn = None;
                return Err(RpcError::Io("injected reply drop".into()));
            }
            if inj.should(FaultSite::RpcTruncate) {
                self.conn = None;
                return Err(RpcError::Proto("injected truncated body".into()));
            }
        }
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::faults::FaultPlan;
    use crate::server::serve_connection;
    use std::net::TcpListener;

    /// Spin a tiny echo server on an OS-assigned port; returns its addr.
    fn echo_server() -> String {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        std::thread::spawn(move || {
            for stream in listener.incoming() {
                let Ok(stream) = stream else { break };
                std::thread::spawn(move || {
                    let _ = serve_connection(stream, |method, path, body| {
                        let echoed = Json::parse(body).unwrap_or(Json::Null);
                        (
                            200,
                            Json::obj(vec![
                                ("method", Json::str(method)),
                                ("path", Json::str(path)),
                                ("body", echoed),
                            ]),
                        )
                    });
                });
            }
        });
        addr
    }

    #[test]
    fn keep_alive_calls_reuse_the_connection() {
        let addr = echo_server();
        let mut client = RpcClient::new(addr, Duration::from_secs(5));
        for i in 0..5 {
            let body = Json::obj(vec![("i", Json::num(i as f64))]);
            let (status, reply) = client.call("POST", "/echo", Some(&body)).unwrap();
            assert_eq!(status, 200);
            assert_eq!(reply.at("path").as_str(), Some("/echo"));
            assert_eq!(reply.at("body").at("i").as_usize(), Some(i));
        }
        // the connection survived all five calls
        assert!(client.conn.is_some(), "keep-alive connection must be reused");
    }

    #[test]
    fn down_peer_reports_io_error_in_one_attempt() {
        // bind-and-drop: the port is (almost certainly) refused after drop
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let mut client = RpcClient::new(addr, Duration::from_millis(500));
        match client.call("GET", "/rpc/health", None) {
            Err(RpcError::Io(_)) => {}
            other => panic!("expected Io error, got {other:?}"),
        }
    }

    #[test]
    fn injected_transport_faults_are_typed_and_deterministic() {
        let addr = echo_server();
        // connect-fault at rate 1.0: fails before any network IO
        let plan = FaultPlan::new(3).with_rate(FaultSite::RpcConnect, 1.0);
        let mut client = RpcClient::new(addr.clone(), Duration::from_secs(5))
            .with_faults(Arc::new(FaultInjector::new(plan)));
        match client.call("GET", "/echo", None) {
            Err(RpcError::Io(m)) => assert!(m.contains("injected")),
            other => panic!("expected injected Io, got {other:?}"),
        }
        // truncate-fault: the exchange really happens, then the reply is
        // discarded as a protocol error and the connection is dropped
        let plan = FaultPlan::new(4).with_rate(FaultSite::RpcTruncate, 1.0);
        let mut client = RpcClient::new(addr, Duration::from_secs(5))
            .with_faults(Arc::new(FaultInjector::new(plan)));
        match client.call("GET", "/echo", None) {
            Err(RpcError::Proto(m)) => assert!(m.contains("truncated")),
            other => panic!("expected injected Proto, got {other:?}"),
        }
        assert!(client.conn.is_none(), "mangled reply must not reuse the socket");
    }
}
