//! Keep-alive HTTP/1.1 JSON client for the dist data plane.
//!
//! One [`RpcClient`] owns one TCP connection to one peer. Requests are
//! sent with `Connection: keep-alive` so the server's
//! [`crate::server::serve_connection`] loop reuses the socket; on a
//! transient transport error (dropped keep-alive socket, refused or timed
//! out connect/read) the client takes **one bounded retry** after a
//! jittered backoff before reporting an IO error — so a blip doesn't
//! immediately escalate toward `suspect` in the router's membership
//! layer, while a genuinely dead peer still fails fast. Retries are
//! counted ([`RpcClient::retries`]) and surfaced as `rpc_retries` on
//! `GET /v1/cluster`. Read/write timeouts bound every call, so a hung
//! peer turns into a typed [`RpcError::Io`] instead of a stuck thread.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use crate::util::json::Json;

/// Largest accepted RPC response body (tensor payloads are bounded by the
/// model's latent size; 64 MiB is far above any real reply).
pub const MAX_RESPONSE_BYTES: usize = 64 << 20;

/// Base backoff before the bounded transport retry.
const RETRY_BACKOFF_BASE: Duration = Duration::from_millis(10);

/// Jitter span added on top of the base (exclusive upper bound, ms).
const RETRY_BACKOFF_JITTER_MS: u64 = 25;

/// Why an RPC call failed at the transport/protocol layer. HTTP-level
/// failures (4xx/5xx) are *not* errors here — they come back as the
/// status + body for the caller to interpret.
#[derive(Debug, Clone, PartialEq)]
pub enum RpcError {
    /// Connect/read/write failure, after the bounded retry.
    Io(String),
    /// The peer spoke something that isn't the expected HTTP/JSON.
    Proto(String),
}

impl std::fmt::Display for RpcError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RpcError::Io(m) => write!(f, "rpc io error: {m}"),
            RpcError::Proto(m) => write!(f, "rpc protocol error: {m}"),
        }
    }
}

/// A single keep-alive connection to one RPC peer.
pub struct RpcClient {
    addr: String,
    timeout: Duration,
    conn: Option<BufReader<TcpStream>>,
    /// Transport-level retries taken so far (router stats: `rpc_retries`).
    retries: u64,
}

impl RpcClient {
    pub fn new(addr: impl Into<String>, timeout: Duration) -> RpcClient {
        RpcClient { addr: addr.into(), timeout, conn: None, retries: 0 }
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// How many calls needed the bounded transport retry.
    pub fn retries(&self) -> u64 {
        self.retries
    }

    /// Jittered backoff before the retry: deterministic per (peer,
    /// ordinal) — an FNV hash of the address mixed with the retry count —
    /// so a fleet of clients reconnecting to the same restarted peer
    /// doesn't do so in lockstep, without pulling in an RNG.
    fn retry_backoff(&self) -> Duration {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in self.addr.bytes() {
            h = (h ^ b as u64).wrapping_mul(0x100_0000_01b3);
        }
        h ^= self.retries;
        h = h.wrapping_mul(0x9e37_79b9_7f4a_7c15);
        RETRY_BACKOFF_BASE + Duration::from_millis(h % RETRY_BACKOFF_JITTER_MS)
    }

    fn connect(&mut self) -> std::io::Result<()> {
        let stream = TcpStream::connect(&self.addr)?;
        stream.set_read_timeout(Some(self.timeout))?;
        stream.set_write_timeout(Some(self.timeout))?;
        stream.set_nodelay(true)?;
        self.conn = Some(BufReader::new(stream));
        Ok(())
    }

    /// One request/response exchange on the current connection.
    fn exchange(
        &mut self,
        method: &str,
        path: &str,
        body: &str,
    ) -> std::io::Result<Result<(u16, Json), RpcError>> {
        if self.conn.is_none() {
            self.connect()?;
        }
        let reader = self.conn.as_mut().expect("connected");
        {
            let stream = reader.get_mut();
            write!(
                stream,
                "{method} {path} HTTP/1.1\r\nHost: {}\r\nConnection: keep-alive\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n{body}",
                self.addr,
                body.len()
            )?;
            stream.flush()?;
        }
        // status line
        let mut line = String::new();
        if reader.read_line(&mut line)? == 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "peer closed before status line",
            ));
        }
        let status: u16 = match line.split_whitespace().nth(1).and_then(|s| s.parse().ok()) {
            Some(s) => s,
            None => return Ok(Err(RpcError::Proto(format!("bad status line {line:?}")))),
        };
        // headers
        let mut content_length = 0usize;
        let mut server_closes = false;
        loop {
            let mut h = String::new();
            if reader.read_line(&mut h)? == 0 {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "peer closed mid-headers",
                ));
            }
            let h = h.trim();
            if h.is_empty() {
                break;
            }
            let lower = h.to_ascii_lowercase();
            if let Some(v) = lower.strip_prefix("content-length:") {
                content_length = v.trim().parse().unwrap_or(usize::MAX);
            } else if let Some(v) = lower.strip_prefix("connection:") {
                server_closes = v.trim() == "close";
            }
        }
        if content_length > MAX_RESPONSE_BYTES {
            self.conn = None;
            return Ok(Err(RpcError::Proto(format!(
                "response of {content_length} bytes exceeds the {MAX_RESPONSE_BYTES}-byte cap"
            ))));
        }
        let mut raw = vec![0u8; content_length];
        reader.read_exact(&mut raw)?;
        if server_closes {
            self.conn = None; // e.g. a 431/413 refusal: don't reuse
        }
        let text = String::from_utf8_lossy(&raw);
        match Json::parse(&text) {
            Ok(j) => Ok(Ok((status, j))),
            Err(e) => Ok(Err(RpcError::Proto(format!("bad JSON body: {e}")))),
        }
    }

    /// Issue one call. On a transport error (a keep-alive socket the peer
    /// already closed looks exactly like a blip) the client takes one
    /// bounded retry after a jittered backoff, then surfaces
    /// [`RpcError::Io`] for the membership layer to escalate.
    pub fn call(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&Json>,
    ) -> Result<(u16, Json), RpcError> {
        let body = body.map(|j| j.to_string()).unwrap_or_default();
        match self.exchange(method, path, &body) {
            Ok(result) => result,
            Err(first) => {
                self.conn = None;
                self.retries += 1;
                std::thread::sleep(self.retry_backoff());
                match self.exchange(method, path, &body) {
                    Ok(result) => result,
                    Err(e) => {
                        self.conn = None;
                        Err(RpcError::Io(format!("{first}; retry: {e}")))
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::serve_connection;
    use std::net::TcpListener;

    /// Spin a tiny echo server on an OS-assigned port; returns its addr.
    fn echo_server() -> String {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        std::thread::spawn(move || {
            for stream in listener.incoming() {
                let Ok(stream) = stream else { break };
                std::thread::spawn(move || {
                    let _ = serve_connection(stream, |method, path, body| {
                        let echoed = Json::parse(body).unwrap_or(Json::Null);
                        (
                            200,
                            Json::obj(vec![
                                ("method", Json::str(method)),
                                ("path", Json::str(path)),
                                ("body", echoed),
                            ]),
                        )
                    });
                });
            }
        });
        addr
    }

    #[test]
    fn keep_alive_calls_reuse_the_connection() {
        let addr = echo_server();
        let mut client = RpcClient::new(addr, Duration::from_secs(5));
        for i in 0..5 {
            let body = Json::obj(vec![("i", Json::num(i as f64))]);
            let (status, reply) = client.call("POST", "/echo", Some(&body)).unwrap();
            assert_eq!(status, 200);
            assert_eq!(reply.at("path").as_str(), Some("/echo"));
            assert_eq!(reply.at("body").at("i").as_usize(), Some(i));
        }
        // the connection survived all five calls, no retries burned
        assert!(client.conn.is_some(), "keep-alive connection must be reused");
        assert_eq!(client.retries(), 0);
    }

    #[test]
    fn down_peer_reports_io_error() {
        // bind-and-drop: the port is (almost certainly) refused after drop
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let mut client = RpcClient::new(addr, Duration::from_millis(500));
        match client.call("GET", "/rpc/health", None) {
            Err(RpcError::Io(_)) => {}
            other => panic!("expected Io error, got {other:?}"),
        }
        // the failure burned exactly the one bounded retry
        assert_eq!(client.retries(), 1);
    }

    #[test]
    fn retry_backoff_is_jittered_and_bounded() {
        let mut seen = std::collections::HashSet::new();
        for port in 1000..1032 {
            let c = RpcClient::new(format!("127.0.0.1:{port}"), Duration::from_secs(1));
            let d = c.retry_backoff();
            assert!(d >= RETRY_BACKOFF_BASE);
            assert!(
                d < RETRY_BACKOFF_BASE + Duration::from_millis(RETRY_BACKOFF_JITTER_MS)
            );
            seen.insert(d);
        }
        // different peers de-synchronize (the jitter actually varies)
        assert!(seen.len() > 1, "backoff must not be constant across peers");
    }
}
