//! Router-side membership/epoch protocol.
//!
//! Workers announce themselves (`POST /rpc/announce`) with their RPC
//! address and resident templates, then heartbeat (`POST /rpc/heartbeat`)
//! with a [`WorkerSnapshot`]. The router runs [`Membership::expire`] on a
//! cadence: a member silent past `suspect_after` is marked [`Suspect`]
//! (no new work routes to it); past `dead_after` it transitions to
//! [`Dead`], which is the failover trigger — the router re-submits the
//! member's queued requests to residency-compatible peers and resolves
//! its in-flight tickets with [`EditError::WorkerLost`]. A heartbeat from
//! a `Suspect` member revives it to [`Ready`]; a `Dead` member must
//! re-announce, which bumps its epoch so stale state is never confused
//! with the new incarnation. Live drain ([`Membership::begin_drain`])
//! parallels the template lifecycle's draining semantics: the member
//! finishes what it holds but receives no new work.
//!
//! Slots are stable: a member keeps its index across re-announces, so the
//! router's book lanes and scheduler worker ids stay aligned.
//!
//! [`Suspect`]: MemberState::Suspect
//! [`Dead`]: MemberState::Dead
//! [`Ready`]: MemberState::Ready
//! [`EditError::WorkerLost`]: crate::engine::request::EditError::WorkerLost

use std::time::{Duration, Instant};

use crate::engine::worker::WorkerSnapshot;

/// Lifecycle of one cluster member, as the router sees it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MemberState {
    /// Announced, no heartbeat yet.
    Joining,
    /// Heartbeating; eligible for new work.
    Ready,
    /// Live drain: finishes held work, receives none.
    Draining,
    /// Missed heartbeats past `suspect_after`; unavailable but not yet
    /// failed over (a late heartbeat revives it).
    Suspect,
    /// Missed heartbeats past `dead_after`; its work has been failed
    /// over. Re-announcing (epoch bump) is the only way back.
    Dead,
}

impl MemberState {
    pub fn label(&self) -> &'static str {
        match self {
            MemberState::Joining => "joining",
            MemberState::Ready => "ready",
            MemberState::Draining => "draining",
            MemberState::Suspect => "suspect",
            MemberState::Dead => "dead",
        }
    }
}

/// One worker process, from the router's point of view.
#[derive(Debug, Clone)]
pub struct Member {
    pub name: String,
    pub rpc_addr: String,
    pub state: MemberState,
    /// Bumped on every (re-)announce; distinguishes incarnations.
    pub epoch: u64,
    pub last_heartbeat: Instant,
    /// Last heartbeat's load snapshot (None until the first heartbeat,
    /// and stale the moment the member stops heartbeating — which is why
    /// availability, not the snapshot, gates routing).
    pub snapshot: Option<WorkerSnapshot>,
    /// Templates the member reports as locally serveable.
    pub templates: Vec<String>,
}

/// Membership table. Pure state machine — no IO, no threads — so the
/// expiry logic is unit-testable with injected clocks; the router owns
/// the cadence and the failover side effects.
pub struct Membership {
    suspect_after: Duration,
    dead_after: Duration,
    members: Vec<Member>,
}

impl Membership {
    pub fn new(suspect_after: Duration, dead_after: Duration) -> Membership {
        assert!(dead_after >= suspect_after);
        Membership { suspect_after, dead_after, members: Vec::new() }
    }

    pub fn members(&self) -> &[Member] {
        &self.members
    }

    pub fn len(&self) -> usize {
        self.members.len()
    }

    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }

    pub fn get(&self, slot: usize) -> Option<&Member> {
        self.members.get(slot)
    }

    pub fn slot_of(&self, name: &str) -> Option<usize> {
        self.members.iter().position(|m| m.name == name)
    }

    /// Register (or re-register) a member. Re-announcing keeps the slot
    /// and bumps the epoch — the path back from `Dead`, and how a
    /// restarted worker replaces its previous incarnation.
    pub fn announce(
        &mut self,
        name: &str,
        rpc_addr: &str,
        templates: Vec<String>,
        now: Instant,
    ) -> (usize, u64) {
        if let Some(slot) = self.slot_of(name) {
            let m = &mut self.members[slot];
            m.rpc_addr = rpc_addr.to_string();
            m.templates = templates;
            m.state = MemberState::Joining;
            m.epoch += 1;
            m.last_heartbeat = now;
            m.snapshot = None;
            (slot, m.epoch)
        } else {
            self.members.push(Member {
                name: name.to_string(),
                rpc_addr: rpc_addr.to_string(),
                state: MemberState::Joining,
                epoch: 1,
                last_heartbeat: now,
                snapshot: None,
                templates,
            });
            (self.members.len() - 1, 1)
        }
    }

    /// Re-seat a journaled member at recovery: same slot order, same
    /// epoch, but `Suspect` until it heartbeats again — a recovered entry
    /// must prove liveness before taking work, and a dead one expires
    /// naturally. Slots are announce-order Vec indices, so restoring
    /// members in journal slot order reproduces the assignment exactly
    /// and a re-announcing live worker lands back on its old slot.
    /// Returns the slot.
    pub fn restore(
        &mut self,
        name: &str,
        rpc_addr: &str,
        templates: Vec<String>,
        epoch: u64,
        now: Instant,
    ) -> usize {
        let slot = match self.slot_of(name) {
            Some(slot) => slot,
            None => {
                self.members.push(Member {
                    name: name.to_string(),
                    rpc_addr: rpc_addr.to_string(),
                    state: MemberState::Suspect,
                    epoch,
                    last_heartbeat: now,
                    snapshot: None,
                    templates: Vec::new(),
                });
                self.members.len() - 1
            }
        };
        let m = &mut self.members[slot];
        m.rpc_addr = rpc_addr.to_string();
        m.templates = templates;
        m.state = MemberState::Suspect;
        m.epoch = epoch;
        m.last_heartbeat = now;
        m.snapshot = None;
        slot
    }

    /// Record a heartbeat. `Joining`/`Suspect` members become `Ready`;
    /// `Draining` stays draining (the drain outlives load reports).
    /// A heartbeat carrying a template set refreshes the member's
    /// residency in place — routing then follows live registrations and
    /// retirements instead of the announce-time snapshot. Returns `false`
    /// for unknown or `Dead` members — the caller should tell the worker
    /// to re-announce.
    pub fn heartbeat(
        &mut self,
        name: &str,
        snapshot: Option<WorkerSnapshot>,
        templates: Option<Vec<String>>,
        now: Instant,
    ) -> bool {
        let Some(slot) = self.slot_of(name) else { return false };
        let m = &mut self.members[slot];
        match m.state {
            MemberState::Dead => return false,
            MemberState::Joining | MemberState::Suspect => m.state = MemberState::Ready,
            MemberState::Ready | MemberState::Draining => {}
        }
        m.last_heartbeat = now;
        if snapshot.is_some() {
            m.snapshot = snapshot;
        }
        if let Some(t) = templates {
            m.templates = t;
        }
        true
    }

    /// Start a live drain. Returns false for unknown/dead members.
    pub fn begin_drain(&mut self, name: &str) -> bool {
        let Some(slot) = self.slot_of(name) else { return false };
        let m = &mut self.members[slot];
        if m.state == MemberState::Dead {
            return false;
        }
        m.state = MemberState::Draining;
        true
    }

    /// Advance the failure detector to `now`. Returns the slots that
    /// transitioned to `Dead` on this call — the router fails those over
    /// exactly once.
    pub fn expire(&mut self, now: Instant) -> Vec<usize> {
        let mut newly_dead = Vec::new();
        for (slot, m) in self.members.iter_mut().enumerate() {
            let age = now.saturating_duration_since(m.last_heartbeat);
            match m.state {
                MemberState::Ready | MemberState::Joining | MemberState::Draining => {
                    if age >= self.dead_after {
                        m.state = MemberState::Dead;
                        newly_dead.push(slot);
                    } else if age >= self.suspect_after {
                        m.state = MemberState::Suspect;
                    }
                }
                MemberState::Suspect => {
                    if age >= self.dead_after {
                        m.state = MemberState::Dead;
                        newly_dead.push(slot);
                    }
                }
                MemberState::Dead => {}
            }
        }
        newly_dead
    }

    /// `available[slot]` for [`crate::scheduler::RouteCtx`]: only `Ready`
    /// members take new work. This is what makes a dead (or merely
    /// silent) remote worker read as *infinite cost* to the mask-aware
    /// and qos-aware policies instead of as its last-published load.
    pub fn available(&self) -> Vec<bool> {
        self.members
            .iter()
            .map(|m| m.state == MemberState::Ready)
            .collect()
    }

    /// Slots currently eligible for failover targets.
    pub fn ready_slots(&self) -> Vec<usize> {
        self.members
            .iter()
            .enumerate()
            .filter(|(_, m)| m.state == MemberState::Ready)
            .map(|(s, _)| s)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> Membership {
        Membership::new(Duration::from_millis(300), Duration::from_millis(600))
    }

    #[test]
    fn announce_heartbeat_lifecycle() {
        let t0 = Instant::now();
        let mut ms = table();
        let (slot, epoch) = ms.announce("w0", "127.0.0.1:9001", vec!["tpl-0".into()], t0);
        assert_eq!((slot, epoch), (0, 1));
        assert_eq!(ms.get(0).unwrap().state, MemberState::Joining);
        assert!(!ms.available()[0], "joining members take no work yet");
        assert!(ms.heartbeat("w0", None, None, t0));
        assert_eq!(ms.get(0).unwrap().state, MemberState::Ready);
        assert!(ms.available()[0]);
        assert!(!ms.heartbeat("ghost", None, None, t0), "unknown members must re-announce");
    }

    #[test]
    fn missed_heartbeats_suspect_then_dead_then_epoch_bump() {
        let t0 = Instant::now();
        let mut ms = table();
        ms.announce("w0", "a", vec![], t0);
        ms.heartbeat("w0", None, None, t0);
        assert!(ms.expire(t0 + Duration::from_millis(100)).is_empty());
        assert_eq!(ms.get(0).unwrap().state, MemberState::Ready);
        // past suspect_after: suspect, not yet failed over
        assert!(ms.expire(t0 + Duration::from_millis(400)).is_empty());
        assert_eq!(ms.get(0).unwrap().state, MemberState::Suspect);
        assert!(!ms.available()[0]);
        // a late heartbeat revives it
        assert!(ms.heartbeat("w0", None, None, t0 + Duration::from_millis(450)));
        assert_eq!(ms.get(0).unwrap().state, MemberState::Ready);
        // silence all the way to dead_after: exactly one dead transition
        let dead = ms.expire(t0 + Duration::from_millis(1100));
        assert_eq!(dead, vec![0]);
        assert!(ms.expire(t0 + Duration::from_millis(1200)).is_empty(), "dead fires once");
        // heartbeats from the dead are refused; re-announce revives with
        // a bumped epoch on the same slot
        assert!(!ms.heartbeat("w0", None, None, t0 + Duration::from_millis(1200)));
        let (slot, epoch) = ms.announce("w0", "a", vec![], t0 + Duration::from_millis(1300));
        assert_eq!((slot, epoch), (0, 2));
        assert_eq!(ms.get(0).unwrap().state, MemberState::Joining);
    }

    #[test]
    fn heartbeats_refresh_template_residency() {
        let t0 = Instant::now();
        let mut ms = table();
        ms.announce("w0", "a", vec!["tpl-0".into()], t0);
        // legacy beat without a template set: announce-time residency kept
        assert!(ms.heartbeat("w0", None, None, t0));
        assert_eq!(ms.get(0).unwrap().templates, vec!["tpl-0".to_string()]);
        // a beat carrying templates replaces the set (tpl-0 retired,
        // tpl-1 registered since the announce)
        assert!(ms.heartbeat("w0", None, Some(vec!["tpl-1".into()]), t0));
        assert_eq!(ms.get(0).unwrap().templates, vec!["tpl-1".to_string()]);
        // an explicitly empty set is honoured too (everything retired)
        assert!(ms.heartbeat("w0", None, Some(Vec::new()), t0));
        assert!(ms.get(0).unwrap().templates.is_empty());
    }

    #[test]
    fn draining_members_take_no_new_work_but_stay_alive() {
        let t0 = Instant::now();
        let mut ms = table();
        ms.announce("w0", "a", vec![], t0);
        ms.announce("w1", "b", vec![], t0);
        ms.heartbeat("w0", None, None, t0);
        ms.heartbeat("w1", None, None, t0);
        assert!(ms.begin_drain("w1"));
        assert_eq!(ms.available(), vec![true, false]);
        assert_eq!(ms.ready_slots(), vec![0]);
        // heartbeats keep it draining (not revived to ready)
        assert!(ms.heartbeat("w1", None, None, t0 + Duration::from_millis(100)));
        assert_eq!(ms.get(1).unwrap().state, MemberState::Draining);
        // but a drained member that stops heartbeating still dies
        let dead = ms.expire(t0 + Duration::from_millis(800));
        assert!(dead.contains(&1));
    }
}
