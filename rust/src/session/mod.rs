//! The session serving plane: interactive editing sessions layered over
//! the request/template/QoS control plane.
//!
//! A *session* pins one template for a user iterating on one edit: rounds
//! arrive one at a time, each an [`crate::engine::request::EditRequest`]
//! stamped with the session id. The [`SessionRegistry`] owns session
//! lifecycle (open → active → idle-expired/closed), the per-session
//! round counter and epoch, the owning worker (sticky affinity — see
//! [`crate::scheduler::SessionAffinity`]), and the previous round's mask
//! for delta-mask reuse ([`delta`]). Three properties the plane
//! maintains:
//!
//! 1. **Affinity**: rounds route to the session's owner while it is
//!    alive; failover re-homes the session (epoch bump) on whatever
//!    worker wins the mask-aware fallback.
//! 2. **Template pinning**: an open session holds one in-flight
//!    reference on its template under a synthetic request id
//!    ([`pin_id`]), so retirement drains behind live sessions and
//!    close/expiry releases (and tier-purges) deterministically.
//! 3. **Delta-mask reuse**: a round whose mask shares the canonical
//!    id-set with its predecessor is *warm* — same gather indices, same
//!    memoized plan, same device-KV keys, zero KV upload bytes on the
//!    owner.

pub mod delta;

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::model::MaskSpec;

/// Synthetic request-id namespace for per-session template pins: the
/// high bit is set, so pins can never collide with real request ids
/// (frontends allocate those from small counters).
pub const SESSION_PIN_BASE: u64 = 1 << 63;

/// The synthetic request id under which session `id` pins its template
/// in the [`crate::templates::TemplateRegistry`].
pub fn pin_id(session: u64) -> u64 {
    SESSION_PIN_BASE | session
}

/// Default idle expiry: a session with no round activity for this long
/// releases its template pin and refuses further rounds.
pub const DEFAULT_IDLE_TIMEOUT: Duration = Duration::from_secs(600);

/// Where a session is in its lifecycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SessionState {
    /// Accepting rounds.
    Open,
    /// Explicitly closed by the client (`DELETE /v1/sessions/{id}`).
    Closed,
    /// Idle-expired by the registry sweep.
    Expired,
}

impl SessionState {
    pub fn label(self) -> &'static str {
        match self {
            SessionState::Open => "open",
            SessionState::Closed => "closed",
            SessionState::Expired => "expired",
        }
    }
}

/// Why a session operation was refused (mapped onto HTTP by frontends).
#[derive(Debug, Clone, PartialEq, Eq, thiserror::Error)]
pub enum SessionError {
    #[error("unknown session {0}")]
    Unknown(u64),
    /// The session is closed or expired: no further rounds.
    #[error("session {id} is {state}")]
    NotOpen { id: u64, state: &'static str },
}

impl SessionError {
    pub fn http_status(&self) -> u16 {
        match self {
            SessionError::Unknown(_) => 404,
            SessionError::NotOpen { .. } => 410,
        }
    }

    pub fn kind(&self) -> &'static str {
        match self {
            SessionError::Unknown(_) => "unknown_session",
            SessionError::NotOpen { .. } => "session_not_open",
        }
    }
}

/// One submitted round of a session.
#[derive(Debug, Clone)]
pub struct RoundRecord {
    /// 1-based round index within the session.
    pub round: u64,
    /// The request id the round was submitted under.
    pub request_id: u64,
    /// Delta-mask verdict: the mask's canonical id-set matched the
    /// previous round's, so cached state (plans, gather indices, device
    /// KV keys) is reused verbatim.
    pub warm: bool,
    /// Worker the round was routed to.
    pub worker: Option<usize>,
    /// End-to-end latency in seconds, once terminal.
    pub latency: Option<f64>,
    /// Whether the round completed successfully, once terminal.
    pub ok: Option<bool>,
}

/// Routing decision inputs for a freshly admitted round.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RoundPlan {
    /// 1-based round index.
    pub round: u64,
    /// Delta-mask verdict vs the previous round.
    pub warm: bool,
    /// Current session owner (sticky-affinity hint; `None` on round 1 or
    /// after the owner died without a successor yet).
    pub owner: Option<usize>,
}

/// Point-in-time view of one session (status endpoints).
#[derive(Debug, Clone)]
pub struct SessionStatus {
    pub id: u64,
    pub template: String,
    pub state: SessionState,
    /// Bumped every time the session re-homes onto a different worker.
    pub epoch: u64,
    pub owner: Option<usize>,
    pub rounds: Vec<RoundRecord>,
    /// Rounds submitted but not yet terminal.
    pub inflight: usize,
    /// Mean e2e latency (seconds) over completed cold (mask-changed)
    /// rounds — round 1 is always cold.
    pub cold_mean: Option<f64>,
    /// Mean e2e latency (seconds) over completed warm (mask-unchanged)
    /// rounds.
    pub warm_mean: Option<f64>,
}

struct SessionInner {
    template: String,
    state: SessionState,
    epoch: u64,
    owner: Option<usize>,
    rounds: Vec<RoundRecord>,
    last_mask: Option<MaskSpec>,
    last_touch: Instant,
    inflight: usize,
}

#[derive(Default)]
struct RegistryInner {
    sessions: HashMap<u64, SessionInner>,
    /// In-flight round request id -> session id.
    by_request: HashMap<u64, u64>,
}

/// Owns every session's lifecycle. Thread-safe; shared between frontends,
/// the routing path, and the completion collector.
pub struct SessionRegistry {
    inner: Mutex<RegistryInner>,
    next_id: AtomicU64,
    idle_timeout: Duration,
}

impl SessionRegistry {
    pub fn new(idle_timeout: Duration) -> SessionRegistry {
        SessionRegistry {
            inner: Mutex::new(RegistryInner::default()),
            next_id: AtomicU64::new(1),
            idle_timeout,
        }
    }

    /// Open a session pinned to `template`; returns its id. The caller is
    /// responsible for taking the template pin (`templates.acquire` under
    /// [`pin_id`]) — the registry only tracks lifecycle.
    pub fn open(&self, template: &str) -> u64 {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let mut inner = self.inner.lock().unwrap();
        inner.sessions.insert(
            id,
            SessionInner {
                template: template.to_string(),
                state: SessionState::Open,
                epoch: 0,
                owner: None,
                rounds: Vec::new(),
                last_mask: None,
                last_touch: Instant::now(),
                inflight: 0,
            },
        );
        id
    }

    /// Re-seat a journaled session at recovery under its original id.
    /// Round details (warm verdicts, latencies) are not journaled — the
    /// restored history carries the round count and which rounds are
    /// still in flight, so `complete_round` resolves recovered rounds
    /// when the reconciling router pumps their results. The previous mask
    /// is gone, so the next round reads cold (correctness over warmth).
    #[allow(clippy::too_many_arguments)]
    pub fn restore(
        &self,
        id: u64,
        template: &str,
        closed: bool,
        epoch: u64,
        owner: Option<usize>,
        rounds: u64,
        inflight: &[u64],
    ) {
        self.next_id.fetch_max(id + 1, Ordering::Relaxed);
        let mut inner = self.inner.lock().unwrap();
        let mut records = Vec::with_capacity(rounds as usize);
        for round in 1..=rounds {
            records.push(RoundRecord {
                round,
                request_id: 0,
                warm: false,
                worker: None,
                latency: None,
                ok: None,
            });
        }
        // The trailing rounds are the in-flight ones, oldest first.
        let first_open = records.len().saturating_sub(inflight.len());
        for (slot, &rid) in records[first_open..].iter_mut().zip(inflight) {
            slot.request_id = rid;
            inner.by_request.insert(rid, id);
        }
        inner.sessions.insert(
            id,
            SessionInner {
                template: template.to_string(),
                state: if closed { SessionState::Closed } else { SessionState::Open },
                epoch,
                owner,
                rounds: records,
                last_mask: None,
                last_touch: Instant::now(),
                inflight: inflight.len(),
            },
        );
    }

    /// Admit one round: checks the session is open, computes the
    /// delta-mask verdict against the previous round, advances the round
    /// counter, and records the round as in-flight under `request_id`.
    pub fn begin_round(
        &self,
        id: u64,
        request_id: u64,
        mask: &MaskSpec,
    ) -> Result<RoundPlan, SessionError> {
        let mut inner = self.inner.lock().unwrap();
        let s = inner.sessions.get_mut(&id).ok_or(SessionError::Unknown(id))?;
        if s.state != SessionState::Open {
            return Err(SessionError::NotOpen { id, state: s.state.label() });
        }
        let warm = s.last_mask.as_ref().is_some_and(|prev| delta::same_ids(prev, mask));
        s.last_mask = Some(mask.clone());
        s.last_touch = Instant::now();
        s.inflight += 1;
        let round = s.rounds.len() as u64 + 1;
        s.rounds.push(RoundRecord {
            round,
            request_id,
            warm,
            worker: None,
            latency: None,
            ok: None,
        });
        let owner = s.owner;
        inner.by_request.insert(request_id, id);
        Ok(RoundPlan { round, warm, owner })
    }

    /// Record where a round landed; a changed worker re-homes the session
    /// (epoch bump). Called after routing picked the worker.
    pub fn assign_owner(&self, id: u64, request_id: u64, worker: usize) {
        let mut inner = self.inner.lock().unwrap();
        if let Some(s) = inner.sessions.get_mut(&id) {
            if s.owner != Some(worker) {
                s.owner = Some(worker);
                s.epoch += 1;
            }
            if let Some(r) = s.rounds.iter_mut().rev().find(|r| r.request_id == request_id) {
                r.worker = Some(worker);
            }
        }
    }

    /// Roll back a round that failed to submit after `begin_round` (e.g.
    /// admission shed it): the round record is removed so it never counts
    /// against the session, and the mask verdict of the *next* round is
    /// unaffected (the stored mask stays — reuse is a property of the
    /// tiers, which the failed round never touched).
    pub fn abort_round(&self, request_id: u64) {
        let mut inner = self.inner.lock().unwrap();
        let Some(id) = inner.by_request.remove(&request_id) else { return };
        if let Some(s) = inner.sessions.get_mut(&id) {
            s.inflight = s.inflight.saturating_sub(1);
            if let Some(pos) = s.rounds.iter().rposition(|r| r.request_id == request_id) {
                s.rounds.remove(pos);
            }
        }
    }

    /// Mark the round submitted under `request_id` terminal. No-op for
    /// requests that are not session rounds.
    pub fn complete_round(&self, request_id: u64, ok: bool, latency_secs: Option<f64>) {
        let mut inner = self.inner.lock().unwrap();
        let Some(id) = inner.by_request.remove(&request_id) else { return };
        if let Some(s) = inner.sessions.get_mut(&id) {
            s.inflight = s.inflight.saturating_sub(1);
            s.last_touch = Instant::now();
            if let Some(r) = s.rounds.iter_mut().rev().find(|r| r.request_id == request_id) {
                r.ok = Some(ok);
                r.latency = latency_secs;
            }
        }
    }

    /// The session a round request belongs to, while the round is in
    /// flight.
    pub fn session_of_request(&self, request_id: u64) -> Option<u64> {
        self.inner.lock().unwrap().by_request.get(&request_id).copied()
    }

    /// Current owner (sticky-affinity hint) of session `id`.
    pub fn owner_of(&self, id: u64) -> Option<usize> {
        self.inner.lock().unwrap().sessions.get(&id).and_then(|s| s.owner)
    }

    /// Drop the owner of every session homed on `worker` (it died or was
    /// drained): their next round re-homes via the mask-aware fallback.
    /// Returns how many sessions were orphaned.
    pub fn orphan_worker(&self, worker: usize) -> usize {
        let mut inner = self.inner.lock().unwrap();
        let mut n = 0;
        for s in inner.sessions.values_mut() {
            if s.owner == Some(worker) {
                s.owner = None;
                n += 1;
            }
        }
        n
    }

    /// In-flight round count of session `id`.
    pub fn inflight(&self, id: u64) -> Option<usize> {
        self.inner.lock().unwrap().sessions.get(&id).map(|s| s.inflight)
    }

    /// Close session `id`: refuses further rounds immediately. Returns
    /// the pinned template (for the caller to release once in-flight
    /// rounds drain) and the in-flight count at close time.
    pub fn close(&self, id: u64) -> Result<(String, usize), SessionError> {
        let mut inner = self.inner.lock().unwrap();
        let s = inner.sessions.get_mut(&id).ok_or(SessionError::Unknown(id))?;
        if s.state != SessionState::Open {
            return Err(SessionError::NotOpen { id, state: s.state.label() });
        }
        s.state = SessionState::Closed;
        Ok((s.template.clone(), s.inflight))
    }

    /// Sweep idle sessions: every open session with no in-flight round
    /// and no activity for the idle timeout expires. Returns the expired
    /// `(session, template)` pairs so the caller can release their pins.
    pub fn expire_idle(&self, now: Instant) -> Vec<(u64, String)> {
        let mut inner = self.inner.lock().unwrap();
        let timeout = self.idle_timeout;
        let mut expired = Vec::new();
        for (&id, s) in inner.sessions.iter_mut() {
            if s.state == SessionState::Open
                && s.inflight == 0
                && now.duration_since(s.last_touch) >= timeout
            {
                s.state = SessionState::Expired;
                expired.push((id, s.template.clone()));
            }
        }
        expired
    }

    /// Status view of session `id`.
    pub fn status(&self, id: u64) -> Option<SessionStatus> {
        let inner = self.inner.lock().unwrap();
        let s = inner.sessions.get(&id)?;
        let mean = |warm: bool| {
            let lats: Vec<f64> = s
                .rounds
                .iter()
                .filter(|r| r.warm == warm)
                .filter_map(|r| r.latency)
                .collect();
            (!lats.is_empty()).then(|| lats.iter().sum::<f64>() / lats.len() as f64)
        };
        Some(SessionStatus {
            id,
            template: s.template.clone(),
            state: s.state,
            epoch: s.epoch,
            owner: s.owner,
            rounds: s.rounds.clone(),
            inflight: s.inflight,
            cold_mean: mean(false),
            warm_mean: mean(true),
        })
    }

    /// Count of open sessions (stats endpoints).
    pub fn open_count(&self) -> usize {
        let inner = self.inner.lock().unwrap();
        inner.sessions.values().filter(|s| s.state == SessionState::Open).count()
    }

    /// Per-worker `(open sessions, in-flight rounds)` over `n` workers —
    /// the session-skew overlay for `WorkerSnapshot`.
    pub fn worker_load(&self, n: usize) -> Vec<(usize, usize)> {
        let inner = self.inner.lock().unwrap();
        let mut load = vec![(0usize, 0usize); n];
        for s in inner.sessions.values() {
            if s.state != SessionState::Open {
                continue;
            }
            if let Some(w) = s.owner {
                if let Some(slot) = load.get_mut(w) {
                    slot.0 += 1;
                    slot.1 += s.inflight;
                }
            }
        }
        load
    }
}

impl Default for SessionRegistry {
    fn default() -> Self {
        SessionRegistry::new(DEFAULT_IDLE_TIMEOUT)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mask(ids: Vec<usize>) -> MaskSpec {
        MaskSpec::new(ids, 64)
    }

    #[test]
    fn lifecycle_open_rounds_close() {
        let reg = SessionRegistry::new(Duration::from_secs(600));
        let id = reg.open("tpl-0");
        assert_eq!(reg.status(id).unwrap().state, SessionState::Open);
        // round 1 is cold, same-mask round 2 is warm
        let p1 = reg.begin_round(id, 100, &mask(vec![1, 2, 3])).unwrap();
        assert_eq!(p1.round, 1);
        assert!(!p1.warm);
        assert_eq!(p1.owner, None);
        reg.assign_owner(id, 100, 1);
        assert_eq!(reg.owner_of(id), Some(1));
        reg.complete_round(100, true, Some(0.25));
        let p2 = reg.begin_round(id, 101, &mask(vec![3, 2, 1])).unwrap();
        assert!(p2.warm);
        assert_eq!(p2.owner, Some(1));
        reg.assign_owner(id, 101, 1);
        reg.complete_round(101, true, Some(0.05));
        // drifted mask -> cold again
        let p3 = reg.begin_round(id, 102, &mask(vec![1, 2, 3, 4])).unwrap();
        assert!(!p3.warm);
        reg.complete_round(102, true, Some(0.2));
        let st = reg.status(id).unwrap();
        assert_eq!(st.rounds.len(), 3);
        assert_eq!(st.inflight, 0);
        assert_eq!(st.warm_mean, Some(0.05));
        assert!((st.cold_mean.unwrap() - 0.225).abs() < 1e-12);
        // close refuses further rounds
        let (tpl, inflight) = reg.close(id).unwrap();
        assert_eq!(tpl, "tpl-0");
        assert_eq!(inflight, 0);
        assert!(matches!(
            reg.begin_round(id, 103, &mask(vec![1])),
            Err(SessionError::NotOpen { .. })
        ));
        assert!(matches!(reg.close(id), Err(SessionError::NotOpen { .. })));
        assert!(matches!(reg.begin_round(999, 104, &mask(vec![1])), Err(SessionError::Unknown(_))));
    }

    #[test]
    fn epoch_bumps_only_on_rehome() {
        let reg = SessionRegistry::default();
        let id = reg.open("t");
        reg.begin_round(id, 1, &mask(vec![1])).unwrap();
        reg.assign_owner(id, 1, 2);
        assert_eq!(reg.status(id).unwrap().epoch, 1);
        reg.complete_round(1, true, None);
        reg.begin_round(id, 2, &mask(vec![1])).unwrap();
        reg.assign_owner(id, 2, 2); // same owner: no bump
        assert_eq!(reg.status(id).unwrap().epoch, 1);
        reg.orphan_worker(2);
        assert_eq!(reg.owner_of(id), None);
        reg.complete_round(2, true, None);
        reg.begin_round(id, 3, &mask(vec![1])).unwrap();
        reg.assign_owner(id, 3, 0); // re-homed
        let st = reg.status(id).unwrap();
        assert_eq!(st.epoch, 2);
        assert_eq!(st.rounds.last().unwrap().worker, Some(0));
    }

    #[test]
    fn idle_expiry_only_hits_quiet_sessions() {
        let reg = SessionRegistry::new(Duration::from_millis(0));
        let quiet = reg.open("a");
        let busy = reg.open("b");
        reg.begin_round(busy, 7, &mask(vec![1])).unwrap();
        let expired = reg.expire_idle(Instant::now());
        assert_eq!(expired, vec![(quiet, "a".to_string())]);
        assert_eq!(reg.status(quiet).unwrap().state, SessionState::Expired);
        assert_eq!(reg.status(busy).unwrap().state, SessionState::Open);
        // an expired session is not expired twice
        assert!(reg.expire_idle(Instant::now()).is_empty());
        // completing the round makes the busy one expirable
        reg.complete_round(7, true, None);
        let expired = reg.expire_idle(Instant::now());
        assert_eq!(expired, vec![(busy, "b".to_string())]);
    }

    #[test]
    fn abort_round_rolls_back() {
        let reg = SessionRegistry::default();
        let id = reg.open("t");
        reg.begin_round(id, 5, &mask(vec![1])).unwrap();
        assert_eq!(reg.inflight(id), Some(1));
        assert_eq!(reg.session_of_request(5), Some(id));
        reg.abort_round(5);
        assert_eq!(reg.inflight(id), Some(0));
        assert_eq!(reg.session_of_request(5), None);
        assert!(reg.status(id).unwrap().rounds.is_empty());
    }

    #[test]
    fn worker_load_counts_open_sessions_and_inflight_rounds() {
        let reg = SessionRegistry::default();
        let a = reg.open("t");
        let b = reg.open("t");
        let c = reg.open("t");
        reg.begin_round(a, 1, &mask(vec![1])).unwrap();
        reg.assign_owner(a, 1, 0);
        reg.begin_round(b, 2, &mask(vec![1])).unwrap();
        reg.assign_owner(b, 2, 0);
        reg.complete_round(2, true, None);
        reg.begin_round(c, 3, &mask(vec![1])).unwrap();
        reg.assign_owner(c, 3, 1);
        reg.close(c).unwrap();
        assert_eq!(reg.worker_load(2), vec![(2, 1), (0, 0)]);
        assert_eq!(reg.open_count(), 2);
        // stale owner index past the worker count is ignored, not a panic
        let d = reg.open("t");
        reg.begin_round(d, 4, &mask(vec![1])).unwrap();
        reg.assign_owner(d, 4, 9);
        let _ = reg.worker_load(2);
    }

    #[test]
    fn pin_ids_never_collide_with_request_ids() {
        assert!(pin_id(1) >= SESSION_PIN_BASE);
        assert_ne!(pin_id(1), pin_id(2));
        assert_eq!(pin_id(7) & !SESSION_PIN_BASE, 7);
    }
}
