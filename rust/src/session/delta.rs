//! Round-over-round mask diffing (delta-mask round reuse).
//!
//! An interactive editing session refines the same masked region over
//! many rounds; between rounds the mask either stays put or drifts by a
//! few tokens. The reuse invariant the session plane maintains: when two
//! consecutive rounds share the *canonical id-set* (sorted, deduplicated
//! masked token ids over the same latent grid), everything keyed by that
//! id-set is reusable verbatim — the masked-first permutation and its
//! gather indices, the memoized Algorithm-1 plan (same bucket, same warm
//! mask), and, critically, the device KV tier keys (`KvKey.ids` is the
//! interned canonical id-set). Routed to the same worker, such a round
//! runs entirely on device-tier hits: **zero KV upload bytes**. A drifted
//! mask changes the id-set, so the round re-keys and pays cold uploads
//! once; [`diff`] reports exactly how much drifted for observability.

use crate::model::MaskSpec;

/// The id-set difference between consecutive rounds' masks.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct MaskDelta {
    /// Token ids masked in the new round but not the previous one.
    pub added: Vec<usize>,
    /// Token ids masked in the previous round but not the new one.
    pub removed: Vec<usize>,
}

impl MaskDelta {
    /// No drift: the canonical id-sets are identical.
    pub fn is_empty(&self) -> bool {
        self.added.is_empty() && self.removed.is_empty()
    }

    /// Total ids that changed between the rounds.
    pub fn churn(&self) -> usize {
        self.added.len() + self.removed.len()
    }
}

/// Whether two masks share the canonical id-set (the delta-mask reuse
/// predicate: same latent grid, same sorted masked ids).
pub fn same_ids(a: &MaskSpec, b: &MaskSpec) -> bool {
    a.tokens() == b.tokens() && a.masked_ids() == b.masked_ids()
}

/// Diff two masks' canonical id-sets (linear merge walk over the sorted
/// ids `MaskSpec` maintains).
pub fn diff(prev: &MaskSpec, next: &MaskSpec) -> MaskDelta {
    let (p, n) = (prev.masked_ids(), next.masked_ids());
    let mut delta = MaskDelta::default();
    let (mut i, mut j) = (0, 0);
    while i < p.len() && j < n.len() {
        match p[i].cmp(&n[j]) {
            std::cmp::Ordering::Equal => {
                i += 1;
                j += 1;
            }
            std::cmp::Ordering::Less => {
                delta.removed.push(p[i]);
                i += 1;
            }
            std::cmp::Ordering::Greater => {
                delta.added.push(n[j]);
                j += 1;
            }
        }
    }
    delta.removed.extend_from_slice(&p[i..]);
    delta.added.extend_from_slice(&n[j..]);
    delta
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(ids: Vec<usize>) -> MaskSpec {
        MaskSpec::new(ids, 64)
    }

    #[test]
    fn identical_masks_have_empty_delta() {
        let a = m(vec![3, 1, 7]);
        let b = m(vec![7, 3, 1]); // canonicalization makes order irrelevant
        assert!(same_ids(&a, &b));
        let d = diff(&a, &b);
        assert!(d.is_empty());
        assert_eq!(d.churn(), 0);
    }

    #[test]
    fn drifted_mask_reports_added_and_removed() {
        let a = m(vec![1, 3, 7]);
        let b = m(vec![3, 7, 9, 12]);
        assert!(!same_ids(&a, &b));
        let d = diff(&a, &b);
        assert_eq!(d.removed, vec![1]);
        assert_eq!(d.added, vec![9, 12]);
        assert_eq!(d.churn(), 3);
    }

    #[test]
    fn different_grids_never_match() {
        let a = MaskSpec::new(vec![1, 2], 64);
        let b = MaskSpec::new(vec![1, 2], 256);
        assert!(!same_ids(&a, &b));
    }

    #[test]
    fn diff_handles_disjoint_and_prefix_sets() {
        let d = diff(&m(vec![0, 1]), &m(vec![10, 11]));
        assert_eq!(d.removed, vec![0, 1]);
        assert_eq!(d.added, vec![10, 11]);
        let d = diff(&m(vec![5, 6, 7]), &m(vec![5, 6]));
        assert_eq!(d.removed, vec![7]);
        assert!(d.added.is_empty());
    }
}
