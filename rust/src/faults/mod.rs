//! Deterministic fault-injection plane + the robustness primitives that
//! absorb the injected (and real) failures.
//!
//! The serving stack has three layers that can actually fail in
//! production: **storage** (disk-tier spill reads/writes, bit-flips,
//! device-tier uploads), **transport** (RPC connects, drops, delays,
//! truncated bodies), and the **engine** (loader jobs, worker crashes at
//! step boundaries). A [`FaultPlan`] assigns each injection site a
//! probability; the shared [`FaultInjector`] draws every decision from a
//! per-site [`Pcg`] stream seeded by the plan, so
//!
//! * runs are reproducible — the same plan produces the same fault
//!   sequence, and
//! * injected faults never perturb request RNG (masks, prompts, noise
//!   trajectories all read different streams), which is what lets the
//!   chaos tests assert **bit-identical** latents against a fault-free
//!   run.
//!
//! Plans parse from `--faults <spec>` / `EngineConfig.faults`:
//!
//! ```text
//! seed=42,disk_read=0.05,disk_corrupt=0.01,rpc_drop=0.02,delay_ms=5
//! ```
//!
//! Alongside the injector live the degradation-ladder primitives:
//! [`CircuitBreaker`] (a repeatedly failing tier is routed around until a
//! cooldown elapses) and [`RetryBudget`] + [`jittered_backoff`] (the
//! router's per-worker token-bucket retry policy — exhausted budgets
//! surface `Retry-After` instead of retrying). Both take explicit clocks
//! so their math is unit-testable without sleeping.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::util::rng::{splitmix64, Pcg};

/// One injectable failure site. The order is the wire/spec order; each
/// site owns an isolated RNG stream inside the injector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultSite {
    /// Disk-tier spill read fails with an I/O error (transient: the
    /// spill file itself is intact).
    DiskRead,
    /// Disk-tier spill write fails; the evicted template is dropped
    /// instead of spilled (recomputable later — never a request error).
    DiskWrite,
    /// A bit-flip is written into the spill payload; the per-artifact
    /// checksum catches it on the next read.
    DiskCorrupt,
    /// Device KV-tier upload/retention fails; the engine re-uploads per
    /// step (device → host demotion).
    DeviceUpload,
    /// RPC connect refused.
    RpcConnect,
    /// RPC request dropped before a byte is written.
    RpcDrop,
    /// RPC response body truncated mid-flight (protocol error).
    RpcTruncate,
    /// RPC call delayed by the plan's `delay_ms` before running.
    RpcDelay,
    /// A cache-loader staging job dies before delivering its block.
    LoaderFail,
    /// The worker "crashes" at a step boundary: all in-flight denoise
    /// progress is lost and members restart deterministically from step
    /// 0 (the recovery the deterministic engine makes cheap).
    WorkerCrash,
}

/// Number of injectable sites (array sizing).
pub const SITE_COUNT: usize = 10;

/// All sites, in spec order.
pub const ALL_SITES: [FaultSite; SITE_COUNT] = [
    FaultSite::DiskRead,
    FaultSite::DiskWrite,
    FaultSite::DiskCorrupt,
    FaultSite::DeviceUpload,
    FaultSite::RpcConnect,
    FaultSite::RpcDrop,
    FaultSite::RpcTruncate,
    FaultSite::RpcDelay,
    FaultSite::LoaderFail,
    FaultSite::WorkerCrash,
];

impl FaultSite {
    fn index(self) -> usize {
        match self {
            FaultSite::DiskRead => 0,
            FaultSite::DiskWrite => 1,
            FaultSite::DiskCorrupt => 2,
            FaultSite::DeviceUpload => 3,
            FaultSite::RpcConnect => 4,
            FaultSite::RpcDrop => 5,
            FaultSite::RpcTruncate => 6,
            FaultSite::RpcDelay => 7,
            FaultSite::LoaderFail => 8,
            FaultSite::WorkerCrash => 9,
        }
    }

    /// The spec key (`--faults disk_read=0.05`) and counter label.
    pub fn key(self) -> &'static str {
        match self {
            FaultSite::DiskRead => "disk_read",
            FaultSite::DiskWrite => "disk_write",
            FaultSite::DiskCorrupt => "disk_corrupt",
            FaultSite::DeviceUpload => "device_upload",
            FaultSite::RpcConnect => "rpc_connect",
            FaultSite::RpcDrop => "rpc_drop",
            FaultSite::RpcTruncate => "rpc_truncate",
            FaultSite::RpcDelay => "rpc_delay",
            FaultSite::LoaderFail => "loader_fail",
            FaultSite::WorkerCrash => "worker_crash",
        }
    }

    fn from_key(key: &str) -> Option<FaultSite> {
        ALL_SITES.iter().copied().find(|s| s.key() == key)
    }
}

/// A seeded fault schedule: per-site probabilities plus the delay used by
/// [`FaultSite::RpcDelay`].
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    /// Base seed for every site stream.
    pub seed: u64,
    /// `rates[site.index()]` = probability in `[0, 1]` that one draw at
    /// that site injects a fault.
    pub rates: [f64; SITE_COUNT],
    /// Injected delay for `rpc_delay` faults.
    pub delay_ms: u64,
}

impl Default for FaultPlan {
    fn default() -> FaultPlan {
        FaultPlan { seed: 0, rates: [0.0; SITE_COUNT], delay_ms: 5 }
    }
}

impl FaultPlan {
    /// An all-zero plan with the given seed (builder base).
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan { seed, ..FaultPlan::default() }
    }

    /// Builder: set one site's injection rate.
    pub fn with_rate(mut self, site: FaultSite, rate: f64) -> FaultPlan {
        self.rates[site.index()] = rate;
        self
    }

    /// The rate configured for one site.
    pub fn rate(&self, site: FaultSite) -> f64 {
        self.rates[site.index()]
    }

    /// Whether any site can fire at all.
    pub fn is_active(&self) -> bool {
        self.rates.iter().any(|&r| r > 0.0)
    }

    /// Parse a `--faults` spec: comma-separated `key=value` pairs where
    /// key is a site name (`disk_read`, `rpc_drop`, ...), `seed`, or
    /// `delay_ms`. Rates outside `[0, 1]`, malformed numbers, and
    /// unknown keys are rejected (a typo must not silently disable the
    /// fault it meant to enable).
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan::default();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (key, value) = part
                .split_once('=')
                .ok_or_else(|| format!("fault spec item {part:?} is not key=value"))?;
            let (key, value) = (key.trim(), value.trim());
            match key {
                "seed" => {
                    plan.seed = value
                        .parse()
                        .map_err(|_| format!("bad fault seed {value:?}"))?;
                }
                "delay_ms" => {
                    plan.delay_ms = value
                        .parse()
                        .map_err(|_| format!("bad delay_ms {value:?}"))?;
                }
                _ => {
                    let site = FaultSite::from_key(key).ok_or_else(|| {
                        format!("unknown fault site {key:?} (sites: disk_read, disk_write, disk_corrupt, device_upload, rpc_connect, rpc_drop, rpc_truncate, rpc_delay, loader_fail, worker_crash)")
                    })?;
                    let rate: f64 = value
                        .parse()
                        .map_err(|_| format!("bad rate {value:?} for {key}"))?;
                    if !(0.0..=1.0).contains(&rate) {
                        return Err(format!("rate {rate} for {key} outside [0, 1]"));
                    }
                    plan.rates[site.index()] = rate;
                }
            }
        }
        Ok(plan)
    }
}

/// Per-site injector state: an isolated RNG stream plus a fired counter.
struct SiteState {
    rng: Mutex<Pcg>,
    injected: AtomicU64,
}

/// Shared, thread-safe fault source. One injector per serving plane
/// (cluster or router); every component that can fail holds an
/// `Option<Arc<FaultInjector>>` and asks [`FaultInjector::should`] at
/// its injection point. Sites with rate 0 never take the stream lock.
pub struct FaultInjector {
    plan: FaultPlan,
    sites: Vec<SiteState>,
}

/// RNG stream tag base for fault sites (disjoint from request streams).
const FAULT_STREAM_BASE: u64 = 0xfa17_0000;

impl FaultInjector {
    pub fn new(plan: FaultPlan) -> FaultInjector {
        let sites = (0..SITE_COUNT)
            .map(|i| SiteState {
                rng: Mutex::new(Pcg::with_stream(plan.seed, FAULT_STREAM_BASE + i as u64)),
                injected: AtomicU64::new(0),
            })
            .collect();
        FaultInjector { plan, sites }
    }

    /// Convenience: build from an optional plan, `None` when inactive
    /// (the no-faults hot path stays a null check).
    pub fn from_plan(plan: Option<&FaultPlan>) -> Option<Arc<FaultInjector>> {
        plan.filter(|p| p.is_active())
            .map(|p| Arc::new(FaultInjector::new(p.clone())))
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// Draw one decision at `site`. Deterministic given the plan: the
    /// n-th draw at a site always lands the same way, regardless of what
    /// other sites drew in between.
    pub fn should(&self, site: FaultSite) -> bool {
        let rate = self.plan.rates[site.index()];
        if rate <= 0.0 {
            return false;
        }
        let state = &self.sites[site.index()];
        let hit = rate >= 1.0 || state.rng.lock().unwrap().f64() < rate;
        if hit {
            state.injected.fetch_add(1, Ordering::Relaxed);
        }
        hit
    }

    /// A deterministic 64-bit word from a site's stream (corruption
    /// offsets, jitter salts). Counts as an injection draw.
    pub fn word(&self, site: FaultSite) -> u64 {
        self.sites[site.index()].rng.lock().unwrap().next_u64()
    }

    /// The injected delay for [`FaultSite::RpcDelay`] faults.
    pub fn delay(&self) -> Duration {
        Duration::from_millis(self.plan.delay_ms)
    }

    /// Faults fired at one site so far.
    pub fn injected(&self, site: FaultSite) -> u64 {
        self.sites[site.index()].injected.load(Ordering::Relaxed)
    }

    /// Faults fired across all sites.
    pub fn total_injected(&self) -> u64 {
        self.sites
            .iter()
            .map(|s| s.injected.load(Ordering::Relaxed))
            .sum()
    }

    /// `(site key, fired count)` for every site (bench/report output).
    pub fn counts(&self) -> Vec<(&'static str, u64)> {
        ALL_SITES
            .iter()
            .map(|&s| (s.key(), self.injected(s)))
            .collect()
    }
}

impl std::fmt::Debug for FaultInjector {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FaultInjector")
            .field("plan", &self.plan)
            .field("total_injected", &self.total_injected())
            .finish()
    }
}

/// Default consecutive-failure threshold before a tier breaker opens.
pub const BREAKER_THRESHOLD: u32 = 3;

/// Default breaker cooldown before a half-open probe is allowed.
pub const BREAKER_COOLDOWN: Duration = Duration::from_millis(500);

#[derive(Debug, Default)]
struct BreakerInner {
    consecutive: u32,
    open_until: Option<Instant>,
    trips: u64,
}

/// Per-tier circuit breaker: `threshold` *consecutive* failures open the
/// circuit for `cooldown`; while open, callers skip the tier entirely
/// (the degradation ladder recomputes instead of hammering a failing
/// disk). After the cooldown one probe is allowed — its success closes
/// the breaker, its failure re-opens immediately.
pub struct CircuitBreaker {
    threshold: u32,
    cooldown: Duration,
    inner: Mutex<BreakerInner>,
}

impl CircuitBreaker {
    pub fn new(threshold: u32, cooldown: Duration) -> CircuitBreaker {
        assert!(threshold > 0, "breaker threshold must be positive");
        CircuitBreaker { threshold, cooldown, inner: Mutex::new(BreakerInner::default()) }
    }

    /// Whether a call may proceed right now (closed, or cooled down
    /// enough for a half-open probe).
    pub fn allow(&self) -> bool {
        self.allow_at(Instant::now())
    }

    /// [`CircuitBreaker::allow`] against an explicit clock (tests).
    pub fn allow_at(&self, now: Instant) -> bool {
        let inner = self.inner.lock().unwrap();
        match inner.open_until {
            Some(until) => now >= until,
            None => true,
        }
    }

    pub fn record_success(&self) {
        let mut inner = self.inner.lock().unwrap();
        inner.consecutive = 0;
        inner.open_until = None;
    }

    pub fn record_failure(&self) {
        self.record_failure_at(Instant::now());
    }

    pub fn record_failure_at(&self, now: Instant) {
        let mut inner = self.inner.lock().unwrap();
        // a failed half-open probe re-opens without needing a fresh run
        // of `threshold` failures
        let reopen = inner.open_until.is_some();
        inner.consecutive = inner.consecutive.saturating_add(1);
        if reopen || inner.consecutive >= self.threshold {
            inner.open_until = Some(now + self.cooldown);
            inner.trips += 1;
            inner.consecutive = 0;
        }
    }

    /// Whether the circuit is open (cooldown still running).
    pub fn is_open(&self) -> bool {
        !self.allow()
    }

    /// Times the breaker has opened so far.
    pub fn trips(&self) -> u64 {
        self.inner.lock().unwrap().trips
    }
}

#[derive(Debug)]
struct BudgetInner {
    tokens: f64,
    last: Instant,
    spent: u64,
}

/// Token-bucket retry budget: `capacity` tokens, refilled continuously
/// at `refill_per_sec`. Each retry spends one token; an empty bucket
/// refuses ([`RetryBudget::try_spend`] = false) and reports how long
/// until the next token ([`RetryBudget::retry_after_ms`]) so the caller
/// can surface `Retry-After` instead of retrying.
pub struct RetryBudget {
    capacity: f64,
    refill_per_sec: f64,
    inner: Mutex<BudgetInner>,
}

impl RetryBudget {
    pub fn new(capacity: f64, refill_per_sec: f64) -> RetryBudget {
        assert!(capacity >= 1.0, "budget capacity must hold >= 1 token");
        assert!(refill_per_sec > 0.0, "refill rate must be positive");
        RetryBudget {
            capacity,
            refill_per_sec,
            inner: Mutex::new(BudgetInner {
                tokens: capacity,
                last: Instant::now(),
                spent: 0,
            }),
        }
    }

    fn refill(&self, inner: &mut BudgetInner, now: Instant) {
        let dt = now.saturating_duration_since(inner.last).as_secs_f64();
        inner.tokens = (inner.tokens + dt * self.refill_per_sec).min(self.capacity);
        inner.last = now;
    }

    /// Spend one token if available.
    pub fn try_spend(&self) -> bool {
        self.try_spend_at(Instant::now())
    }

    /// [`RetryBudget::try_spend`] against an explicit clock (tests). The
    /// clock must be monotone across calls (earlier instants refill
    /// nothing).
    pub fn try_spend_at(&self, now: Instant) -> bool {
        let mut inner = self.inner.lock().unwrap();
        self.refill(&mut inner, now);
        if inner.tokens >= 1.0 {
            inner.tokens -= 1.0;
            inner.spent += 1;
            true
        } else {
            false
        }
    }

    /// Current token count (refilled to `now`).
    pub fn tokens_at(&self, now: Instant) -> f64 {
        let mut inner = self.inner.lock().unwrap();
        self.refill(&mut inner, now);
        inner.tokens
    }

    /// Tokens spent over the budget's lifetime.
    pub fn spent(&self) -> u64 {
        self.inner.lock().unwrap().spent
    }

    /// Milliseconds until one full token is available (0 when spendable
    /// now) — the `Retry-After` hint on budget exhaustion.
    pub fn retry_after_ms(&self) -> u64 {
        self.retry_after_ms_at(Instant::now())
    }

    pub fn retry_after_ms_at(&self, now: Instant) -> u64 {
        let mut inner = self.inner.lock().unwrap();
        self.refill(&mut inner, now);
        if inner.tokens >= 1.0 {
            return 0;
        }
        let deficit = 1.0 - inner.tokens;
        (deficit / self.refill_per_sec * 1e3).ceil() as u64
    }
}

/// Jittered exponential backoff, bounded to `[base, cap]`: the ceiling
/// doubles per attempt (`base << attempt`, saturating at `cap`) and the
/// result is drawn uniformly in `[base, ceiling]` from `salt` — full
/// jitter, but never below `base`, so property tests can pin both ends.
pub fn jittered_backoff(base: Duration, cap: Duration, attempt: u32, salt: u64) -> Duration {
    let base_ns = base.as_nanos() as u64;
    let cap_ns = cap.as_nanos().min(u64::MAX as u128) as u64;
    if cap_ns <= base_ns {
        return base;
    }
    let ceiling = base_ns
        .saturating_mul(1u64.checked_shl(attempt.min(32)).unwrap_or(u64::MAX))
        .min(cap_ns);
    // uniform in [base, ceiling] via a 53-bit fraction of the salt hash
    let frac = (splitmix64(salt) >> 11) as f64 / (1u64 << 53) as f64;
    let span = (ceiling - base_ns) as f64;
    Duration::from_nanos(base_ns + (span * frac) as u64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::prop_check;

    #[test]
    fn plan_parses_full_spec_and_rejects_garbage() {
        let plan =
            FaultPlan::parse("seed=42, disk_read=0.05, rpc_drop=0.5, delay_ms=7, worker_crash=1")
                .expect("valid spec");
        assert_eq!(plan.seed, 42);
        assert_eq!(plan.delay_ms, 7);
        assert_eq!(plan.rate(FaultSite::DiskRead), 0.05);
        assert_eq!(plan.rate(FaultSite::RpcDrop), 0.5);
        assert_eq!(plan.rate(FaultSite::WorkerCrash), 1.0);
        assert_eq!(plan.rate(FaultSite::DiskWrite), 0.0);
        assert!(plan.is_active());
        assert!(!FaultPlan::parse("").unwrap().is_active());
        assert!(FaultPlan::parse("disk_red=0.1").is_err(), "typo must be rejected");
        assert!(FaultPlan::parse("disk_read=1.5").is_err(), "rate > 1 rejected");
        assert!(FaultPlan::parse("disk_read=-0.1").is_err());
        assert!(FaultPlan::parse("disk_read").is_err(), "missing value rejected");
        assert!(FaultPlan::parse("seed=x").is_err());
    }

    #[test]
    fn injector_is_deterministic_and_streams_are_isolated() {
        let plan = FaultPlan::new(7)
            .with_rate(FaultSite::DiskRead, 0.3)
            .with_rate(FaultSite::RpcDrop, 0.3);
        let a = FaultInjector::new(plan.clone());
        let b = FaultInjector::new(plan.clone());
        // same plan => identical decision sequences per site
        let seq_a: Vec<bool> = (0..64).map(|_| a.should(FaultSite::DiskRead)).collect();
        let seq_b: Vec<bool> = (0..64).map(|_| b.should(FaultSite::DiskRead)).collect();
        assert_eq!(seq_a, seq_b);
        // interleaving draws at another site must not shift the stream
        let c = FaultInjector::new(plan);
        let seq_c: Vec<bool> = (0..64)
            .map(|_| {
                c.should(FaultSite::RpcDrop); // foreign-site draw in between
                c.should(FaultSite::DiskRead)
            })
            .collect();
        assert_eq!(seq_a, seq_c, "per-site streams must be isolated");
        assert_eq!(
            a.injected(FaultSite::DiskRead),
            seq_a.iter().filter(|&&h| h).count() as u64
        );
        assert_eq!(a.injected(FaultSite::WorkerCrash), 0);
    }

    #[test]
    fn zero_and_one_rates_are_exact() {
        let inj = FaultInjector::new(
            FaultPlan::new(1)
                .with_rate(FaultSite::LoaderFail, 1.0)
                .with_rate(FaultSite::DiskRead, 0.0),
        );
        for _ in 0..32 {
            assert!(inj.should(FaultSite::LoaderFail));
            assert!(!inj.should(FaultSite::DiskRead));
        }
        assert_eq!(inj.injected(FaultSite::LoaderFail), 32);
        assert_eq!(inj.total_injected(), 32);
    }

    #[test]
    fn from_plan_gates_on_activity() {
        assert!(FaultInjector::from_plan(None).is_none());
        assert!(FaultInjector::from_plan(Some(&FaultPlan::new(3))).is_none());
        let active = FaultPlan::new(3).with_rate(FaultSite::DiskRead, 0.1);
        assert!(FaultInjector::from_plan(Some(&active)).is_some());
    }

    #[test]
    fn breaker_trips_after_threshold_and_probes_after_cooldown() {
        let t0 = Instant::now();
        let br = CircuitBreaker::new(3, Duration::from_millis(100));
        assert!(br.allow_at(t0));
        br.record_failure_at(t0);
        br.record_failure_at(t0);
        assert!(br.allow_at(t0), "below threshold stays closed");
        br.record_failure_at(t0);
        assert!(!br.allow_at(t0), "third consecutive failure opens");
        assert_eq!(br.trips(), 1);
        // success resets nothing while open; cooldown gates the probe
        assert!(!br.allow_at(t0 + Duration::from_millis(99)));
        assert!(br.allow_at(t0 + Duration::from_millis(100)), "half-open probe");
        // failed probe re-opens immediately (no fresh threshold run)
        br.record_failure_at(t0 + Duration::from_millis(100));
        assert!(!br.allow_at(t0 + Duration::from_millis(150)));
        assert_eq!(br.trips(), 2);
        // successful probe closes and clears the failure run
        br.record_success();
        assert!(br.allow_at(t0));
        br.record_failure_at(t0);
        br.record_failure_at(t0);
        assert!(br.allow_at(t0), "success reset the consecutive count");
    }

    #[test]
    fn property_backoff_stays_within_base_and_cap() {
        prop_check("jittered backoff in [base, cap]", 300, |rng| {
            let base = Duration::from_millis(1 + rng.below(50) as u64);
            let cap = base + Duration::from_millis(rng.below(2_000) as u64);
            let attempt = rng.below(40) as u32;
            let d = jittered_backoff(base, cap, attempt, rng.next_u64());
            prop_assert!(d >= base, "backoff {d:?} below base {base:?}");
            prop_assert!(d <= cap, "backoff {d:?} above cap {cap:?}");
            // attempt 0 has no headroom beyond base by construction
            let first = jittered_backoff(base, cap, 0, rng.next_u64());
            prop_assert!(first == base, "attempt 0 must sit at base, got {first:?}");
            Ok(())
        });
    }

    #[test]
    fn property_budget_refills_at_configured_rate() {
        prop_check("token bucket refill rate + capacity", 200, |rng| {
            let capacity = 1.0 + rng.below(20) as f64;
            let rate = 0.5 + rng.f64() * 50.0;
            let budget = RetryBudget::new(capacity, rate);
            let t0 = Instant::now();
            // drain the full bucket; the next spend must fail
            for i in 0..capacity as usize {
                prop_assert!(budget.try_spend_at(t0), "token {i} of {capacity} missing");
            }
            prop_assert!(!budget.try_spend_at(t0), "overdraw allowed");
            prop_assert!(budget.spent() == capacity as u64, "spent {}", budget.spent());
            // after dt seconds the bucket holds ~rate*dt tokens (capped)
            let dt_ms = 1 + rng.below(5_000) as u64;
            let later = t0 + Duration::from_millis(dt_ms);
            let expect = (rate * dt_ms as f64 / 1e3).min(capacity);
            let got = budget.tokens_at(later);
            prop_assert!(
                (got - expect).abs() < 1e-6,
                "refill: expected {expect} tokens after {dt_ms}ms at {rate}/s, got {got}"
            );
            // and a long wait never exceeds capacity
            let full = budget.tokens_at(later + Duration::from_secs(3_600));
            prop_assert!((full - capacity).abs() < 1e-9, "cap breached: {full}");
            Ok(())
        });
    }

    #[test]
    fn property_exhausted_budget_reports_retry_after() {
        prop_check("exhausted budget surfaces Retry-After", 200, |rng| {
            let rate = 0.5 + rng.f64() * 20.0;
            let budget = RetryBudget::new(1.0 + rng.below(5) as f64, rate);
            let t0 = Instant::now();
            while budget.try_spend_at(t0) {}
            let wait = budget.retry_after_ms_at(t0);
            prop_assert!(wait > 0, "empty bucket must report a positive wait");
            let bound = (1e3 / rate).ceil() as u64 + 1;
            prop_assert!(wait <= bound, "wait {wait}ms exceeds one-token bound {bound}ms");
            // the reported wait is honest: a token exists once it elapses
            let then = t0 + Duration::from_millis(wait);
            prop_assert!(
                budget.try_spend_at(then),
                "token missing after the reported {wait}ms"
            );
            prop_assert!(budget.retry_after_ms_at(t0) > 0, "still exhausted at t0");
            Ok(())
        });
    }
}
