//! Cluster deployment: N workers + scheduler + response collection
//! (paper Fig. 8: scheduler routes ① ② , workers serve ③ ④ , results
//! return ⑤ ).
//!
//! The request lifecycle is handle-based: [`Cluster::submit`] routes a
//! request and returns an [`EditTicket`] whose `wait(timeout)` resolves to
//! that request's own `Result<EditResponse, EditError>` — fulfilled by the
//! collector through the per-id [`RequestRegistry`] (no global completion
//! counting, so concurrent frontends can never observe each other's
//! results). Queued requests can be cancelled ([`Cluster::cancel`]), and
//! the batch-replay rendezvous [`Cluster::await_completed`] blocks on the
//! registry Condvar instead of sleep-polling.
//!
//! Templates are an **online** resource (§2.2: they arrive continuously):
//! each worker owns its own cache tier ([`TieredStore`]), fronted by the
//! cluster-level [`TemplateRegistry`] that owns the authoritative set,
//! reference counts in-flight edits, and tracks registration epochs.
//! [`Cluster::register_template_async`] traces a new template on a
//! low-priority background lane while serving continues;
//! [`Cluster::retire_template`] drains in-flight edits and then frees the
//! template's bytes on every worker tier. Routing sees per-worker
//! residency through [`RouteCtx`], so the mask-aware and cache-aware
//! policies charge a cache-load penalty to workers whose host tier is
//! cold for the request's template (Algorithm 2's "computation + cache
//! loading" cost).
//!
//! QoS (`engine.qos`): requests carry a priority class and an optional
//! deadline; [`Cluster::submit_guarded`] runs the
//! [`AdmissionController`]'s feasibility gate before routing, shedding
//! over-capacity work with `Overloaded` (HTTP 429 + `Retry-After`) and
//! impossible deadlines with `DeadlineInfeasible` (422). Worker queues
//! pop in aged priority order, full batches preempt their lowest-class
//! member at a step boundary when an `Interactive` request waits, and
//! [`Cluster::cancel`] reaches parked/preempted requests via cancel marks
//! ([`CancelOutcome::Cancelling`]).
//!
//! Sessions (`session`): [`Cluster::open_session`] pins a template under
//! a synthetic request id for a user iterating on one edit;
//! [`Cluster::submit_session_round`] stamps the round with the session id
//! and its sticky-affinity owner ([`RouteCtx::session_owner`]), so the
//! `session-affinity` policy keeps warm rounds on the worker whose tiers
//! already hold the round's KV keys. The collector feeds round
//! completions back into the [`SessionRegistry`];
//! [`Cluster::close_session`] and the idle sweep drain in-flight rounds
//! and release the pin (purging tiers when that drains a retirement).

pub mod lifecycle;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::channel;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::cache::store::{register_template, TemplateActivations};
use crate::cache::tier::{Residency, TierStats, TieredStore};
use crate::cache::LatencyModel;
use crate::config::{CacheMode, EngineConfig, ModelConfig};
use crate::engine::queue::{Submitter, WorkerQueue};
use crate::engine::request::{EditError, EditRequest, EditResponse, WorkerEvent};
use crate::engine::worker::{Worker, WorkerShared, WorkerSnapshot};
use crate::faults::FaultInjector;
use crate::qos::{Admission, AdmissionController, ClassDepth, CLASS_COUNT};
use crate::runtime::ModelRuntime;
use crate::scheduler::{Outstanding, RouteCtx, Scheduler};
use crate::session::{pin_id, RoundPlan, SessionError, SessionRegistry, SessionStatus};
use crate::templates::{
    RegisterAdmission, RetireOutcome, TemplateInfo, TemplateRegistry,
};
use crate::util::pool::ThreadPool;
use crate::workload::TraceEvent;

pub use lifecycle::{CancelOutcome, EditTicket, RequestRegistry, RequestState, RequestStatus};

/// Per-worker load snapshot for stats endpoints.
#[derive(Debug, Clone, Default)]
pub struct WorkerDepth {
    pub worker: usize,
    /// Requests waiting in the worker's queue (either lane + preprocess).
    pub queued: usize,
    /// Requests dispatched to the worker and not yet completed.
    pub outstanding: usize,
    /// Per-class queued depth + oldest-wait age (QoS observability).
    pub classes: [ClassDepth; CLASS_COUNT],
}

/// Per-worker cache-tier snapshot for stats endpoints: the §4.2 hierarchy
/// made observable over HTTP.
#[derive(Debug, Clone)]
pub struct WorkerCache {
    pub worker: usize,
    pub stats: TierStats,
    pub host_bytes: usize,
    pub host_templates: usize,
}

/// One template's cluster-wide status: registry entry + where it lives on
/// each worker.
#[derive(Debug, Clone)]
pub struct TemplateStatus {
    pub info: TemplateInfo,
    /// `residency[w]` = worker w's tier residency for this template.
    pub residency: Vec<Residency>,
}

/// Why a session round was refused: either the session itself (unknown /
/// closed / expired) or the usual edit admission path (template, QoS).
#[derive(Debug, Clone, PartialEq, Eq, thiserror::Error)]
pub enum RoundError {
    #[error(transparent)]
    Session(#[from] SessionError),
    #[error(transparent)]
    Edit(#[from] EditError),
}

/// A running cluster.
pub struct Cluster {
    submitters: Vec<Submitter>,
    queues: Vec<Arc<WorkerQueue>>,
    /// Engine-published per-worker state (running composition, step and
    /// transfer counters) — the live feed behind `worker_snapshots`.
    shareds: Vec<Arc<WorkerShared>>,
    /// Per-worker cache tiers (index = worker id).
    tiers: Vec<Arc<TieredStore>>,
    stops: Vec<Arc<AtomicBool>>,
    handles: Vec<std::thread::JoinHandle<Result<()>>>,
    collector: Option<std::thread::JoinHandle<()>>,
    book: Arc<Mutex<Vec<Vec<Outstanding>>>>,
    scheduler: Mutex<Box<dyn Scheduler>>,
    /// QoS admission control (None when `engine.qos` is disabled).
    admission: Option<AdmissionController>,
    /// Serializes guarded submissions: the admission check and the book
    /// push are not one atomic step, so without this two concurrent
    /// frontends could both pass a nearly-full `max_pending` cap.
    admission_gate: Mutex<()>,
    registry: Arc<RequestRegistry>,
    templates: Arc<TemplateRegistry>,
    /// Interactive session lifecycle (sticky affinity, delta-mask reuse).
    sessions: Arc<SessionRegistry>,
    /// Runtime for template registration traces (launch + online jobs).
    reg_rt: Arc<Mutex<ModelRuntime>>,
    /// Dedicated single-thread background lane for online registration
    /// jobs — kept off the workers' pre/post pools so a multi-second
    /// trace can never occupy a latency-critical pre/post thread (the
    /// workers' own low-priority lanes carry only cheap prefetches).
    reg_pool: ThreadPool,
    cache_mode: CacheMode,
    responses: Arc<Mutex<Vec<Arc<EditResponse>>>>,
    retain_responses: Arc<AtomicBool>,
    pub model: ModelConfig,
    started: Instant,
}

/// Launch options.
pub struct ClusterOpts {
    pub workers: usize,
    pub engine: EngineConfig,
    pub model: String,
    pub artifact_dir: String,
    pub templates: Vec<String>,
    pub lat_model: LatencyModel,
    /// Pre-compile the program grid on every worker before serving
    /// (recommended for latency benches).
    pub warmup: bool,
}

/// Drop a template from every worker tier (retirement purge): host/disk
/// immediately, and the engine-thread-confined device KV tier via a
/// purge request each engine drains at its next loop boundary.
fn purge_tiers(tiers: &[Arc<TieredStore>], shareds: &[Arc<WorkerShared>], template_id: &str) {
    for t in tiers {
        t.remove(template_id);
    }
    for s in shareds {
        s.request_kv_purge(template_id);
    }
}

/// Reuse a spill left on the shared disk tier by a previous launch (or
/// `instgenie register`) instead of re-running the full-model trace —
/// only when the stored activations provably belong to this
/// (model-shape, template) pair: dims, trajectory seed, id, and (for
/// K/V mode) the presence of K/V taps must all match. Spill files carry
/// no model name, so shape + seed is the identity check.
fn warm_start(
    tier: &TieredStore,
    template_id: &str,
    cfg: &ModelConfig,
    mode: CacheMode,
) -> Option<Arc<TemplateActivations>> {
    if tier.residency(template_id) != Residency::Disk {
        return None;
    }
    let found = tier.get(template_id).ok().flatten()?;
    let kv_ok = match mode {
        CacheMode::CacheY => true,
        CacheMode::CacheKV => found.entries().first().map(|e| e.kv.is_some()).unwrap_or(false),
    };
    let compatible = found.template_id == template_id
        && found.steps == cfg.steps
        && found.blocks == cfg.blocks
        && found.tokens == cfg.tokens
        && found.hidden == cfg.hidden
        && found.seed == TemplateActivations::seed_for(template_id)
        && kv_ok;
    compatible.then_some(found)
}

impl Cluster {
    /// Register templates, spawn workers, start the collector.
    pub fn launch(opts: ClusterOpts, scheduler: Box<dyn Scheduler>) -> Result<Cluster> {
        anyhow::ensure!(opts.workers > 0, "need >= 1 worker");
        // One cache tier per worker: host residency is a per-worker
        // property the scheduler routes on. The disk tier is shared
        // (paper §4.2: per-device host memory over common slower
        // storage), so `instgenie register` pre-warms every worker and a
        // template spilled by one worker is promotable by all — spill
        // writes are atomic (tmp + rename), so concurrent evictions of
        // the same template are safe.
        // One injector for the whole deployment (None in production):
        // storage, loader, device and engine sites all draw from its
        // seeded per-site streams, so a chaos run is one `--faults` spec.
        let faults = FaultInjector::from_plan(opts.engine.faults.as_ref());
        let tiers: Vec<Arc<TieredStore>> = (0..opts.workers)
            .map(|_| {
                let mut tier = TieredStore::new(
                    opts.engine.host_cache_budget,
                    opts.engine.spill_dir.clone(),
                    0.0, // cluster benches exercise the host tier; disk pacing off
                );
                if let Some(f) = &faults {
                    tier = tier.with_faults(Arc::clone(f));
                }
                Arc::new(tier)
            })
            .collect();

        let templates = TemplateRegistry::new(opts.model.as_str());

        // Launch-time registration: one trace per *new* (model, template)
        // pair, fanned into every worker tier. `begin_register` dedupes
        // repeated ids within the list, and a compatible spill left by a
        // previous launch (or `instgenie register`) warm-starts the pair
        // without re-running the full-model pass.
        let reg_rt = ModelRuntime::create(&opts.artifact_dir, &opts.model)
            .context("registration runtime")?;
        for tpl in &opts.templates {
            let RegisterAdmission::Started { epoch } = templates.begin_register(tpl) else {
                continue; // already registered (duplicate id in the list)
            };
            let acts = match warm_start(&tiers[0], tpl, &reg_rt.config, opts.engine.cache_mode)
            {
                Some(found) => found,
                None => {
                    // drop any stale/foreign/corrupt spill so it cannot
                    // shadow the fresh trace on a later eviction
                    tiers[0].remove(tpl);
                    register_template(&reg_rt, tpl, opts.engine.cache_mode)?.0
                }
            };
            let bytes = acts.size_bytes();
            for tier in &tiers {
                tier.insert(Arc::clone(&acts))?;
            }
            templates.complete_register(tpl, epoch, bytes);
        }
        let reg_rt = Arc::new(Mutex::new(reg_rt));

        let (tx, rx) = channel::<WorkerEvent>();
        let mut submitters = Vec::new();
        let mut queues = Vec::new();
        let mut shareds = Vec::new();
        let mut stops = Vec::new();
        let mut handles = Vec::new();
        let mut model_cfg = None;
        for w in 0..opts.workers {
            let rt = ModelRuntime::create(&opts.artifact_dir, &opts.model)?;
            if opts.warmup {
                rt.warmup(&[1, 2, 4, 8])?;
            }
            model_cfg.get_or_insert_with(|| rt.config.clone());
            let mut worker = Worker::new(
                w,
                opts.engine.clone(),
                rt,
                Arc::clone(&tiers[w]),
                opts.lat_model.clone(),
                tx.clone(),
            )
            .with_registry(Arc::clone(&templates));
            if let Some(f) = &faults {
                worker = worker.with_faults(Arc::clone(f));
            }
            submitters.push(worker.submitter());
            queues.push(worker.queue());
            shareds.push(worker.shared());
            stops.push(worker.stop_flag());
            handles.push(worker.start());
        }
        drop(tx); // collector exits once all workers drop their senders

        let book: Arc<Mutex<Vec<Vec<Outstanding>>>> =
            Arc::new(Mutex::new(vec![Vec::new(); opts.workers]));
        let registry = RequestRegistry::new();
        let sessions = Arc::new(SessionRegistry::default());
        let responses: Arc<Mutex<Vec<Arc<EditResponse>>>> = Arc::new(Mutex::new(Vec::new()));
        let retain_responses = Arc::new(AtomicBool::new(true));
        let collector = {
            let book = Arc::clone(&book);
            let registry = Arc::clone(&registry);
            let templates = Arc::clone(&templates);
            let sessions = Arc::clone(&sessions);
            let tiers = tiers.clone();
            let shareds = shareds.clone();
            let queues = queues.clone();
            let responses = Arc::clone(&responses);
            let retain = Arc::clone(&retain_responses);
            std::thread::Builder::new()
                .name("collector".into())
                .spawn(move || {
                    while let Ok(event) = rx.recv() {
                        match event {
                            WorkerEvent::Started { id, .. } => registry.mark_running(id),
                            WorkerEvent::Finished { id, worker, result } => {
                                let mut b = book.lock().unwrap();
                                if let Some(lane) = b.get_mut(worker) {
                                    if let Some(pos) =
                                        lane.iter().position(|o| o.id == id)
                                    {
                                        lane.swap_remove(pos);
                                    }
                                }
                                drop(b);
                                // drop any cancel mark / held flag that
                                // raced this completion
                                if let Some(q) = queues.get(worker) {
                                    q.clear_cancel(id);
                                }
                                // the edit no longer pins its template; a
                                // drained retirement purges every tier
                                if let Some(tpl) = templates.release_request(id) {
                                    purge_tiers(&tiers, &shareds, &tpl);
                                }
                                // one Arc per response, shared between the
                                // registry (polling) and the replay log
                                let result = result.map(Arc::new);
                                // session rounds settle their record before
                                // the ticket resolves (no-op otherwise)
                                sessions.complete_round(
                                    id,
                                    result.is_ok(),
                                    result.as_ref().ok().map(|r| r.timing.e2e),
                                );
                                let resp = result.as_ref().ok().map(Arc::clone);
                                if registry.fulfill(id, result)
                                    && retain.load(Ordering::Relaxed)
                                {
                                    if let Some(resp) = resp {
                                        responses.lock().unwrap().push(resp);
                                    }
                                }
                            }
                        }
                    }
                })
                .expect("spawn collector")
        };

        let model = model_cfg.expect("at least one worker");
        // QoS admission control: the same cost model the mask-aware
        // scheduler uses, turned into an up-front feasibility gate
        let admission = opts.engine.qos.enabled.then(|| {
            AdmissionController::new(
                model.clone(),
                opts.lat_model.clone(),
                opts.engine.cache_mode,
                opts.engine.max_batch,
                opts.engine.qos.clone(),
            )
        });
        Ok(Cluster {
            submitters,
            queues,
            shareds,
            tiers,
            stops,
            handles,
            collector: Some(collector),
            book,
            scheduler: Mutex::new(scheduler),
            admission,
            admission_gate: Mutex::new(()),
            registry,
            templates,
            sessions,
            reg_rt,
            reg_pool: ThreadPool::new("tpl-reg", 1),
            cache_mode: opts.engine.cache_mode,
            responses,
            retain_responses,
            model,
            started: Instant::now(),
        })
    }

    pub fn workers(&self) -> usize {
        self.submitters.len()
    }

    /// Whether every worker tier's disk circuit breaker is closed. An
    /// open breaker is not fatal — the tier is routed around and cold
    /// promotions recompute — but readiness surfaces it so operators see
    /// a cluster running degraded. Feeds `/v1/readyz`.
    pub fn breakers_closed(&self) -> bool {
        self.tiers.iter().all(|t| !t.breaker_open())
    }

    /// Total disk-breaker trips across worker tiers (chaos observability).
    pub fn breaker_trips(&self) -> u64 {
        self.tiers.iter().map(|t| t.breaker_trips()).sum()
    }

    /// Whether a submission against this template would be accepted:
    /// ready, or queued behind an in-flight registration. (Workers can
    /// still cold-register ids submitted directly via
    /// [`Cluster::submit`].)
    pub fn has_template(&self, template_id: &str) -> bool {
        self.templates.is_submittable(template_id)
    }

    /// Typed admission check for frontends (`UnknownTemplate`,
    /// `TemplateRetired`, or the registration failure).
    pub fn check_template(&self, template_id: &str) -> Result<(), EditError> {
        self.templates.check_submittable(template_id)
    }

    /// The cluster-wide template table.
    pub fn template_registry(&self) -> &Arc<TemplateRegistry> {
        &self.templates
    }

    /// Start registering a template online: the full-model trace runs as
    /// a background job on the registration lane while the cluster keeps
    /// serving; requests submitted meanwhile queue at the workers until
    /// the template is ready. Idempotent for known templates.
    pub fn register_template_async(&self, template_id: &str) -> RegisterAdmission {
        let admission = self.templates.begin_register(template_id);
        if let RegisterAdmission::Started { epoch } = admission {
            let templates = Arc::clone(&self.templates);
            let tiers = self.tiers.clone();
            let shareds = self.shareds.clone();
            let reg_rt = Arc::clone(&self.reg_rt);
            let mode = self.cache_mode;
            let id = template_id.to_string();
            self.reg_pool.submit_low(move || {
                let traced = {
                    let rt = reg_rt.lock().unwrap();
                    register_template(&rt, &id, mode)
                };
                match traced {
                    Ok((acts, _)) => {
                        let bytes = acts.size_bytes();
                        for tier in &tiers {
                            let _ = tier.insert(Arc::clone(&acts));
                        }
                        if !templates.complete_register(&id, epoch, bytes) {
                            // retired or re-registered while tracing:
                            // un-publish what this stale job staged
                            purge_tiers(&tiers, &shareds, &id);
                        }
                    }
                    Err(e) => templates.fail_register(&id, epoch, &format!("{e:#}")),
                }
            });
        }
        admission
    }

    /// Block until a template leaves `registering` (tests, sync tools).
    pub fn await_template(&self, template_id: &str, timeout: Duration) -> Result<(), EditError> {
        self.templates.wait_ready(template_id, timeout)
    }

    /// Retire a template: new submissions are rejected with
    /// `TemplateRetired`; in-flight edits drain. Its bytes are freed on
    /// every worker tier — now if idle, or when the last in-flight edit
    /// releases it.
    pub fn retire_template(&self, template_id: &str) -> RetireOutcome {
        let outcome = self.templates.retire(template_id);
        if outcome == RetireOutcome::Retired {
            purge_tiers(&self.tiers, &self.shareds, template_id);
        }
        outcome
    }

    /// One template's registry entry + per-worker residency.
    pub fn template_status(&self, template_id: &str) -> Option<TemplateStatus> {
        let info = self.templates.info(template_id)?;
        Some(TemplateStatus {
            residency: self
                .tiers
                .iter()
                .map(|t| t.residency(template_id))
                .collect(),
            info,
        })
    }

    /// Number of known templates (any state) — cheap, for stats.
    pub fn template_count(&self) -> usize {
        self.templates.len()
    }

    /// All templates, sorted by id.
    pub fn templates_status(&self) -> Vec<TemplateStatus> {
        self.templates
            .list()
            .into_iter()
            .map(|info| TemplateStatus {
                residency: self
                    .tiers
                    .iter()
                    .map(|t| t.residency(&info.template_id))
                    .collect(),
                info,
            })
            .collect()
    }

    /// Routing context for one template: per-worker residency + bytes.
    fn route_ctx(&self, template_id: &str) -> RouteCtx {
        RouteCtx {
            residency: self
                .tiers
                .iter()
                .map(|t| t.residency(template_id))
                .collect(),
            template_bytes: self.templates.bytes(template_id).unwrap_or(0),
            available: Vec::new(),
            session_owner: None,
        }
    }

    fn outstanding_for(&self, req: &EditRequest) -> Outstanding {
        Outstanding {
            id: req.id,
            masked_tokens: req.mask.masked_count(),
            remaining_steps: self.model.steps,
            priority: req.priority,
        }
    }

    /// Route + submit one request; returns its completion handle.
    pub fn submit(&self, req: EditRequest) -> EditTicket {
        let outstanding = self.outstanding_for(&req);
        let ctx = self.route_ctx(&req.template_id);
        self.submit_routed(req, outstanding, ctx)
    }

    /// The routing + bookkeeping tail of a submission (outstanding entry
    /// and routing context already built by the caller).
    fn submit_routed(
        &self,
        req: EditRequest,
        outstanding: Outstanding,
        ctx: RouteCtx,
    ) -> EditTicket {
        // pin the template for the request's lifetime (retirement drains
        // on these references)
        self.templates.acquire(req.id, &req.template_id);
        let w = {
            let book = self.book.lock().unwrap();
            let mut sched = self.scheduler.lock().unwrap();
            let w = sched.pick(&outstanding, &book, &ctx);
            w.min(self.submitters.len() - 1)
        };
        let ticket = self
            .registry
            .register(req.id, w, req.priority, req.deadline_ms());
        self.book.lock().unwrap()[w].push(outstanding);
        self.submitters[w].submit(req);
        ticket
    }

    /// Admission core: estimate against the live book + routing context.
    fn assess_admission(
        &self,
        req: &EditRequest,
        outstanding: &Outstanding,
        ctx: &RouteCtx,
    ) -> Result<(), EditError> {
        let Some(ctl) = &self.admission else {
            return Ok(());
        };
        let remaining = req
            .deadline
            .map(|d| d.saturating_duration_since(Instant::now()));
        let book = self.book.lock().unwrap();
        match ctl.assess(outstanding, remaining, &book, ctx) {
            Admission::Admit => Ok(()),
            Admission::Overloaded { retry_after, .. } => Err(EditError::Overloaded {
                retry_after_ms: (retry_after * 1e3).ceil() as u64,
            }),
            Admission::DeadlineInfeasible { estimate, deadline } => {
                Err(EditError::DeadlineInfeasible(format!(
                    "estimated completion {estimate:.3}s exceeds deadline {deadline:.3}s"
                )))
            }
        }
    }

    /// QoS admission check (no-op when QoS is disabled): estimates the
    /// request's completion latency on its best worker and rejects
    /// over-capacity ([`EditError::Overloaded`], HTTP 429 + `Retry-After`)
    /// or deadline-infeasible ([`EditError::DeadlineInfeasible`], 422)
    /// submissions before they reach a queue.
    pub fn check_admission(&self, req: &EditRequest) -> Result<(), EditError> {
        let outstanding = self.outstanding_for(req);
        let ctx = self.route_ctx(&req.template_id);
        self.assess_admission(req, &outstanding, &ctx)
    }

    /// Like [`Cluster::submit`], but with the frontend's typed template
    /// admission check: unknown templates are rejected, retired ones get
    /// `TemplateRetired`, and templates still registering are accepted
    /// (the edit queues at the worker until the template is ready).
    pub fn submit_checked(&self, req: EditRequest) -> Result<EditTicket, EditError> {
        self.check_template(&req.template_id)?;
        Ok(self.submit(req))
    }

    /// The full guarded path the HTTP frontend uses: template check, then
    /// QoS admission, then route + submit. Guarded submissions are
    /// serialized so `max_pending` holds under concurrent frontends; the
    /// outstanding entry and routing context are built once and shared by
    /// the admission check and the routing step.
    pub fn submit_guarded(&self, req: EditRequest) -> Result<EditTicket, EditError> {
        self.check_template(&req.template_id)?;
        let outstanding = self.outstanding_for(&req);
        let ctx = self.route_ctx(&req.template_id);
        let _gate = self.admission_gate.lock().unwrap();
        self.assess_admission(&req, &outstanding, &ctx)?;
        Ok(self.submit_routed(req, outstanding, ctx))
    }

    /// The session lifecycle table (status endpoints, dist overlay).
    pub fn sessions(&self) -> &Arc<SessionRegistry> {
        &self.sessions
    }

    /// One worker's engine-published shared state (SSE progress streams
    /// read per-round event buffers from here).
    pub fn worker_shared(&self, worker: usize) -> Option<Arc<WorkerShared>> {
        self.shareds.get(worker).cloned()
    }

    /// Open an interactive session pinned to `template_id`: the session
    /// holds one in-flight template reference under [`pin_id`] until it
    /// closes or idle-expires, so retirement drains behind it.
    pub fn open_session(&self, template_id: &str) -> Result<u64, EditError> {
        self.check_template(template_id)?;
        let sid = self.sessions.open(template_id);
        self.templates.acquire(pin_id(sid), template_id);
        Ok(sid)
    }

    /// Submit one round of session `sid`. The round inherits the
    /// session's pinned template and is stamped with the session id (so
    /// the engine publishes progress events for it); routing sees the
    /// session's owner through [`RouteCtx::session_owner`] and the round
    /// is recorded against the session once placed. Admission failures
    /// roll the round back ([`SessionRegistry::abort_round`]).
    pub fn submit_session_round(
        &self,
        sid: u64,
        mut req: EditRequest,
    ) -> Result<(EditTicket, RoundPlan), RoundError> {
        let status = self
            .sessions
            .status(sid)
            .ok_or(SessionError::Unknown(sid))?;
        req.template_id = status.template;
        req.session = Some(sid);
        self.check_template(&req.template_id).map_err(RoundError::Edit)?;
        let plan = self.sessions.begin_round(sid, req.id, &req.mask)?;
        let outstanding = self.outstanding_for(&req);
        let mut ctx = self.route_ctx(&req.template_id);
        ctx.session_owner = plan.owner;
        let _gate = self.admission_gate.lock().unwrap();
        if let Err(e) = self.assess_admission(&req, &outstanding, &ctx) {
            self.sessions.abort_round(req.id);
            return Err(e.into());
        }
        let rid = req.id;
        let ticket = self.submit_routed(req, outstanding, ctx);
        self.sessions.assign_owner(sid, rid, ticket.worker());
        Ok((ticket, plan))
    }

    /// Status view of one session (None for unknown ids).
    pub fn session_status(&self, sid: u64) -> Option<SessionStatus> {
        self.sessions.status(sid)
    }

    /// Close a session: further rounds are refused immediately, in-flight
    /// rounds drain (bounded by `drain_timeout`), then the template pin is
    /// released — purging tiers when that drains a retirement.
    pub fn close_session(
        &self,
        sid: u64,
        drain_timeout: Duration,
    ) -> Result<SessionStatus, SessionError> {
        let (_template, inflight) = self.sessions.close(sid)?;
        if inflight > 0 {
            let deadline = Instant::now() + drain_timeout;
            while self.sessions.inflight(sid).unwrap_or(0) > 0 && Instant::now() < deadline {
                std::thread::sleep(Duration::from_millis(2));
            }
        }
        if let Some(t) = self.templates.release_request(pin_id(sid)) {
            purge_tiers(&self.tiers, &self.shareds, &t);
        }
        Ok(self.sessions.status(sid).expect("closed session has status"))
    }

    /// Sweep idle sessions and release their template pins. Returns how
    /// many sessions expired.
    pub fn expire_idle_sessions(&self) -> usize {
        self.expire_idle_sessions_at(Instant::now())
    }

    /// Idle sweep against an explicit clock (tests simulate elapsed idle
    /// time by passing a future instant).
    pub fn expire_idle_sessions_at(&self, now: Instant) -> usize {
        let expired = self.sessions.expire_idle(now);
        for (sid, _template) in &expired {
            if let Some(t) = self.templates.release_request(pin_id(*sid)) {
                purge_tiers(&self.tiers, &self.shareds, &t);
            }
        }
        expired.len()
    }

    /// Realize a trace event into a request (class + deadline included).
    pub fn event_request(&self, ev: &TraceEvent) -> EditRequest {
        let mask = ev.mask(self.model.latent_hw);
        let mut req = EditRequest::new(ev.id, ev.template.clone(), mask, ev.prompt_seed);
        req.priority = ev.priority;
        req.deadline = ev
            .deadline_ms
            .map(|ms| req.arrival + Duration::from_millis(ms));
        req
    }

    /// Convenience: realize and submit a trace event.
    pub fn submit_event(&self, ev: &TraceEvent) -> EditTicket {
        self.submit(self.event_request(ev))
    }

    /// Cancel a request that has not finished. Still-queued requests are
    /// removed synchronously (the removal races fairly with admission:
    /// whoever takes the queue lock first wins, so a cancelled request
    /// never also completes). Requests the worker holds outside its
    /// lanes — mid-preprocess, parked on a registering template, or
    /// preempted — get a cancel mark instead ([`CancelOutcome::
    /// Cancelling`]): the engine thread resolves them to `Cancelled` at
    /// its next step boundary, releasing their slot promptly.
    pub fn cancel(&self, id: u64) -> CancelOutcome {
        let Some(st) = self.registry.status(id) else {
            return CancelOutcome::NotFound;
        };
        let w = st.worker.min(self.queues.len() - 1);
        match st.state {
            RequestState::Done(_) | RequestState::Failed(_) => CancelOutcome::TooLate,
            RequestState::Queued => {
                if !self.queues[w].remove(id) {
                    // popped before we got there: mid-preprocess or parked
                    // at the worker — mark it for the engine thread
                    self.queues[w].request_cancel(id);
                    // if it reached a terminal state in the meantime, the
                    // collector's mark-cleanup may already have run: reap
                    // our own mark so the cancels set cannot leak, and
                    // report the honest outcome
                    if let Some(st) = self.registry.status(id) {
                        if st.state.is_terminal() {
                            self.queues[w].clear_cancel(id);
                            return CancelOutcome::TooLate;
                        }
                    }
                    return CancelOutcome::Cancelling;
                }
                // retire the scheduler's outstanding entry ourselves — the
                // worker will never emit a Finished event for this id (so
                // also reap any mark a previous cancel attempt posted)
                self.queues[w].clear_cancel(id);
                let mut b = self.book.lock().unwrap();
                if let Some(pos) = b[w].iter().position(|o| o.id == id) {
                    b[w].swap_remove(pos);
                }
                drop(b);
                // release the template reference the submission pinned
                if let Some(tpl) = self.templates.release_request(id) {
                    purge_tiers(&self.tiers, &self.shareds, &tpl);
                }
                self.registry.fulfill(id, Err(EditError::Cancelled));
                CancelOutcome::Cancelled
            }
            RequestState::Running => {
                // preempted out of the batch: cancellable via mark. The
                // held-check + mark are one atomic queue op, so a member
                // resuming concurrently either sees the mark (and
                // cancels) or was never marked (and we report TooLate).
                if self.queues[w].cancel_if_held(id) {
                    CancelOutcome::Cancelling
                } else {
                    CancelOutcome::TooLate
                }
            }
        }
    }

    /// Lifecycle snapshot of one request (None for unknown ids).
    pub fn status(&self, id: u64) -> Option<RequestStatus> {
        self.registry.status(id)
    }

    /// Drop a *terminal* lifecycle entry once its result was consumed
    /// (`DELETE /v1/edits/{id}` on a finished request). Live entries are
    /// never evicted; returns whether one was removed.
    pub fn evict(&self, id: u64) -> bool {
        self.registry.evict_terminal(id)
    }

    /// Enable/disable the replay log of successful responses. Batch
    /// replay (`run`, benches, tests) reads it back from [`Cluster::
    /// shutdown`]; long-lived online frontends turn it off so memory is
    /// bounded by live requests + unevicted registry entries only.
    pub fn set_retain_responses(&self, retain: bool) {
        self.retain_responses.store(retain, Ordering::Relaxed);
    }

    /// Live per-worker snapshots (§4.4): the running batch's *actual*
    /// mask composition plus queued ratios, step counts, and step-loop
    /// transfer totals — assembled from the engine-published shared state
    /// rather than the pre-start `Worker::snapshot` handle.
    pub fn worker_snapshots(&self) -> Vec<WorkerSnapshot> {
        // workers are session-blind: the per-worker session counts are
        // overlaid here from the registry's ownership table
        let load = self.sessions.worker_load(self.queues.len());
        self.queues
            .iter()
            .zip(&self.shareds)
            .enumerate()
            .map(|(w, (q, s))| {
                let mut snap = WorkerSnapshot::collect(w, q, s);
                let (open, rounds) = load.get(w).copied().unwrap_or((0, 0));
                snap.sessions_open = open;
                snap.session_rounds = rounds;
                snap
            })
            .collect()
    }

    /// Per-worker queue depth + dispatched-but-unfinished counts, broken
    /// out per class.
    pub fn queue_depths(&self) -> Vec<WorkerDepth> {
        let book = self.book.lock().unwrap();
        let now = Instant::now();
        self.queues
            .iter()
            .enumerate()
            .map(|(w, q)| WorkerDepth {
                worker: w,
                queued: q.pending(),
                outstanding: book.get(w).map(|l| l.len()).unwrap_or(0),
                classes: q.class_depths(now),
            })
            .collect()
    }

    /// Per-worker cache-tier stats (host hits / promotions / misses /
    /// evictions + resident bytes) for `GET /v1/stats`.
    pub fn cache_stats(&self) -> Vec<WorkerCache> {
        self.tiers
            .iter()
            .enumerate()
            .map(|(w, t)| WorkerCache {
                worker: w,
                stats: t.stats(),
                host_bytes: t.host_bytes(),
                host_templates: t.host_templates(),
            })
            .collect()
    }

    /// Requests that reached a terminal state (success, failure, or
    /// cancellation).
    pub fn completed(&self) -> usize {
        self.registry.finished()
    }

    /// Block until `n` requests finished (or timeout). Returns success.
    /// Condvar-backed (signaled by the collector) — kept for the `run`
    /// subcommand's batch replay; online frontends wait on their tickets.
    pub fn await_completed(&self, n: usize, timeout: Duration) -> bool {
        self.registry.await_finished(n, timeout)
    }

    /// Seconds since launch (makespan for reports).
    pub fn elapsed(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Ask every worker thread to stop after its current batch, without
    /// consuming the cluster. Used by the dist plane's `WorkerNode`,
    /// which holds the cluster in an `Arc` and needs to initiate
    /// shutdown from a `&self` RPC handler; the owning thread still calls
    /// [`Cluster::shutdown`] afterwards to join and drain.
    pub fn request_stop(&self) {
        for s in &self.stops {
            s.store(true, Ordering::Relaxed);
        }
    }

    /// Stop workers, drain, and return all successful responses. Tickets
    /// still outstanding afterwards resolve to `WorkerShutdown`.
    pub fn shutdown(mut self) -> Result<Vec<Arc<EditResponse>>> {
        for s in &self.stops {
            s.store(true, Ordering::Relaxed);
        }
        for h in self.handles.drain(..) {
            h.join().expect("worker thread")?;
        }
        if let Some(c) = self.collector.take() {
            c.join().expect("collector thread");
        }
        self.registry.fail_all_pending(EditError::WorkerShutdown);
        let out = std::mem::take(&mut *self.responses.lock().unwrap());
        Ok(out)
    }
}
