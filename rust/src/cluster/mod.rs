//! Cluster deployment: N workers + scheduler + response collection
//! (paper Fig. 8: scheduler routes ① ② , workers serve ③ ④ , results
//! return ⑤ ).
//!
//! The request lifecycle is handle-based: [`Cluster::submit`] routes a
//! request and returns an [`EditTicket`] whose `wait(timeout)` resolves to
//! that request's own `Result<EditResponse, EditError>` — fulfilled by the
//! collector through the per-id [`RequestRegistry`] (no global completion
//! counting, so concurrent frontends can never observe each other's
//! results). Queued requests can be cancelled ([`Cluster::cancel`]), and
//! the batch-replay rendezvous [`Cluster::await_completed`] blocks on the
//! registry Condvar instead of sleep-polling.

pub mod lifecycle;

use std::collections::HashSet;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::channel;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::cache::store::register_template;
use crate::cache::tier::TieredStore;
use crate::cache::LatencyModel;
use crate::config::{EngineConfig, ModelConfig};
use crate::engine::queue::{Submitter, WorkerQueue};
use crate::engine::request::{EditError, EditRequest, EditResponse, WorkerEvent};
use crate::engine::worker::Worker;
use crate::runtime::ModelRuntime;
use crate::scheduler::{Outstanding, Scheduler};
use crate::workload::TraceEvent;

pub use lifecycle::{CancelOutcome, EditTicket, RequestRegistry, RequestState, RequestStatus};

/// Per-worker load snapshot for stats endpoints.
#[derive(Debug, Clone, Default)]
pub struct WorkerDepth {
    pub worker: usize,
    /// Requests waiting in the worker's queue (either lane + preprocess).
    pub queued: usize,
    /// Requests dispatched to the worker and not yet completed.
    pub outstanding: usize,
}

/// A running cluster.
pub struct Cluster {
    submitters: Vec<Submitter>,
    queues: Vec<Arc<WorkerQueue>>,
    stops: Vec<Arc<AtomicBool>>,
    handles: Vec<std::thread::JoinHandle<Result<()>>>,
    collector: Option<std::thread::JoinHandle<()>>,
    book: Arc<Mutex<Vec<Vec<Outstanding>>>>,
    scheduler: Mutex<Box<dyn Scheduler>>,
    registry: Arc<RequestRegistry>,
    responses: Arc<Mutex<Vec<Arc<EditResponse>>>>,
    retain_responses: Arc<AtomicBool>,
    templates: HashSet<String>,
    pub model: ModelConfig,
    started: Instant,
}

/// Launch options.
pub struct ClusterOpts {
    pub workers: usize,
    pub engine: EngineConfig,
    pub model: String,
    pub artifact_dir: String,
    pub templates: Vec<String>,
    pub lat_model: LatencyModel,
    /// Pre-compile the program grid on every worker before serving
    /// (recommended for latency benches).
    pub warmup: bool,
}

impl Cluster {
    /// Register templates, spawn workers, start the collector.
    pub fn launch(opts: ClusterOpts, scheduler: Box<dyn Scheduler>) -> Result<Cluster> {
        anyhow::ensure!(opts.workers > 0, "need >= 1 worker");
        let tiers = Arc::new(TieredStore::new(
            opts.engine.host_cache_budget,
            opts.engine.spill_dir.clone(),
            0.0, // cluster benches exercise the host tier; disk pacing off
        ));

        // one registration pass populates the shared host tier
        {
            let reg_rt = ModelRuntime::create(&opts.artifact_dir, &opts.model)
                .context("registration runtime")?;
            for tpl in &opts.templates {
                let (acts, _) = register_template(&reg_rt, tpl, opts.engine.cache_mode)?;
                tiers.insert(acts)?;
            }
        }

        let (tx, rx) = channel::<WorkerEvent>();
        let mut submitters = Vec::new();
        let mut queues = Vec::new();
        let mut stops = Vec::new();
        let mut handles = Vec::new();
        let mut model_cfg = None;
        for w in 0..opts.workers {
            let rt = ModelRuntime::create(&opts.artifact_dir, &opts.model)?;
            if opts.warmup {
                rt.warmup(&[1, 2, 4, 8])?;
            }
            model_cfg.get_or_insert_with(|| rt.config.clone());
            let worker = Worker::new(
                w,
                opts.engine.clone(),
                rt,
                Arc::clone(&tiers),
                opts.lat_model.clone(),
                tx.clone(),
            );
            submitters.push(worker.submitter());
            queues.push(worker.queue());
            stops.push(worker.stop_flag());
            handles.push(worker.start());
        }
        drop(tx); // collector exits once all workers drop their senders

        let book: Arc<Mutex<Vec<Vec<Outstanding>>>> =
            Arc::new(Mutex::new(vec![Vec::new(); opts.workers]));
        let registry = RequestRegistry::new();
        let responses: Arc<Mutex<Vec<Arc<EditResponse>>>> = Arc::new(Mutex::new(Vec::new()));
        let retain_responses = Arc::new(AtomicBool::new(true));
        let collector = {
            let book = Arc::clone(&book);
            let registry = Arc::clone(&registry);
            let responses = Arc::clone(&responses);
            let retain = Arc::clone(&retain_responses);
            std::thread::Builder::new()
                .name("collector".into())
                .spawn(move || {
                    while let Ok(event) = rx.recv() {
                        match event {
                            WorkerEvent::Started { id, .. } => registry.mark_running(id),
                            WorkerEvent::Finished { id, worker, result } => {
                                let mut b = book.lock().unwrap();
                                if let Some(lane) = b.get_mut(worker) {
                                    if let Some(pos) =
                                        lane.iter().position(|o| o.id == id)
                                    {
                                        lane.swap_remove(pos);
                                    }
                                }
                                drop(b);
                                // one Arc per response, shared between the
                                // registry (polling) and the replay log
                                let result = result.map(Arc::new);
                                let resp = result.as_ref().ok().map(Arc::clone);
                                if registry.fulfill(id, result)
                                    && retain.load(Ordering::Relaxed)
                                {
                                    if let Some(resp) = resp {
                                        responses.lock().unwrap().push(resp);
                                    }
                                }
                            }
                        }
                    }
                })
                .expect("spawn collector")
        };

        Ok(Cluster {
            submitters,
            queues,
            stops,
            handles,
            collector: Some(collector),
            book,
            scheduler: Mutex::new(scheduler),
            registry,
            responses,
            retain_responses,
            templates: opts.templates.iter().cloned().collect(),
            model: model_cfg.expect("at least one worker"),
            started: Instant::now(),
        })
    }

    pub fn workers(&self) -> usize {
        self.submitters.len()
    }

    /// Templates pre-registered at launch (the valid set for the HTTP
    /// frontend; workers can still cold-register ids submitted directly).
    pub fn has_template(&self, template_id: &str) -> bool {
        self.templates.contains(template_id)
    }

    /// Route + submit one request; returns its completion handle.
    pub fn submit(&self, req: EditRequest) -> EditTicket {
        let outstanding = Outstanding {
            id: req.id,
            masked_tokens: req.mask.masked_count(),
            remaining_steps: self.model.steps,
        };
        let w = {
            let book = self.book.lock().unwrap();
            let mut sched = self.scheduler.lock().unwrap();
            let w = sched.pick(&outstanding, &book);
            w.min(self.submitters.len() - 1)
        };
        let ticket = self.registry.register(req.id, w);
        self.book.lock().unwrap()[w].push(outstanding);
        self.submitters[w].submit(req);
        ticket
    }

    /// Like [`Cluster::submit`], but rejects templates that were not
    /// registered at launch. Library-facing convenience over the same
    /// [`Cluster::has_template`] predicate the HTTP frontend checks
    /// before allocating an id.
    pub fn submit_checked(&self, req: EditRequest) -> Result<EditTicket, EditError> {
        if !self.has_template(&req.template_id) {
            return Err(EditError::UnknownTemplate(req.template_id));
        }
        Ok(self.submit(req))
    }

    /// Convenience: realize and submit a trace event.
    pub fn submit_event(&self, ev: &TraceEvent) -> EditTicket {
        let mask = ev.mask(self.model.latent_hw);
        let mut req = EditRequest::new(ev.id, ev.template.clone(), mask, ev.prompt_seed);
        req.arrival = Instant::now();
        self.submit(req)
    }

    /// Cancel a request that is still waiting in its worker queue. The
    /// removal races fairly with admission: whoever takes the queue lock
    /// first wins, so a cancelled request never also completes.
    pub fn cancel(&self, id: u64) -> CancelOutcome {
        let Some(w) = self.registry.worker_if_queued(id) else {
            return if self.registry.status(id).is_some() {
                CancelOutcome::TooLate
            } else {
                CancelOutcome::NotFound
            };
        };
        if !self.queues[w].remove(id) {
            // popped for admission (or mid-preprocess) before we got there
            return CancelOutcome::TooLate;
        }
        // retire the scheduler's outstanding entry ourselves — the worker
        // will never emit a Finished event for this id
        let mut b = self.book.lock().unwrap();
        if let Some(pos) = b[w].iter().position(|o| o.id == id) {
            b[w].swap_remove(pos);
        }
        drop(b);
        self.registry.fulfill(id, Err(EditError::Cancelled));
        CancelOutcome::Cancelled
    }

    /// Lifecycle snapshot of one request (None for unknown ids).
    pub fn status(&self, id: u64) -> Option<RequestStatus> {
        self.registry.status(id)
    }

    /// Drop a *terminal* lifecycle entry once its result was consumed
    /// (`DELETE /v1/edits/{id}` on a finished request). Live entries are
    /// never evicted; returns whether one was removed.
    pub fn evict(&self, id: u64) -> bool {
        self.registry.evict_terminal(id)
    }

    /// Enable/disable the replay log of successful responses. Batch
    /// replay (`run`, benches, tests) reads it back from [`Cluster::
    /// shutdown`]; long-lived online frontends turn it off so memory is
    /// bounded by live requests + unevicted registry entries only.
    pub fn set_retain_responses(&self, retain: bool) {
        self.retain_responses.store(retain, Ordering::Relaxed);
    }

    /// Per-worker queue depth + dispatched-but-unfinished counts.
    pub fn queue_depths(&self) -> Vec<WorkerDepth> {
        let book = self.book.lock().unwrap();
        self.queues
            .iter()
            .enumerate()
            .map(|(w, q)| WorkerDepth {
                worker: w,
                queued: q.pending(),
                outstanding: book.get(w).map(|l| l.len()).unwrap_or(0),
            })
            .collect()
    }

    /// Requests that reached a terminal state (success, failure, or
    /// cancellation).
    pub fn completed(&self) -> usize {
        self.registry.finished()
    }

    /// Block until `n` requests finished (or timeout). Returns success.
    /// Condvar-backed (signaled by the collector) — kept for the `run`
    /// subcommand's batch replay; online frontends wait on their tickets.
    pub fn await_completed(&self, n: usize, timeout: Duration) -> bool {
        self.registry.await_finished(n, timeout)
    }

    /// Seconds since launch (makespan for reports).
    pub fn elapsed(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Stop workers, drain, and return all successful responses. Tickets
    /// still outstanding afterwards resolve to `WorkerShutdown`.
    pub fn shutdown(mut self) -> Result<Vec<Arc<EditResponse>>> {
        for s in &self.stops {
            s.store(true, Ordering::Relaxed);
        }
        for h in self.handles.drain(..) {
            h.join().expect("worker thread")?;
        }
        if let Some(c) = self.collector.take() {
            c.join().expect("collector thread");
        }
        self.registry.fail_all_pending(EditError::WorkerShutdown);
        let out = std::mem::take(&mut *self.responses.lock().unwrap());
        Ok(out)
    }
}
