//! Cluster deployment: N workers + scheduler + response collection
//! (paper Fig. 8: scheduler routes ① ② , workers serve ③ ④ , results
//! return ⑤ ).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::channel;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::cache::store::register_template;
use crate::cache::tier::TieredStore;
use crate::cache::LatencyModel;
use crate::config::{EngineConfig, ModelConfig};
use crate::engine::queue::Submitter;
use crate::engine::request::{EditRequest, EditResponse};
use crate::engine::worker::Worker;
use crate::runtime::ModelRuntime;
use crate::scheduler::{Outstanding, Scheduler};
use crate::workload::TraceEvent;

/// A running cluster.
pub struct Cluster {
    submitters: Vec<Submitter>,
    stops: Vec<Arc<AtomicBool>>,
    handles: Vec<std::thread::JoinHandle<Result<()>>>,
    collector: Option<std::thread::JoinHandle<()>>,
    book: Arc<Mutex<Vec<Vec<Outstanding>>>>,
    scheduler: Mutex<Box<dyn Scheduler>>,
    responses: Arc<Mutex<Vec<EditResponse>>>,
    pub model: ModelConfig,
    started: Instant,
}

/// Launch options.
pub struct ClusterOpts {
    pub workers: usize,
    pub engine: EngineConfig,
    pub model: String,
    pub artifact_dir: String,
    pub templates: Vec<String>,
    pub lat_model: LatencyModel,
    /// Pre-compile the program grid on every worker before serving
    /// (recommended for latency benches).
    pub warmup: bool,
}

impl Cluster {
    /// Register templates, spawn workers, start the collector.
    pub fn launch(opts: ClusterOpts, scheduler: Box<dyn Scheduler>) -> Result<Cluster> {
        anyhow::ensure!(opts.workers > 0, "need >= 1 worker");
        let tiers = Arc::new(TieredStore::new(
            opts.engine.host_cache_budget,
            opts.engine.spill_dir.clone(),
            0.0, // cluster benches exercise the host tier; disk pacing off
        ));

        // one registration pass populates the shared host tier
        {
            let reg_rt = ModelRuntime::create(&opts.artifact_dir, &opts.model)
                .context("registration runtime")?;
            for tpl in &opts.templates {
                let (acts, _) = register_template(&reg_rt, tpl, opts.engine.cache_mode)?;
                tiers.insert(acts)?;
            }
        }

        let (tx, rx) = channel::<EditResponse>();
        let mut submitters = Vec::new();
        let mut stops = Vec::new();
        let mut handles = Vec::new();
        let mut model_cfg = None;
        for w in 0..opts.workers {
            let rt = ModelRuntime::create(&opts.artifact_dir, &opts.model)?;
            if opts.warmup {
                rt.warmup(&[1, 2, 4, 8])?;
            }
            model_cfg.get_or_insert_with(|| rt.config.clone());
            let worker = Worker::new(
                w,
                opts.engine.clone(),
                rt,
                Arc::clone(&tiers),
                opts.lat_model.clone(),
                tx.clone(),
            );
            submitters.push(worker.submitter());
            stops.push(worker.stop_flag());
            handles.push(worker.start());
        }
        drop(tx); // collector exits once all workers drop their senders

        let book: Arc<Mutex<Vec<Vec<Outstanding>>>> =
            Arc::new(Mutex::new(vec![Vec::new(); opts.workers]));
        let responses = Arc::new(Mutex::new(Vec::new()));
        let collector = {
            let book = Arc::clone(&book);
            let responses = Arc::clone(&responses);
            std::thread::Builder::new()
                .name("collector".into())
                .spawn(move || {
                    while let Ok(resp) = rx.recv() {
                        let mut b = book.lock().unwrap();
                        for worker in b.iter_mut() {
                            if let Some(pos) = worker.iter().position(|o| o.id == resp.id) {
                                worker.swap_remove(pos);
                                break;
                            }
                        }
                        drop(b);
                        responses.lock().unwrap().push(resp);
                    }
                })
                .expect("spawn collector")
        };

        Ok(Cluster {
            submitters,
            stops,
            handles,
            collector: Some(collector),
            book,
            scheduler: Mutex::new(scheduler),
            responses,
            model: model_cfg.expect("at least one worker"),
            started: Instant::now(),
        })
    }

    pub fn workers(&self) -> usize {
        self.submitters.len()
    }

    /// Route + submit one request; returns the chosen worker.
    pub fn submit(&self, req: EditRequest) -> usize {
        let outstanding = Outstanding {
            id: req.id,
            masked_tokens: req.mask.masked_count(),
            remaining_steps: self.model.steps,
        };
        let w = {
            let book = self.book.lock().unwrap();
            let mut sched = self.scheduler.lock().unwrap();
            let w = sched.pick(&outstanding, &book);
            w.min(self.submitters.len() - 1)
        };
        self.book.lock().unwrap()[w].push(outstanding);
        self.submitters[w].submit(req);
        w
    }

    /// Convenience: realize and submit a trace event.
    pub fn submit_event(&self, ev: &TraceEvent) -> usize {
        let mask = ev.mask(self.model.latent_hw);
        let mut req = EditRequest::new(ev.id, ev.template.clone(), mask, ev.prompt_seed);
        req.arrival = Instant::now();
        self.submit(req)
    }

    pub fn completed(&self) -> usize {
        self.responses.lock().unwrap().len()
    }

    /// Block until `n` responses arrived (or timeout). Returns success.
    pub fn await_completed(&self, n: usize, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        while self.completed() < n {
            if Instant::now() > deadline {
                return false;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        true
    }

    /// Seconds since launch (makespan for reports).
    pub fn elapsed(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Stop workers, drain, and return all responses.
    pub fn shutdown(mut self) -> Result<Vec<EditResponse>> {
        for s in &self.stops {
            s.store(true, Ordering::Relaxed);
        }
        for h in self.handles.drain(..) {
            h.join().expect("worker thread")?;
        }
        if let Some(c) = self.collector.take() {
            c.join().expect("collector thread");
        }
        let out = std::mem::take(&mut *self.responses.lock().unwrap());
        Ok(out)
    }
}
