//! Per-request lifecycle tracking: the completion table behind
//! [`EditTicket`].
//!
//! Every request submitted through [`crate::cluster::Cluster::submit`]
//! gets an entry here. Workers report `Started`/`Finished` events; the
//! cluster collector translates them into state transitions, and tickets
//! (plus the batch-replay rendezvous `Cluster::await_completed`) block on
//! a single registry Condvar instead of sleep-polling. Terminal entries
//! are retained so `GET /v1/edits/{id}` can poll results after
//! completion, until the client evicts them (`DELETE` on a finished id)
//! or the cluster shuts down.

use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::engine::request::{EditError, EditResponse};
use crate::qos::Priority;

/// Where a request is in its life.
#[derive(Debug, Clone)]
pub enum RequestState {
    /// Accepted, waiting in a worker queue (or in preprocessing).
    Queued,
    /// Joined a worker's running batch.
    Running,
    /// Completed; the response is held for polling frontends.
    Done(Arc<EditResponse>),
    /// Terminated without a response (cancelled, failed, shutdown).
    Failed(EditError),
}

impl RequestState {
    /// Stable label for status endpoints: queued / running / done /
    /// cancelled / failed.
    pub fn label(&self) -> &'static str {
        match self {
            RequestState::Queued => "queued",
            RequestState::Running => "running",
            RequestState::Done(_) => "done",
            RequestState::Failed(EditError::Cancelled) => "cancelled",
            RequestState::Failed(_) => "failed",
        }
    }

    pub fn is_terminal(&self) -> bool {
        matches!(self, RequestState::Done(_) | RequestState::Failed(_))
    }
}

/// Snapshot of one request's lifecycle entry.
#[derive(Debug, Clone)]
pub struct RequestStatus {
    pub id: u64,
    pub worker: usize,
    pub state: RequestState,
    /// Seconds since submission (age for status endpoints).
    pub age_secs: f64,
    /// Request class, as submitted (echoed by status endpoints).
    pub priority: Priority,
    /// Deadline as submitted (ms after arrival), if any.
    pub deadline_ms: Option<u64>,
}

/// Result of a cancellation attempt.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CancelOutcome {
    /// Removed from the worker queue; the ticket resolves to `Cancelled`.
    Cancelled,
    /// The worker holds the request outside its queue (mid-preprocess,
    /// parked, or preempted): a cancel mark was posted and the engine
    /// thread resolves it to `Cancelled` at its next step boundary.
    /// Best-effort: a request that wins the race into the running batch
    /// completes normally (poll the status for the terminal outcome).
    Cancelling,
    /// The request is running un-preempted or already finished.
    TooLate,
    /// No such request id.
    NotFound,
}

struct Entry {
    worker: usize,
    submitted: Instant,
    state: RequestState,
    priority: Priority,
    deadline_ms: Option<u64>,
}

#[derive(Default)]
struct Inner {
    entries: HashMap<u64, Entry>,
    /// Requests that reached a terminal state (success or failure).
    finished: usize,
}

/// The per-id completion table shared by the cluster, its collector, and
/// all outstanding tickets.
pub struct RequestRegistry {
    inner: Mutex<Inner>,
    cv: Condvar,
}

impl Default for RequestRegistry {
    fn default() -> Self {
        RequestRegistry { inner: Mutex::new(Inner::default()), cv: Condvar::new() }
    }
}

impl RequestRegistry {
    pub fn new() -> Arc<RequestRegistry> {
        Arc::new(RequestRegistry::default())
    }

    /// Create the entry for a freshly routed request and hand back its
    /// ticket. Re-registering a live id is a caller bug.
    pub fn register(
        self: &Arc<Self>,
        id: u64,
        worker: usize,
        priority: Priority,
        deadline_ms: Option<u64>,
    ) -> EditTicket {
        let mut g = self.inner.lock().unwrap();
        let prev = g.entries.insert(
            id,
            Entry {
                worker,
                submitted: Instant::now(),
                state: RequestState::Queued,
                priority,
                deadline_ms,
            },
        );
        if let Some(prev) = prev {
            if !prev.state.is_terminal() {
                panic!("request id {id} registered twice while in flight");
            }
            // a terminal entry with a recycled id was superseded; its
            // finished count already landed, nothing to adjust
        }
        EditTicket { id, worker, registry: Arc::clone(self) }
    }

    /// Queued -> Running (worker admitted the request into its batch).
    pub fn mark_running(&self, id: u64) {
        let mut g = self.inner.lock().unwrap();
        if let Some(e) = g.entries.get_mut(&id) {
            if matches!(e.state, RequestState::Queued) {
                e.state = RequestState::Running;
                self.cv.notify_all();
            }
        }
    }

    /// Resolve a request. First terminal transition wins; returns whether
    /// this call performed it. Successful responses are taken as `Arc` so
    /// the caller can retain a handle without a second tensor copy.
    pub fn fulfill(&self, id: u64, result: Result<Arc<EditResponse>, EditError>) -> bool {
        let mut g = self.inner.lock().unwrap();
        let Some(e) = g.entries.get_mut(&id) else { return false };
        if e.state.is_terminal() {
            return false;
        }
        e.state = match result {
            Ok(resp) => RequestState::Done(resp),
            Err(err) => RequestState::Failed(err),
        };
        g.finished += 1;
        self.cv.notify_all();
        true
    }

    /// Drop a terminal entry (client acknowledged the result). Keeps
    /// serve-mode memory bounded for clients that reap what they poll;
    /// live entries are never evicted. Returns whether an entry was
    /// removed.
    pub fn evict_terminal(&self, id: u64) -> bool {
        let mut g = self.inner.lock().unwrap();
        match g.entries.get(&id) {
            Some(e) if e.state.is_terminal() => {
                g.entries.remove(&id);
                true
            }
            _ => false,
        }
    }

    /// Fail every non-terminal entry (cluster shutdown).
    pub fn fail_all_pending(&self, err: EditError) {
        let mut g = self.inner.lock().unwrap();
        let mut newly = 0;
        for e in g.entries.values_mut() {
            if !e.state.is_terminal() {
                e.state = RequestState::Failed(err.clone());
                newly += 1;
            }
        }
        g.finished += newly;
        if newly > 0 {
            self.cv.notify_all();
        }
    }

    /// The worker a still-queued request was routed to (cancellation
    /// pre-check); `None` once it is running or terminal, or unknown.
    pub fn worker_if_queued(&self, id: u64) -> Option<usize> {
        let g = self.inner.lock().unwrap();
        g.entries
            .get(&id)
            .filter(|e| matches!(e.state, RequestState::Queued))
            .map(|e| e.worker)
    }

    pub fn status(&self, id: u64) -> Option<RequestStatus> {
        let g = self.inner.lock().unwrap();
        g.entries.get(&id).map(|e| RequestStatus {
            id,
            worker: e.worker,
            state: e.state.clone(),
            age_secs: e.submitted.elapsed().as_secs_f64(),
            priority: e.priority,
            deadline_ms: e.deadline_ms,
        })
    }

    /// Requests that reached a terminal state so far.
    pub fn finished(&self) -> usize {
        self.inner.lock().unwrap().finished
    }

    /// Block until at least `n` requests finished (success, failure, or
    /// cancellation), or `timeout` elapsed. Condvar-based — no polling.
    pub fn await_finished(&self, n: usize, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        let mut g = self.inner.lock().unwrap();
        while g.finished < n {
            let now = Instant::now();
            if now >= deadline {
                return false;
            }
            let (guard, _) = self.cv.wait_timeout(g, deadline - now).unwrap();
            g = guard;
        }
        true
    }

    /// Number of tracked entries (live + retained terminal).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn wait_terminal(&self, id: u64, timeout: Duration) -> Result<Arc<EditResponse>, EditError> {
        let deadline = Instant::now() + timeout;
        let mut g = self.inner.lock().unwrap();
        loop {
            match g.entries.get(&id).map(|e| &e.state) {
                Some(RequestState::Done(resp)) => return Ok(Arc::clone(resp)),
                Some(RequestState::Failed(err)) => return Err(err.clone()),
                Some(_) => {}
                None => return Err(EditError::WorkerShutdown),
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(EditError::Timeout);
            }
            let (guard, _) = self.cv.wait_timeout(g, deadline - now).unwrap();
            g = guard;
        }
    }
}

/// Handle to one in-flight edit: returned by `Cluster::submit`, fulfilled
/// by the collector through the shared [`RequestRegistry`].
#[derive(Clone)]
pub struct EditTicket {
    id: u64,
    worker: usize,
    registry: Arc<RequestRegistry>,
}

impl EditTicket {
    pub fn id(&self) -> u64 {
        self.id
    }

    /// The worker the scheduler routed this request to.
    pub fn worker(&self) -> usize {
        self.worker
    }

    /// Current lifecycle snapshot (the entry outlives completion).
    pub fn status(&self) -> Option<RequestStatus> {
        self.registry.status(self.id)
    }

    /// Block until this request resolves, with `Err(Timeout)` after
    /// `timeout`. Waiting again after a terminal state returns the same
    /// outcome (responses are retained in the registry until evicted).
    pub fn wait(&self, timeout: Duration) -> Result<Arc<EditResponse>, EditError> {
        self.registry.wait_terminal(self.id, timeout)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::request::RequestTiming;
    use crate::util::tensor::Tensor;

    fn resp(id: u64) -> EditResponse {
        EditResponse {
            id,
            template_id: "t".into(),
            image: Tensor::zeros(&[2, 2]),
            latent: Tensor::zeros(&[2, 2]),
            timing: RequestTiming::default(),
            mask_ratio: 0.1,
            priority: Priority::Standard,
        }
    }

    #[test]
    fn ticket_resolves_after_fulfill() {
        let reg = RequestRegistry::new();
        let t = reg.register(1, 0, Priority::Standard, None);
        assert_eq!(t.status().unwrap().state.label(), "queued");
        reg.mark_running(1);
        assert_eq!(t.status().unwrap().state.label(), "running");
        assert!(reg.fulfill(1, Ok(Arc::new(resp(1)))));
        let got = t.wait(Duration::from_millis(10)).expect("done");
        assert_eq!(got.id, 1);
        // idempotent: a second fulfillment is ignored, wait re-reads
        assert!(!reg.fulfill(1, Err(EditError::Cancelled)));
        assert!(t.wait(Duration::from_millis(10)).is_ok());
        assert_eq!(reg.finished(), 1);
    }

    #[test]
    fn ticket_wait_times_out() {
        let reg = RequestRegistry::new();
        let t = reg.register(2, 0, Priority::Standard, None);
        let t0 = Instant::now();
        assert!(matches!(t.wait(Duration::from_millis(20)), Err(EditError::Timeout)));
        assert!(t0.elapsed() >= Duration::from_millis(20));
    }

    #[test]
    fn ticket_unblocks_from_another_thread() {
        let reg = RequestRegistry::new();
        let t = reg.register(3, 1, Priority::Standard, None);
        let reg2 = Arc::clone(&reg);
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            reg2.fulfill(3, Ok(Arc::new(resp(3))));
        });
        let got = t.wait(Duration::from_secs(5)).expect("fulfilled");
        assert_eq!(got.id, 3);
        assert_eq!(t.worker(), 1);
        h.join().unwrap();
    }

    #[test]
    fn cancelled_state_labels() {
        let reg = RequestRegistry::new();
        let t = reg.register(4, 0, Priority::Standard, None);
        assert_eq!(reg.worker_if_queued(4), Some(0));
        assert!(reg.fulfill(4, Err(EditError::Cancelled)));
        assert_eq!(reg.worker_if_queued(4), None);
        assert_eq!(t.status().unwrap().state.label(), "cancelled");
        assert!(matches!(t.wait(Duration::from_millis(5)), Err(EditError::Cancelled)));
    }

    #[test]
    fn fail_all_pending_skips_terminal() {
        let reg = RequestRegistry::new();
        let a = reg.register(5, 0, Priority::Standard, None);
        let b = reg.register(6, 0, Priority::Standard, None);
        reg.fulfill(5, Ok(Arc::new(resp(5))));
        reg.fail_all_pending(EditError::WorkerShutdown);
        assert!(a.wait(Duration::from_millis(5)).is_ok());
        assert!(matches!(b.wait(Duration::from_millis(5)), Err(EditError::WorkerShutdown)));
        assert_eq!(reg.finished(), 2);
    }

    #[test]
    fn evict_terminal_frees_entries_but_never_live_ones() {
        let reg = RequestRegistry::new();
        let t = reg.register(10, 0, Priority::Standard, None);
        assert!(!reg.evict_terminal(10), "queued entries must survive");
        reg.fulfill(10, Ok(Arc::new(resp(10))));
        assert!(reg.evict_terminal(10));
        assert!(reg.status(10).is_none());
        assert!(!reg.evict_terminal(10), "already gone");
        // a waiter on an evicted entry resolves instead of hanging
        assert!(matches!(
            t.wait(Duration::from_millis(5)),
            Err(EditError::WorkerShutdown)
        ));
        // eviction does not roll back the finished counter
        assert_eq!(reg.finished(), 1);
    }

    #[test]
    fn status_echoes_qos_fields() {
        let reg = RequestRegistry::new();
        let t = reg.register(11, 2, Priority::Batch, Some(500));
        let st = t.status().unwrap();
        assert_eq!(st.priority, Priority::Batch);
        assert_eq!(st.deadline_ms, Some(500));
        let t = reg.register(12, 0, Priority::Interactive, None);
        let st = t.status().unwrap();
        assert_eq!(st.priority, Priority::Interactive);
        assert_eq!(st.deadline_ms, None);
    }

    #[test]
    fn await_finished_counts_terminals() {
        let reg = RequestRegistry::new();
        let _a = reg.register(7, 0, Priority::Standard, None);
        let _b = reg.register(8, 0, Priority::Standard, None);
        assert!(!reg.await_finished(1, Duration::from_millis(10)));
        reg.fulfill(7, Err(EditError::Cancelled));
        let reg2 = Arc::clone(&reg);
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            reg2.fulfill(8, Ok(Arc::new(resp(8))));
        });
        assert!(reg.await_finished(2, Duration::from_secs(5)));
        h.join().unwrap();
    }
}
