//! Minimal HTTP/1.1 frontend (the paper's FastAPI analogue; DESIGN.md
//! "Offline-crate substitution").
//!
//! # API v1 — handle-based request lifecycle
//!
//! - `POST /v1/edits` — async submit. Body
//!   `{"template": "tpl-0", "mask_ratio": 0.15, "prompt_seed": 7,
//!   "priority": "interactive" | "standard" | "batch",
//!   "deadline_ms": 2000}` (priority defaults to `standard`, deadline is
//!   optional); validates via [`EditRequestBuilder`], passes the QoS
//!   admission gate (over capacity → `429` with a `Retry-After` header
//!   and `retry_after_ms` body field; infeasible deadline → `422`),
//!   routes through the cluster scheduler, and returns `202 {"id",
//!   "status": "queued", "status_url", "worker"}` immediately.
//! - `GET /v1/edits/{id}` — poll one request:
//!   `{"status": "queued" | "running" | "done" | "cancelled" | "failed"}`
//!   with the submitted `priority` (+ `deadline_ms` when set) echoed,
//!   plus, once done, the full per-request `timing` decomposition
//!   (queue / inference / e2e / interruptions / steps_computed) and
//!   decoded-image stats. A deadline that expires while queued resolves
//!   the request to `failed` with `error_kind: "deadline_exceeded"`.
//! - `DELETE /v1/edits/{id}` — cancel while still queued
//!   (`200 "cancelled"`); requests the worker holds outside its queue
//!   (mid-preprocess, parked on a registering template, or preempted)
//!   get a best-effort cancel mark the engine resolves at its next step
//!   boundary (`202 "cancelling"` — poll for the terminal state; a
//!   request that wins the race into the running batch completes
//!   normally); on an already-finished request it evicts the
//!   retained result instead (`200 "evicted"`, freeing serve-mode
//!   memory); `409` while running un-preempted, `404` for unknown ids.
//! - `GET /v1/stats` — uptime, completions, per-worker queue depths
//!   (broken out per class with oldest-wait ages) and cache-tier stats
//!   (host hits / disk promotions / misses / evictions / resident
//!   bytes).
//! - `POST /edit` — synchronous compatibility wrapper: submit + wait on
//!   the request's own ticket (no cross-request rendezvous), returning
//!   timing + image stats.
//! - `GET /stats`, `GET /healthz` (alias `/v1/healthz`) — legacy
//!   counters / liveness; `GET /v1/readyz` — readiness (503 while any
//!   disk breaker is open).
//!
//! # Session endpoints (interactive editing, [`crate::session`])
//!
//! - `POST /v1/sessions` — body `{"template": "tpl-0"}`: open a session
//!   pinned to that template, `201 {"session", "state": "open"}`.
//! - `POST /v1/sessions/{id}/rounds` — submit one round (same body as
//!   `/v1/edits` minus `template`; priority defaults to `interactive`).
//!   Returns `202` with the round index, the delta-mask `warm` verdict,
//!   the owning worker, and the round's `events_url`.
//! - `GET /v1/sessions/{id}` — session status: state / epoch / owner,
//!   every round's record, and the warm-vs-cold mean latency split.
//! - `DELETE /v1/sessions/{id}` — close: refuses further rounds, drains
//!   in-flight ones, releases the template pin.
//! - `GET /v1/sessions/{id}/rounds/{n}/events` — **SSE** progress
//!   stream (`text/event-stream`): one `step` event per denoise-step
//!   boundary (`seq`, `step`, `est_remaining_ms`, latent stats) and a
//!   terminal `done` event. Served on a dedicated connection; the
//!   per-round buffer is dropped when the stream ends (completion or
//!   client disconnect alike).
//!
//! # Template lifecycle endpoints (online registration, §2.2 / §4.2)
//!
//! - `POST /v1/templates` — body `{"template": "tpl-9"}`: enqueue a
//!   background registration (full-model trace on the cluster's
//!   low-priority lane) and return `202 {"state": "registering"}`
//!   immediately; the cluster keeps serving. Idempotent: an
//!   already-ready template returns `200 {"state": "ready"}`.
//! - `GET /v1/templates[/{id}]` — list or inspect templates: state
//!   (registering / ready / failed / retired), cache bytes, in-flight
//!   edits, and per-worker residency (host / disk / absent).
//! - `DELETE /v1/templates/{id}` — retire: new edits are rejected with
//!   `410`, in-flight ones drain, and the template's host-tier bytes are
//!   freed on every worker (observable in `GET /v1/stats`). `200` when
//!   purged at once, `202` while draining.
//!
//! Failures are typed ([`EditError`]) and mapped onto status codes:
//! 404 unknown template, 410 retired template, 400 invalid mask,
//! 409 cancelled, 504 timeout, 503 worker shutdown, 500 internal engine
//! fault. Bodies over 1 MiB are rejected with `413` instead of being
//! silently truncated; header sections over [`MAX_HEADER_BYTES`] /
//! [`MAX_HEADER_LINES`] get `431` (slowloris guard), and every connection
//! carries read + write timeouts. The same [`serve_connection`] loop
//! backs the dist RPC listeners ([`crate::dist`]), so the public API port
//! and the data-plane ports share one set of limits.
//!
//! ```text
//! curl -s localhost:8801/v1/edits -d '{"template":"tpl-0","mask_ratio":0.2}'
//!   -> {"id": 1000000, "status": "queued", "status_url": "/v1/edits/1000000", ...}
//! curl -s localhost:8801/v1/edits/1000000
//!   -> {"id": 1000000, "status": "done", "timing": {"queue": ..., "e2e": ...}, ...}
//! curl -s localhost:8801/v1/templates -d '{"template":"tpl-9"}'
//!   -> {"template": "tpl-9", "state": "registering", "status_url": "/v1/templates/tpl-9"}
//! curl -s localhost:8801/v1/templates/tpl-9
//!   -> {"template": "tpl-9", "state": "ready", "bytes": ..., "workers": [...]}
//! curl -s -X DELETE localhost:8801/v1/templates/tpl-9
//!   -> {"template": "tpl-9", "state": "retired"}
//! curl -s localhost:8801/v1/stats
//!   -> {"completed": 1, "workers": [{"worker": 0, "queued": 0, "cache": {...}}], ...}
//! ```

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::cluster::{CancelOutcome, Cluster, RequestState, RoundError, TemplateStatus};
use crate::engine::request::{EditError, EditRequest, EditRequestBuilder, EditResponse};
use crate::engine::worker::ProgressEvent;
use crate::qos::Priority;
use crate::session::{SessionError, SessionStatus};
use crate::templates::{RegisterAdmission, RetireOutcome};
use crate::util::json::Json;
use crate::util::tensor::Tensor;

/// Largest accepted request body; larger uploads get `413`.
pub const MAX_BODY_BYTES: usize = 1 << 20;

/// Largest accepted header section (request line + all headers); beyond
/// this the connection gets `431` and is closed — together with
/// [`READ_TIMEOUT`] this is the slowloris guard on every listener (public
/// API and dist RPC ports alike).
pub const MAX_HEADER_BYTES: usize = 16 << 10;

/// Most header lines accepted per request (same guard).
pub const MAX_HEADER_LINES: usize = 64;

/// Per-connection socket read timeout.
pub const READ_TIMEOUT: Duration = Duration::from_secs(10);

/// Per-connection socket write timeout (a stalled reader cannot pin a
/// handler thread forever).
pub const WRITE_TIMEOUT: Duration = Duration::from_secs(10);

/// How long the synchronous `POST /edit` wrapper waits on its ticket.
const SYNC_EDIT_TIMEOUT: Duration = Duration::from_secs(120);

/// How long `DELETE /v1/sessions/{id}` waits for in-flight rounds to
/// drain before releasing the template pin.
const SESSION_DRAIN_TIMEOUT: Duration = Duration::from_secs(30);

/// SSE poll cadence: how often an idle event stream re-checks the
/// per-round buffer (the engine publishes at step boundaries).
const SSE_POLL: Duration = Duration::from_millis(2);

/// Upper bound on one SSE stream's lifetime (belt-and-braces: streams
/// normally end at the round's terminal event).
const SSE_MAX_DURATION: Duration = Duration::from_secs(120);

/// Serve a cluster over HTTP until the process is killed (or asked to
/// stop via [`HttpServer::shutdown`]).
pub struct HttpServer {
    cluster: Arc<Cluster>,
    next_id: AtomicU64,
    stopping: std::sync::atomic::AtomicBool,
    bound: std::sync::Mutex<Option<std::net::SocketAddr>>,
}

impl HttpServer {
    pub fn new(cluster: Arc<Cluster>, first_id: u64) -> HttpServer {
        // online serving is long-lived: don't accumulate the batch-replay
        // response log (results live in the registry until evicted)
        cluster.set_retain_responses(false);
        HttpServer {
            cluster,
            next_id: AtomicU64::new(first_id),
            stopping: std::sync::atomic::AtomicBool::new(false),
            bound: std::sync::Mutex::new(None),
        }
    }

    /// Bind and serve (blocking). One thread per connection — fine for a
    /// control-plane frontend; the data plane is the worker engine.
    pub fn serve(self: Arc<Self>, addr: &str) -> Result<()> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        *self.bound.lock().unwrap() = listener.local_addr().ok();
        eprintln!("[http] listening on {addr}");
        for stream in listener.incoming() {
            if self.stopping.load(Ordering::SeqCst) {
                break;
            }
            let Ok(stream) = stream else { continue };
            let this = Arc::clone(&self);
            std::thread::spawn(move || {
                let _ = this.handle(stream);
            });
        }
        eprintln!("[http] listener on {addr} stopped");
        Ok(())
    }

    /// Stop accepting connections: graceful-shutdown entry for the
    /// in-process frontend. In-flight handler threads finish their
    /// current request; the accept loop exits on its next wakeup (a
    /// self-dial unblocks it immediately).
    pub fn shutdown(&self) {
        self.stopping.store(true, Ordering::SeqCst);
        if let Some(addr) = *self.bound.lock().unwrap() {
            let _ = TcpStream::connect(addr);
        }
    }

    /// Serve one connection. Mirrors [`serve_connection`] but intercepts
    /// the SSE endpoint, which takes over the socket for the stream's
    /// lifetime instead of writing one JSON reply.
    fn handle(&self, stream: TcpStream) -> Result<()> {
        stream.set_read_timeout(Some(READ_TIMEOUT))?;
        stream.set_write_timeout(Some(WRITE_TIMEOUT))?;
        let mut reader = BufReader::new(stream);
        loop {
            let (status, reply, keep) = match read_request(&mut reader)? {
                ReadOutcome::Closed => return Ok(()),
                ReadOutcome::BadHeaders => (
                    431,
                    error_obj(&format!(
                        "header section exceeds {MAX_HEADER_BYTES} bytes / {MAX_HEADER_LINES} lines"
                    )),
                    false,
                ),
                ReadOutcome::TooLarge { declared } => (
                    413,
                    error_obj(&format!(
                        "body of {declared} bytes exceeds the {MAX_BODY_BYTES}-byte limit"
                    )),
                    false,
                ),
                ReadOutcome::Request { method, path, body, keep_alive, .. } => {
                    if method == "GET" {
                        if let Some((sid, round)) = parse_events_path(&path) {
                            return self.stream_round_events(reader.get_mut(), sid, round);
                        }
                    }
                    let (status, reply) = self.route(&method, &path, &body);
                    (status, reply, keep_alive)
                }
            };
            let retry_after = reply
                .at("retry_after_ms")
                .as_f64()
                .map(|ms| ((ms / 1e3).ceil() as u64).max(1));
            write_response(reader.get_mut(), status, &reply.to_string(), retry_after, keep)?;
            if !keep {
                return Ok(());
            }
        }
    }

    /// Route a request (separated from IO for unit testing).
    pub fn route(&self, method: &str, path: &str, body: &str) -> (u16, Json) {
        if let Some(rest) = path.strip_prefix("/v1/edits/") {
            return match rest.parse::<u64>() {
                Ok(id) => self.edit_by_id(method, id),
                Err(_) => (400, error_obj(&format!("bad request id {rest:?}"))),
            };
        }
        if let Some(rest) = path.strip_prefix("/v1/templates/") {
            if rest.is_empty() {
                return (400, error_obj("empty template id"));
            }
            return self.template_by_id(method, rest);
        }
        if let Some(rest) = path.strip_prefix("/v1/sessions") {
            if rest.is_empty() || rest.starts_with('/') {
                return self.sessions_route(method, rest, body);
            }
        }
        match (method, path) {
            ("GET", "/healthz") | ("GET", "/v1/healthz") => {
                (200, Json::obj(vec![("ok", Json::Bool(true))]))
            }
            ("GET", "/v1/readyz") => self.readyz(),
            ("GET", "/stats") => (
                200,
                Json::obj(vec![
                    ("completed", Json::num(self.cluster.completed() as f64)),
                    ("uptime_secs", Json::num(self.cluster.elapsed())),
                    ("workers", Json::num(self.cluster.workers() as f64)),
                ]),
            ),
            ("GET", "/v1/stats") => self.stats_v1(),
            ("POST", "/edit") => self.edit_sync(body),
            ("POST", "/v1/edits") => self.edit_async(body),
            ("POST", "/v1/templates") => self.template_register(body),
            ("GET", "/v1/templates") => self.templates_list(),
            _ => (404, error_obj("not found")),
        }
    }

    /// `GET /v1/readyz`: liveness is not readiness — the process can be
    /// up while every disk breaker is open and the cluster is serving
    /// purely from recompute. 200 only when a worker exists and all
    /// breakers are closed; 503 tells the balancer to prefer a healthy
    /// peer without restarting this one.
    fn readyz(&self) -> (u16, Json) {
        let workers = self.cluster.workers();
        let breakers_closed = self.cluster.breakers_closed();
        let ok = workers >= 1 && breakers_closed;
        (
            if ok { 200 } else { 503 },
            Json::obj(vec![
                ("ready", Json::Bool(ok)),
                ("workers", Json::num(workers as f64)),
                ("breakers_closed", Json::Bool(breakers_closed)),
                ("breaker_trips", Json::num(self.cluster.breaker_trips() as f64)),
            ]),
        )
    }

    /// Parse + validate a submit body into an `EditRequest`. The id is
    /// allocated only after local validation, so malformed submissions
    /// never burn ids (template/admission rejects in `submit_guarded`
    /// happen after allocation — the counter is monotonic, gaps are fine).
    fn build_request(
        &self,
        body: &str,
        default_priority: Priority,
    ) -> Result<EditRequest, (u16, Json)> {
        let j = Json::parse(body)
            .map_err(|e| (400, error_obj(&format!("invalid JSON body: {e}"))))?;
        let template = j.at("template").as_str().unwrap_or("tpl-0").to_string();
        let ratio = j.at("mask_ratio").as_f64().unwrap_or(0.15);
        let seed = j.at("prompt_seed").as_f64().unwrap_or(0.0) as u64;
        let priority = match j.at("priority").as_str() {
            None => default_priority,
            Some(s) => Priority::parse(s).ok_or_else(|| {
                (
                    400,
                    error_obj(&format!(
                        "unknown priority {s:?} (interactive | standard | batch)"
                    )),
                )
            })?,
        };
        let deadline_ms = j.at("deadline_ms").as_f64().map(|ms| ms.max(0.0) as u64);
        // template admission (unknown -> 404, retired -> 410, failed
        // registration -> 500; still-registering accepted) happens in
        // `submit_guarded`, together with the QoS admission gate
        let hw = self.cluster.model.latent_hw;
        let mut builder = EditRequestBuilder::new(0)
            .template(template)
            .prompt_seed(seed)
            .priority(priority);
        if let Some(ms) = deadline_ms {
            builder = builder.deadline_ms(ms);
        }
        let mut req = builder
            .synth_mask(hw, ratio)
            .and_then(|b| b.expect_tokens(hw * hw).build())
            .map_err(|e| edit_error_reply(&e))?;
        req.id = self.next_id.fetch_add(1, Ordering::Relaxed);
        Ok(req)
    }

    /// `POST /edit`: submit + wait on this request's *own* ticket.
    fn edit_sync(&self, body: &str) -> (u16, Json) {
        let req = match self.build_request(body, Priority::default()) {
            Ok(r) => r,
            Err(reply) => return reply,
        };
        let ticket = match self.cluster.submit_guarded(req) {
            Ok(t) => t,
            Err(e) => return edit_error_reply(&e),
        };
        let outcome = ticket.wait(SYNC_EDIT_TIMEOUT);
        // same meaning as the polling endpoint's field: wall time since
        // submission (read before the entry is dropped)
        let (age, deadline_ms) = ticket
            .status()
            .map(|s| (s.age_secs, s.deadline_ms))
            .unwrap_or((0.0, None));
        // the result is consumed right here — release the registry entry
        // (no-op on a Timeout, whose entry is still live)
        self.cluster.evict(ticket.id());
        match outcome {
            Ok(resp) => (
                200,
                done_body(ticket.id(), ticket.worker(), age, deadline_ms, &resp),
            ),
            Err(e) => edit_error_reply(&e),
        }
    }

    /// `POST /v1/edits`: async submit, returns the polling handle.
    fn edit_async(&self, body: &str) -> (u16, Json) {
        let req = match self.build_request(body, Priority::default()) {
            Ok(r) => r,
            Err(reply) => return reply,
        };
        let ticket = match self.cluster.submit_guarded(req) {
            Ok(t) => t,
            Err(e) => return edit_error_reply(&e),
        };
        (
            202,
            Json::obj(vec![
                ("id", Json::num(ticket.id() as f64)),
                ("status", Json::str("queued")),
                ("status_url", Json::str(format!("/v1/edits/{}", ticket.id()))),
                ("worker", Json::num(ticket.worker() as f64)),
            ]),
        )
    }

    /// `GET`/`DELETE /v1/edits/{id}`.
    fn edit_by_id(&self, method: &str, id: u64) -> (u16, Json) {
        match method {
            "GET" => match self.cluster.status(id) {
                None => (404, error_obj(&format!("no such request {id}"))),
                Some(st) => {
                    let reply = match &st.state {
                        RequestState::Done(resp) => {
                            done_body(id, st.worker, st.age_secs, st.deadline_ms, resp)
                        }
                        RequestState::Failed(err) => {
                            let mut pairs =
                                status_pairs(id, st.state.label(), st.worker, st.age_secs);
                            push_qos_pairs(&mut pairs, st.priority, st.deadline_ms);
                            if *err != EditError::Cancelled {
                                pairs.push(("error", Json::str(err.to_string())));
                                pairs.push(("error_kind", Json::str(err.kind())));
                            }
                            Json::obj(pairs)
                        }
                        _ => {
                            let mut pairs =
                                status_pairs(id, st.state.label(), st.worker, st.age_secs);
                            push_qos_pairs(&mut pairs, st.priority, st.deadline_ms);
                            Json::obj(pairs)
                        }
                    };
                    (200, reply)
                }
            },
            "DELETE" => match self.cluster.cancel(id) {
                CancelOutcome::Cancelled => (
                    200,
                    Json::obj(vec![
                        ("id", Json::num(id as f64)),
                        ("status", Json::str("cancelled")),
                    ]),
                ),
                // the worker holds it parked/preempted/mid-preprocess: a
                // cancel mark resolves it at the next step boundary
                CancelOutcome::Cancelling => (
                    202,
                    Json::obj(vec![
                        ("id", Json::num(id as f64)),
                        ("status", Json::str("cancelling")),
                        ("status_url", Json::str(format!("/v1/edits/{id}"))),
                    ]),
                ),
                // terminal entries are evicted instead (result already
                // delivered; frees the retained response)
                CancelOutcome::TooLate if self.cluster.evict(id) => (
                    200,
                    Json::obj(vec![
                        ("id", Json::num(id as f64)),
                        ("status", Json::str("evicted")),
                    ]),
                ),
                CancelOutcome::TooLate => {
                    (409, error_obj("too late to cancel: request is running"))
                }
                CancelOutcome::NotFound => {
                    (404, error_obj(&format!("no such request {id}")))
                }
            },
            _ => (405, error_obj("method not allowed")),
        }
    }

    /// `POST /v1/templates`: enqueue a background registration.
    fn template_register(&self, body: &str) -> (u16, Json) {
        let j = match Json::parse(body) {
            Ok(j) => j,
            Err(e) => return (400, error_obj(&format!("invalid JSON body: {e}"))),
        };
        let Some(template) = j.at("template").as_str() else {
            return (400, error_obj("missing \"template\" field"));
        };
        if template.is_empty() {
            return (400, error_obj("empty template id"));
        }
        match self.cluster.register_template_async(template) {
            RegisterAdmission::AlreadyReady => {
                (200, template_reply(template, "ready", None))
            }
            RegisterAdmission::Started { .. } | RegisterAdmission::InProgress => {
                (202, template_reply(template, "registering", None))
            }
        }
    }

    /// `GET /v1/templates`: every template's state + residency.
    fn templates_list(&self) -> (u16, Json) {
        let templates = self
            .cluster
            .templates_status()
            .into_iter()
            .map(|s| template_status_body(&s))
            .collect();
        (
            200,
            Json::obj(vec![
                ("model", Json::str(self.cluster.model.name.clone())),
                ("templates", Json::arr(templates)),
            ]),
        )
    }

    /// `GET`/`DELETE /v1/templates/{id}`.
    fn template_by_id(&self, method: &str, template_id: &str) -> (u16, Json) {
        match method {
            "GET" => match self.cluster.template_status(template_id) {
                Some(status) => (200, template_status_body(&status)),
                None => (404, error_obj(&format!("no such template {template_id:?}"))),
            },
            "DELETE" => match self.cluster.retire_template(template_id) {
                RetireOutcome::Retired => {
                    (200, template_reply(template_id, "retired", None))
                }
                RetireOutcome::Draining { inflight } => {
                    (202, template_reply(template_id, "retiring", Some(inflight)))
                }
                RetireOutcome::NotFound => {
                    (404, error_obj(&format!("no such template {template_id:?}")))
                }
            },
            _ => (405, error_obj("method not allowed")),
        }
    }

    /// Dispatch `/v1/sessions[...]` (`rest` is `""` or starts with `/`).
    fn sessions_route(&self, method: &str, rest: &str, body: &str) -> (u16, Json) {
        if rest.is_empty() {
            return match method {
                "POST" => self.session_open(body),
                _ => (405, error_obj("method not allowed")),
            };
        }
        let rest = &rest[1..]; // strip the leading '/'
        let (sid_str, tail) = match rest.find('/') {
            Some(i) => (&rest[..i], &rest[i..]),
            None => (rest, ""),
        };
        let Ok(sid) = sid_str.parse::<u64>() else {
            return (400, error_obj(&format!("bad session id {sid_str:?}")));
        };
        match (method, tail) {
            ("GET", "") => match self.cluster.session_status(sid) {
                Some(st) => (200, session_status_body(&st)),
                None => (404, error_obj(&format!("no such session {sid}"))),
            },
            ("DELETE", "") => match self.cluster.close_session(sid, SESSION_DRAIN_TIMEOUT) {
                Ok(st) => (200, session_status_body(&st)),
                Err(e) => session_error_reply(&e),
            },
            ("POST", "/rounds") => self.session_round(sid, body),
            // the SSE endpoint is intercepted in `handle` (it takes over
            // the socket); reaching it through plain routing is an error
            ("GET", t) if t.starts_with("/rounds/") && t.ends_with("/events") => (
                400,
                error_obj("event streams are served over a dedicated SSE connection"),
            ),
            _ => (404, error_obj("not found")),
        }
    }

    /// `POST /v1/sessions`: open a session pinned to one template.
    fn session_open(&self, body: &str) -> (u16, Json) {
        let j = match Json::parse(body) {
            Ok(j) => j,
            Err(e) => return (400, error_obj(&format!("invalid JSON body: {e}"))),
        };
        let template = j.at("template").as_str().unwrap_or("tpl-0").to_string();
        match self.cluster.open_session(&template) {
            Ok(sid) => (
                201,
                Json::obj(vec![
                    ("session", Json::num(sid as f64)),
                    ("template", Json::str(template)),
                    ("state", Json::str("open")),
                    ("status_url", Json::str(format!("/v1/sessions/{sid}"))),
                ]),
            ),
            Err(e) => edit_error_reply(&e),
        }
    }

    /// `POST /v1/sessions/{id}/rounds`: submit one round. Same body as
    /// `/v1/edits` minus `template` (the session's pin wins); priority
    /// defaults to `interactive`.
    fn session_round(&self, sid: u64, body: &str) -> (u16, Json) {
        let req = match self.build_request(body, Priority::Interactive) {
            Ok(r) => r,
            Err(reply) => return reply,
        };
        match self.cluster.submit_session_round(sid, req) {
            Ok((ticket, plan)) => (
                202,
                Json::obj(vec![
                    ("id", Json::num(ticket.id() as f64)),
                    ("session", Json::num(sid as f64)),
                    ("round", Json::num(plan.round as f64)),
                    ("warm", Json::Bool(plan.warm)),
                    ("worker", Json::num(ticket.worker() as f64)),
                    ("status_url", Json::str(format!("/v1/edits/{}", ticket.id()))),
                    (
                        "events_url",
                        Json::str(format!("/v1/sessions/{sid}/rounds/{}/events", plan.round)),
                    ),
                ]),
            ),
            Err(RoundError::Edit(e)) => edit_error_reply(&e),
            Err(RoundError::Session(e)) => session_error_reply(&e),
        }
    }

    /// `GET /v1/sessions/{id}/rounds/{n}/events`: stream step-boundary
    /// progress as SSE until the round's terminal event, the client
    /// disconnects, or [`SSE_MAX_DURATION`] elapses. The per-round buffer
    /// is dropped on every exit path, so ended streams never leak.
    fn stream_round_events(&self, stream: &mut TcpStream, sid: u64, round: u64) -> Result<()> {
        let rec = self
            .cluster
            .session_status(sid)
            .and_then(|st| st.rounds.iter().find(|r| r.round == round).cloned());
        let Some(rec) = rec else {
            let body = error_obj(&format!("no such round {round} in session {sid}"));
            return write_response(stream, 404, &body.to_string(), None, false);
        };
        let Some(shared) = rec.worker.and_then(|w| self.cluster.worker_shared(w)) else {
            let body = error_obj("round has no assigned worker yet");
            return write_response(stream, 409, &body.to_string(), None, false);
        };
        let id = rec.request_id;
        write!(
            stream,
            "HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\nCache-Control: no-cache\r\nConnection: close\r\n\r\n"
        )?;
        stream.flush()?;
        let deadline = Instant::now() + SSE_MAX_DURATION;
        let mut cursor = 0u64;
        'stream: loop {
            match shared.progress_since(id, cursor) {
                Some((events, done)) => {
                    for ev in &events {
                        cursor = ev.seq + 1;
                        let kind = if ev.done { "done" } else { "step" };
                        let wrote = write!(stream, "event: {kind}\ndata: {}\n\n", progress_body(ev))
                            .and_then(|()| stream.flush());
                        if wrote.is_err() {
                            break 'stream; // client disconnected
                        }
                    }
                    if done {
                        break 'stream;
                    }
                }
                None => {
                    // no buffer yet (round still queued) — or none ever:
                    // failed/cancelled rounds never publish, so a terminal
                    // request without a buffer ends the stream with a
                    // synthetic done event
                    let terminal = self
                        .cluster
                        .status(id)
                        .map(|s| s.state.is_terminal())
                        .unwrap_or(true);
                    if terminal {
                        let body = Json::obj(vec![
                            ("id", Json::num(id as f64)),
                            ("done", Json::Bool(true)),
                        ]);
                        let _ = write!(stream, "event: done\ndata: {body}\n\n")
                            .and_then(|()| stream.flush());
                        break 'stream;
                    }
                }
            }
            if Instant::now() >= deadline {
                break 'stream;
            }
            std::thread::sleep(SSE_POLL);
        }
        shared.drop_progress(id);
        Ok(())
    }

    /// `GET /v1/stats`: per-worker queue depths (per class) + cache-tier
    /// stats + completion counters.
    fn stats_v1(&self) -> (u16, Json) {
        let caches = self.cluster.cache_stats();
        let session_load = self.cluster.sessions().worker_load(self.cluster.workers());
        let depths = self
            .cluster
            .queue_depths()
            .into_iter()
            .zip(caches)
            .map(|(d, c)| {
                let (open, active_rounds) =
                    session_load.get(d.worker).copied().unwrap_or((0, 0));
                let classes = Priority::ALL
                    .iter()
                    .map(|p| {
                        let cd = d.classes[p.rank()];
                        (
                            p.label(),
                            Json::obj(vec![
                                ("queued", Json::num(cd.queued as f64)),
                                ("oldest_wait_secs", Json::num(cd.oldest_wait_secs)),
                            ]),
                        )
                    })
                    .collect();
                Json::obj(vec![
                    ("worker", Json::num(d.worker as f64)),
                    ("queued", Json::num(d.queued as f64)),
                    ("outstanding", Json::num(d.outstanding as f64)),
                    ("classes", Json::obj(classes)),
                    (
                        "sessions",
                        Json::obj(vec![
                            ("open", Json::num(open as f64)),
                            ("active_rounds", Json::num(active_rounds as f64)),
                        ]),
                    ),
                    (
                        "cache",
                        Json::obj(vec![
                            ("host_hits", Json::num(c.stats.host_hits as f64)),
                            ("disk_promotions", Json::num(c.stats.disk_promotions as f64)),
                            ("misses", Json::num(c.stats.misses as f64)),
                            ("evictions", Json::num(c.stats.evictions as f64)),
                            ("host_bytes", Json::num(c.host_bytes as f64)),
                            ("host_templates", Json::num(c.host_templates as f64)),
                        ]),
                    ),
                ])
            })
            .collect();
        (
            200,
            Json::obj(vec![
                ("completed", Json::num(self.cluster.completed() as f64)),
                ("uptime_secs", Json::num(self.cluster.elapsed())),
                ("templates", Json::num(self.cluster.template_count() as f64)),
                (
                    "sessions_open",
                    Json::num(self.cluster.sessions().open_count() as f64),
                ),
                ("workers", Json::arr(depths)),
            ]),
        )
    }
}

/// Parse `/v1/sessions/{sid}/rounds/{n}/events` into `(sid, n)`.
fn parse_events_path(path: &str) -> Option<(u64, u64)> {
    let rest = path.strip_prefix("/v1/sessions/")?;
    let (sid, rest) = rest.split_once('/')?;
    let rest = rest.strip_prefix("rounds/")?;
    let (round, tail) = rest.split_once('/')?;
    if tail != "events" {
        return None;
    }
    Some((sid.parse().ok()?, round.parse().ok()?))
}

/// One SSE `data:` payload: the progress event as JSON.
fn progress_body(ev: &ProgressEvent) -> Json {
    Json::obj(vec![
        ("seq", Json::num(ev.seq as f64)),
        ("step", Json::num(ev.step as f64)),
        ("steps_total", Json::num(ev.steps_total as f64)),
        ("est_remaining_ms", Json::num(ev.est_remaining_ms as f64)),
        ("latent_mean", Json::num(ev.latent_mean as f64)),
        ("latent_rms", Json::num(ev.latent_rms as f64)),
        ("done", Json::Bool(ev.done)),
    ])
}

/// Map a typed [`SessionError`] to its HTTP reply (404 unknown session,
/// 410 closed/expired).
pub fn session_error_reply(e: &SessionError) -> (u16, Json) {
    (
        e.http_status(),
        Json::obj(vec![
            ("error", Json::str(e.to_string())),
            ("error_kind", Json::str(e.kind())),
        ]),
    )
}

/// Full session status body: lifecycle + per-round records + the
/// warm-vs-cold latency split.
pub fn session_status_body(st: &SessionStatus) -> Json {
    let rounds = st
        .rounds
        .iter()
        .map(|r| {
            let mut pairs = vec![
                ("round", Json::num(r.round as f64)),
                ("id", Json::num(r.request_id as f64)),
                ("warm", Json::Bool(r.warm)),
                (
                    "status",
                    Json::str(match r.ok {
                        Some(true) => "done",
                        Some(false) => "failed",
                        None => "inflight",
                    }),
                ),
            ];
            if let Some(w) = r.worker {
                pairs.push(("worker", Json::num(w as f64)));
            }
            if let Some(l) = r.latency {
                pairs.push(("latency_secs", Json::num(l)));
            }
            Json::obj(pairs)
        })
        .collect();
    let mut pairs = vec![
        ("session", Json::num(st.id as f64)),
        ("template", Json::str(st.template.clone())),
        ("state", Json::str(st.state.label())),
        ("epoch", Json::num(st.epoch as f64)),
        ("inflight", Json::num(st.inflight as f64)),
        ("rounds", Json::arr(rounds)),
    ];
    if let Some(w) = st.owner {
        pairs.push(("owner", Json::num(w as f64)));
    }
    if let Some(c) = st.cold_mean {
        pairs.push(("cold_mean_secs", Json::num(c)));
    }
    if let Some(w) = st.warm_mean {
        pairs.push(("warm_mean_secs", Json::num(w)));
    }
    Json::obj(pairs)
}

/// Minimal template reply: id + state (+ draining count), with the
/// polling URL.
fn template_reply(template_id: &str, state: &str, inflight: Option<usize>) -> Json {
    let mut pairs = vec![
        ("template", Json::str(template_id)),
        ("state", Json::str(state)),
        (
            "status_url",
            Json::str(format!("/v1/templates/{template_id}")),
        ),
    ];
    if let Some(n) = inflight {
        pairs.push(("inflight", Json::num(n as f64)));
    }
    Json::obj(pairs)
}

/// Full template status body: registry entry + per-worker residency.
fn template_status_body(status: &TemplateStatus) -> Json {
    let info = &status.info;
    let workers = status
        .residency
        .iter()
        .enumerate()
        .map(|(w, r)| {
            Json::obj(vec![
                ("worker", Json::num(w as f64)),
                ("residency", Json::str(r.label())),
            ])
        })
        .collect();
    Json::obj(vec![
        ("template", Json::str(info.template_id.clone())),
        ("state", Json::str(info.state.label())),
        ("bytes", Json::num(info.bytes as f64)),
        ("inflight", Json::num(info.inflight as f64)),
        ("epoch", Json::num(info.epoch as f64)),
        ("age_secs", Json::num(info.age_secs)),
        ("workers", Json::arr(workers)),
    ])
}

/// Common status-body prefix: id / status / worker / age.
pub fn status_pairs<'a>(
    id: u64,
    label: &'static str,
    worker: usize,
    age_secs: f64,
) -> Vec<(&'a str, Json)> {
    vec![
        ("id", Json::num(id as f64)),
        ("status", Json::str(label)),
        ("worker", Json::num(worker as f64)),
        ("age_secs", Json::num(age_secs)),
    ]
}

/// Echo the submitted QoS fields on status bodies.
pub fn push_qos_pairs(pairs: &mut Vec<(&str, Json)>, priority: Priority, deadline_ms: Option<u64>) {
    pairs.push(("priority", Json::str(priority.label())));
    if let Some(ms) = deadline_ms {
        pairs.push(("deadline_ms", Json::num(ms as f64)));
    }
}

/// Completed-request body: status + timing decomposition + image stats.
pub fn done_body(
    id: u64,
    worker: usize,
    age_secs: f64,
    deadline_ms: Option<u64>,
    resp: &EditResponse,
) -> Json {
    let t = &resp.timing;
    let mut pairs = status_pairs(id, "done", worker, age_secs);
    push_qos_pairs(&mut pairs, resp.priority, deadline_ms);
    pairs.push(("template", Json::str(resp.template_id.clone())));
    pairs.push(("mask_ratio", Json::num(resp.mask_ratio)));
    pairs.push((
        "timing",
        Json::obj(vec![
            ("queue", Json::num(t.queue)),
            ("inference", Json::num(t.inference)),
            ("e2e", Json::num(t.e2e)),
            ("interruptions", Json::num(t.interruptions as f64)),
            ("steps_computed", Json::num(t.steps_computed as f64)),
        ]),
    ));
    pairs.push(("image", image_stats(&resp.image)));
    Json::obj(pairs)
}

/// Shape + value summary of the decoded image (the response payload of a
/// simulation frontend: stats instead of pixels).
fn image_stats(image: &Tensor) -> Json {
    let data = image.data();
    let n = data.len().max(1) as f64;
    let mean = data.iter().map(|&v| v as f64).sum::<f64>() / n;
    let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
    for &v in data {
        lo = lo.min(v as f64);
        hi = hi.max(v as f64);
    }
    let shape = image.shape();
    Json::obj(vec![
        ("rows", Json::num(shape.first().copied().unwrap_or(0) as f64)),
        ("cols", Json::num(shape.get(1).copied().unwrap_or(0) as f64)),
        ("mean", Json::num(mean)),
        ("min", Json::num(if data.is_empty() { 0.0 } else { lo })),
        ("max", Json::num(if data.is_empty() { 0.0 } else { hi })),
    ])
}

/// `{"error": msg}` body (shared by all listeners).
pub fn error_obj(msg: &str) -> Json {
    Json::obj(vec![("error", Json::str(msg))])
}

/// Map a typed [`EditError`] to its HTTP reply. Overload sheds carry the
/// admission estimate so clients (and the `Retry-After` header) know when
/// to come back.
pub fn edit_error_reply(e: &EditError) -> (u16, Json) {
    let mut pairs = vec![
        ("error", Json::str(e.to_string())),
        ("error_kind", Json::str(e.kind())),
    ];
    if let EditError::Overloaded { retry_after_ms } = e {
        pairs.push(("retry_after_ms", Json::num(*retry_after_ms as f64)));
    }
    (e.http_status(), Json::obj(pairs))
}

/// One parsed inbound request (or why parsing refused it).
pub enum ReadOutcome {
    Request {
        method: String,
        path: String,
        body: String,
        /// The client asked to reuse the connection (`Connection:
        /// keep-alive`). Closing stays the default so EOF-delimited
        /// clients (curl, the integration tests) keep working; the dist
        /// RPC client opts in for its long-lived data-plane links.
        keep_alive: bool,
        /// `Idempotency-Key` header, verbatim (case preserved). Routers
        /// dedupe `POST /v1/edits` and session-round submits on it so a
        /// client retry after a dropped ack (or a router failover)
        /// returns the original ticket instead of a duplicate.
        idempotency_key: Option<String>,
    },
    /// Declared Content-Length exceeded [`MAX_BODY_BYTES`] (or did not
    /// parse, which gets the same refusal); the body was not read.
    TooLarge { declared: usize },
    /// The header section blew [`MAX_HEADER_BYTES`]/[`MAX_HEADER_LINES`],
    /// or the peer vanished mid-headers (slowloris guard).
    BadHeaders,
    /// Clean EOF before a request line (keep-alive peer hung up).
    Closed,
}

/// Read one HTTP/1.1 request off a (possibly reused) connection, with
/// bounded header and body sizes. Shared by the public API frontend and
/// the dist RPC listeners so every port gets the same guards.
pub fn read_request(reader: &mut BufReader<TcpStream>) -> Result<ReadOutcome> {
    let mut limited = reader.by_ref().take((MAX_HEADER_BYTES + 1) as u64);
    let mut line = String::new();
    if limited.read_line(&mut line)? == 0 {
        return Ok(ReadOutcome::Closed);
    }
    if !line.ends_with('\n') {
        return Ok(ReadOutcome::BadHeaders);
    }
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("/").to_string();
    let mut content_length = 0usize;
    let mut keep_alive = false;
    let mut idempotency_key = None;
    let mut lines = 0usize;
    loop {
        let mut h = String::new();
        let n = limited.read_line(&mut h)?;
        // EOF mid-headers, or the header-byte cap truncated the line
        if n == 0 || !h.ends_with('\n') {
            return Ok(ReadOutcome::BadHeaders);
        }
        lines += 1;
        if lines > MAX_HEADER_LINES {
            return Ok(ReadOutcome::BadHeaders);
        }
        let h = h.trim();
        if h.is_empty() {
            break;
        }
        let lower = h.to_ascii_lowercase();
        if let Some(v) = lower.strip_prefix("content-length:") {
            // an unparseable length (e.g. a value overflowing usize) must
            // not fall back to "no body" and sneak past the size guard
            content_length = v.trim().parse().unwrap_or(usize::MAX);
        } else if let Some(v) = lower.strip_prefix("connection:") {
            keep_alive = v.trim() == "keep-alive";
        } else if lower.starts_with("idempotency-key:") {
            // slice the original-case line: keys are opaque client tokens
            idempotency_key = Some(h["idempotency-key:".len()..].trim().to_string());
        }
    }
    if content_length > MAX_BODY_BYTES {
        return Ok(ReadOutcome::TooLarge { declared: content_length });
    }
    drop(limited); // the body has its own (already-enforced) bound
    let mut body = vec![0u8; content_length];
    if content_length > 0 {
        reader.read_exact(&mut body)?;
    }
    Ok(ReadOutcome::Request {
        method,
        path,
        body: String::from_utf8_lossy(&body).into_owned(),
        keep_alive,
        idempotency_key,
    })
}

/// Write one HTTP/1.1 response. Shared by every listener in the process.
pub fn write_response(
    stream: &mut TcpStream,
    status: u16,
    body: &str,
    retry_after_secs: Option<u64>,
    keep_alive: bool,
) -> Result<()> {
    let reason = match status {
        200 => "OK",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        410 => "Gone",
        413 => "Payload Too Large",
        422 => "Unprocessable Entity",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Internal Server Error",
    };
    let retry = retry_after_secs
        .map(|s| format!("Retry-After: {s}\r\n"))
        .unwrap_or_default();
    let conn = if keep_alive { "keep-alive" } else { "close" };
    write!(
        stream,
        "HTTP/1.1 {status} {reason}\r\nContent-Type: application/json\r\nContent-Length: {}\r\n{retry}Connection: {conn}\r\n\r\n{body}",
        body.len()
    )?;
    stream.flush()?;
    Ok(())
}

/// Serve one accepted connection until it closes: read requests under the
/// slowloris limits, route them, reply, and honor keep-alive. Both the
/// public API port and the dist RPC ports run their connections through
/// here, so the hardening applies uniformly.
pub fn serve_connection<F>(stream: TcpStream, mut route: F) -> Result<()>
where
    F: FnMut(&str, &str, &str) -> (u16, Json),
{
    serve_connection_ext(stream, move |m, p, b, _| route(m, p, b))
}

/// [`serve_connection`] plus header context: the route closure also
/// receives the request's `Idempotency-Key` (when sent). The dist router
/// uses this to make `POST /v1/edits` / round submits retry-safe.
pub fn serve_connection_ext<F>(stream: TcpStream, mut route: F) -> Result<()>
where
    F: FnMut(&str, &str, &str, Option<&str>) -> (u16, Json),
{
    stream.set_read_timeout(Some(READ_TIMEOUT))?;
    stream.set_write_timeout(Some(WRITE_TIMEOUT))?;
    let mut reader = BufReader::new(stream);
    loop {
        let (status, reply, keep) = match read_request(&mut reader)? {
            ReadOutcome::Closed => return Ok(()),
            ReadOutcome::BadHeaders => (
                431,
                error_obj(&format!(
                    "header section exceeds {MAX_HEADER_BYTES} bytes / {MAX_HEADER_LINES} lines"
                )),
                false,
            ),
            ReadOutcome::TooLarge { declared } => (
                413,
                error_obj(&format!(
                    "body of {declared} bytes exceeds the {MAX_BODY_BYTES}-byte limit"
                )),
                false,
            ),
            ReadOutcome::Request { method, path, body, keep_alive, idempotency_key } => {
                let (status, reply) = route(&method, &path, &body, idempotency_key.as_deref());
                (status, reply, keep_alive)
            }
        };
        // 429 bodies carry the admission estimate; surface it as the
        // standard Retry-After header too (whole seconds, min 1)
        let retry_after = reply
            .at("retry_after_ms")
            .as_f64()
            .map(|ms| ((ms / 1e3).ceil() as u64).max(1));
        write_response(reader.get_mut(), status, &reply.to_string(), retry_after, keep)?;
        if !keep {
            return Ok(());
        }
    }
}
