//! Minimal HTTP/1.1 frontend (the paper's FastAPI analogue; DESIGN.md
//! "Offline-crate substitution").
//!
//! Endpoints:
//! - `POST /edit`  body `{"template": "tpl-0", "mask_ratio": 0.15,
//!   "prompt_seed": 7}` — routes through the cluster scheduler, blocks
//!   until the edit completes, returns timing + image stats as JSON.
//! - `GET /stats` — completed count + uptime.
//! - `GET /healthz` — liveness.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::cluster::Cluster;
use crate::engine::request::EditRequest;
use crate::model::MaskSpec;
use crate::util::json::Json;
use crate::util::rng::Pcg;

/// Serve a cluster over HTTP until the process is killed.
pub struct HttpServer {
    cluster: Arc<Cluster>,
    next_id: AtomicU64,
}

impl HttpServer {
    pub fn new(cluster: Arc<Cluster>, first_id: u64) -> HttpServer {
        HttpServer { cluster, next_id: AtomicU64::new(first_id) }
    }

    /// Bind and serve (blocking). One thread per connection — fine for a
    /// control-plane frontend; the data plane is the worker engine.
    pub fn serve(self: Arc<Self>, addr: &str) -> Result<()> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        eprintln!("[http] listening on {addr}");
        for stream in listener.incoming() {
            let Ok(stream) = stream else { continue };
            let this = Arc::clone(&self);
            std::thread::spawn(move || {
                let _ = this.handle(stream);
            });
        }
        Ok(())
    }

    fn handle(&self, mut stream: TcpStream) -> Result<()> {
        stream.set_read_timeout(Some(Duration::from_secs(10)))?;
        let (method, path, body) = read_request(&mut stream)?;
        let (status, reply) = self.route(&method, &path, &body);
        write_response(&mut stream, status, &reply.to_string())
    }

    /// Route a request (separated from IO for unit testing).
    pub fn route(&self, method: &str, path: &str, body: &str) -> (u16, Json) {
        match (method, path) {
            ("GET", "/healthz") => (200, Json::obj(vec![("ok", Json::Bool(true))])),
            ("GET", "/stats") => (
                200,
                Json::obj(vec![
                    ("completed", Json::num(self.cluster.completed() as f64)),
                    ("uptime_secs", Json::num(self.cluster.elapsed())),
                    ("workers", Json::num(self.cluster.workers() as f64)),
                ]),
            ),
            ("POST", "/edit") => match self.edit(body) {
                Ok(j) => (200, j),
                Err(e) => (400, Json::obj(vec![("error", Json::str(e.to_string()))])),
            },
            _ => (404, Json::obj(vec![("error", Json::str("not found"))])),
        }
    }

    fn edit(&self, body: &str) -> Result<Json> {
        let j = Json::parse(body).context("invalid JSON body")?;
        let template = j.at("template").as_str().unwrap_or("tpl-0").to_string();
        let ratio = j.at("mask_ratio").as_f64().unwrap_or(0.15).clamp(0.001, 1.0);
        let seed = j.at("prompt_seed").as_f64().unwrap_or(0.0) as u64;
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);

        let hw = self.cluster.model.latent_hw;
        let mut rng = Pcg::with_stream(seed, 0x6d61_736b);
        let mask = MaskSpec::synth(hw, ratio, &mut rng);
        let req = EditRequest::new(id, template, mask, seed);
        let before = self.cluster.completed();
        let worker = self.cluster.submit(req);
        // block until our response count grows past the id (simple
        // rendezvous: the frontend is synchronous per connection)
        let ok = self
            .cluster
            .await_completed(before + 1, Duration::from_secs(120));
        anyhow::ensure!(ok, "edit timed out");
        Ok(Json::obj(vec![
            ("id", Json::num(id as f64)),
            ("worker", Json::num(worker as f64)),
            ("completed", Json::num(self.cluster.completed() as f64)),
        ]))
    }
}

fn read_request(stream: &mut TcpStream) -> Result<(String, String, String)> {
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("/").to_string();
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim();
        if h.is_empty() {
            break;
        }
        if let Some(v) = h.to_ascii_lowercase().strip_prefix("content-length:") {
            content_length = v.trim().parse().unwrap_or(0);
        }
    }
    let mut body = vec![0u8; content_length.min(1 << 20)];
    if content_length > 0 {
        reader.read_exact(&mut body)?;
    }
    Ok((method, path, String::from_utf8_lossy(&body).into_owned()))
}

fn write_response(stream: &mut TcpStream, status: u16, body: &str) -> Result<()> {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        _ => "Internal Server Error",
    };
    write!(
        stream,
        "HTTP/1.1 {status} {reason}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )?;
    stream.flush()?;
    Ok(())
}
