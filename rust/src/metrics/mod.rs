//! Serving metrics: per-request latency decomposition, throughput, and
//! report tables (the quantities of Fig. 4/12/14/16), broken out per
//! QoS class, with failures split by [`EditError`] kind so overload
//! behavior (sheds vs deadline expiries vs cancels) is observable.

use std::collections::BTreeMap;

use crate::engine::request::{EditError, EditResponse};
use crate::qos::{Priority, CLASS_COUNT};
use crate::util::json::Json;
use crate::util::stats::Summary;

/// Per-class slice of a report.
#[derive(Debug, Clone, Default)]
pub struct ClassReport {
    pub class: &'static str,
    pub completed: usize,
    pub e2e: Summary,
}

/// Aggregated serving metrics over a run.
#[derive(Debug, Clone, Default)]
pub struct Report {
    pub queue: Summary,
    pub inference: Summary,
    pub e2e: Summary,
    pub completed: usize,
    /// Requests per second actually completed (makespan-based).
    pub throughput: f64,
    pub mean_interruptions: f64,
    pub mean_steps_computed: f64,
    pub makespan: f64,
    /// Requests that ended without a response (cancelled / shed /
    /// expired / failed / shutdown).
    pub failed: usize,
    /// `failed`, broken out by [`EditError::kind`] (sorted by kind).
    pub failed_by_kind: Vec<(String, usize)>,
    /// Per-class completion counts + end-to-end latency summaries,
    /// indexed by [`Priority::rank`].
    pub by_class: Vec<ClassReport>,
}

/// Collects responses and derives the report.
#[derive(Debug, Default)]
pub struct Recorder {
    queue: Vec<f64>,
    inference: Vec<f64>,
    e2e: Vec<f64>,
    interruptions: Vec<f64>,
    steps: Vec<f64>,
    class_e2e: [Vec<f64>; CLASS_COUNT],
    failures: Vec<&'static str>,
}

impl Recorder {
    pub fn new() -> Recorder {
        Recorder::default()
    }

    pub fn record(&mut self, resp: &EditResponse) {
        self.queue.push(resp.timing.queue);
        self.inference.push(resp.timing.inference);
        self.e2e.push(resp.timing.e2e);
        self.interruptions.push(resp.timing.interruptions as f64);
        self.steps.push(resp.timing.steps_computed as f64);
        self.class_e2e[resp.priority.rank()].push(resp.timing.e2e);
    }

    /// Account a request that terminated without a response.
    pub fn record_failure(&mut self, err: &EditError) {
        self.failures.push(err.kind());
    }

    pub fn len(&self) -> usize {
        self.e2e.len()
    }

    pub fn is_empty(&self) -> bool {
        self.e2e.is_empty()
    }

    /// Completions recorded for one class so far.
    pub fn class_completed(&self, priority: Priority) -> usize {
        self.class_e2e[priority.rank()].len()
    }

    /// Build the report; `makespan` = wall-clock of the serving window.
    pub fn report(&self, makespan: f64) -> Report {
        let mut kinds: BTreeMap<&'static str, usize> = BTreeMap::new();
        for k in &self.failures {
            *kinds.entry(*k).or_insert(0) += 1;
        }
        Report {
            queue: Summary::of(&self.queue),
            inference: Summary::of(&self.inference),
            e2e: Summary::of(&self.e2e),
            completed: self.e2e.len(),
            throughput: if makespan > 0.0 { self.e2e.len() as f64 / makespan } else { 0.0 },
            mean_interruptions: mean_or0(&self.interruptions),
            mean_steps_computed: mean_or0(&self.steps),
            makespan,
            failed: self.failures.len(),
            failed_by_kind: kinds.into_iter().map(|(k, n)| (k.to_string(), n)).collect(),
            by_class: Priority::ALL
                .iter()
                .map(|p| ClassReport {
                    class: p.label(),
                    completed: self.class_e2e[p.rank()].len(),
                    e2e: Summary::of(&self.class_e2e[p.rank()]),
                })
                .collect(),
        }
    }
}

fn mean_or0(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

impl Report {
    /// One-line human summary.
    pub fn line(&self) -> String {
        format!(
            "n={} tput={:.2}req/s e2e(mean/p50/p95)={:.3}/{:.3}/{:.3}s queue(mean)={:.3}s inf(mean)={:.3}s intr={:.1}",
            self.completed,
            self.throughput,
            self.e2e.mean,
            self.e2e.p50,
            self.e2e.p95,
            self.queue.mean,
            self.inference.mean,
            self.mean_interruptions,
        ) + &if self.failed > 0 {
            let kinds: Vec<String> = self
                .failed_by_kind
                .iter()
                .map(|(k, n)| format!("{k}={n}"))
                .collect();
            format!(" failed={} ({})", self.failed, kinds.join(" "))
        } else {
            String::new()
        }
    }

    pub fn to_json(&self) -> Json {
        let s = |x: &Summary| {
            Json::obj(vec![
                ("mean", Json::num(x.mean)),
                ("p50", Json::num(x.p50)),
                ("p95", Json::num(x.p95)),
                ("p99", Json::num(x.p99)),
            ])
        };
        let classes = self
            .by_class
            .iter()
            .map(|c| {
                (
                    c.class,
                    Json::obj(vec![
                        ("completed", Json::num(c.completed as f64)),
                        ("e2e", s(&c.e2e)),
                    ]),
                )
            })
            .collect();
        let kinds = self
            .failed_by_kind
            .iter()
            .map(|(k, n)| (k.as_str(), Json::num(*n as f64)))
            .collect();
        Json::obj(vec![
            ("completed", Json::num(self.completed as f64)),
            ("throughput", Json::num(self.throughput)),
            ("queue", s(&self.queue)),
            ("inference", s(&self.inference)),
            ("e2e", s(&self.e2e)),
            ("classes", Json::obj(classes)),
            ("mean_interruptions", Json::num(self.mean_interruptions)),
            ("mean_steps_computed", Json::num(self.mean_steps_computed)),
            ("makespan", Json::num(self.makespan)),
            ("failed", Json::num(self.failed as f64)),
            ("failed_by_kind", Json::obj(kinds)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::request::RequestTiming;
    use crate::util::tensor::Tensor;

    fn resp(queue: f64, inf: f64, priority: Priority) -> EditResponse {
        EditResponse {
            id: 0,
            template_id: "t".into(),
            image: Tensor::zeros(&[1, 1]),
            latent: Tensor::zeros(&[1, 1]),
            timing: RequestTiming {
                queue,
                inference: inf,
                e2e: queue + inf,
                interruptions: 2,
                steps_computed: 8,
            },
            mask_ratio: 0.1,
            priority,
        }
    }

    #[test]
    fn report_aggregates() {
        let mut r = Recorder::new();
        r.record(&resp(0.1, 0.5, Priority::Standard));
        r.record(&resp(0.3, 0.5, Priority::Standard));
        r.record_failure(&EditError::Cancelled);
        let rep = r.report(2.0);
        assert_eq!(rep.completed, 2);
        assert_eq!(rep.failed, 1);
        assert!(rep.line().contains("failed=1"));
        assert!((rep.throughput - 1.0).abs() < 1e-12);
        assert!((rep.queue.mean - 0.2).abs() < 1e-12);
        assert!((rep.e2e.mean - 0.7).abs() < 1e-12);
        assert_eq!(rep.mean_interruptions, 2.0);
        // json emits without panicking and parses back
        let j = rep.to_json().to_string();
        assert!(Json::parse(&j).is_ok());
    }

    #[test]
    fn report_breaks_out_classes_and_failure_kinds() {
        let mut r = Recorder::new();
        r.record(&resp(0.0, 0.2, Priority::Interactive));
        r.record(&resp(0.0, 0.4, Priority::Interactive));
        r.record(&resp(0.5, 0.5, Priority::Batch));
        r.record_failure(&EditError::Overloaded { retry_after_ms: 100 });
        r.record_failure(&EditError::Overloaded { retry_after_ms: 200 });
        r.record_failure(&EditError::DeadlineExceeded);
        r.record_failure(&EditError::Cancelled);
        assert_eq!(r.class_completed(Priority::Interactive), 2);
        assert_eq!(r.class_completed(Priority::Standard), 0);
        let rep = r.report(1.0);
        assert_eq!(rep.by_class.len(), 3);
        assert_eq!(rep.by_class[Priority::Interactive.rank()].completed, 2);
        assert_eq!(rep.by_class[Priority::Batch.rank()].completed, 1);
        assert!(
            (rep.by_class[Priority::Interactive.rank()].e2e.mean - 0.3).abs() < 1e-12,
            "per-class e2e means are independent"
        );
        // failure kinds are counted and sorted by kind
        assert_eq!(
            rep.failed_by_kind,
            vec![
                ("cancelled".to_string(), 1),
                ("deadline_exceeded".to_string(), 1),
                ("overloaded".to_string(), 2),
            ]
        );
        assert!(rep.line().contains("overloaded=2"), "{}", rep.line());
        // json carries both breakdowns
        let j = rep.to_json();
        assert_eq!(
            j.at("classes").at("interactive").at("completed").as_usize(),
            Some(2)
        );
        assert_eq!(j.at("failed_by_kind").at("overloaded").as_usize(), Some(2));
    }

    #[test]
    fn empty_recorder_safe() {
        let rep = Recorder::new().report(1.0);
        assert_eq!(rep.completed, 0);
        assert_eq!(rep.throughput, 0.0);
        assert_eq!(rep.by_class.len(), 3);
        assert!(rep.failed_by_kind.is_empty());
    }
}
