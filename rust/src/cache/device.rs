//! Device-resident KV working set — the HBM tier above the host/disk
//! `TieredStore`.
//!
//! Templates are reused up to 35 000× (paper §2.2), yet until this tier
//! existed every cache-KV step re-uploaded each cached block's packed
//! K/V host→device. The tier pins upload-once device buffers under a
//! byte budget so a *warm* template's cached blocks run with **zero**
//! per-step KV transfers; the budget is enforced by LRU eviction that
//! never touches a buffer the current batch is using (pinned), and
//! template retirement purges the tier the same way it purges host and
//! disk.
//!
//! The tier is generic over the payload so the eviction/budget/pinning
//! logic is property-testable without compiled artifacts; the engine
//! instantiates it with the `(K, V)` `PjRtBuffer` pair. `PjRtBuffer`s
//! are engine-thread-confined (see the SAFETY note on `ModelRuntime`),
//! so the tier lives inside the `Worker` and is only touched from the
//! engine thread — cross-thread retirement reaches it through a purge
//! list drained at step boundaries (`engine/worker.rs`).
//!
//! Keys are exact, not hashed: template ids and gather-id sets are
//! interned to small integers on first use, so two requests share an
//! entry only when their template, step, block, batch bucket, *and*
//! cached-row id set are all identical — a tier hit is bit-identical to
//! the upload it replaces by construction.

use std::collections::HashMap;
use std::rc::Rc;
use std::sync::Arc;

use crate::faults::{FaultInjector, FaultSite};

/// Key of one cached block's device-resident K/V.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct KvKey {
    /// Interned template id (`intern_template`).
    pub template: u32,
    /// Interned cached-row id set (`intern_ids`) — the exact rows the
    /// packed buffer was gathered from.
    pub ids: u32,
    pub step: u32,
    pub block: u32,
    /// Batch-bucket slot count of the packed `(bucket, L - n, H)` layout.
    pub bucket: u32,
}

struct Entry<P> {
    payload: Rc<P>,
    bytes: usize,
    pins: u32,
    last_used: u64,
}

/// Counters surfaced through `TransferTotals` and the overhead bench.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct KvTierStats {
    pub hits: u64,
    pub misses: u64,
    pub insertions: u64,
    pub evictions: u64,
    pub rejected: u64,
    pub purged: u64,
    pub bytes: u64,
    pub entries: u64,
    /// Injected (or real) upload/retention failures: the buffer served
    /// this step but was not retained — the block re-uploads next step
    /// (the device → host rung of the degradation ladder).
    pub upload_faults: u64,
}

/// HBM-budgeted LRU over upload-once device buffers.
pub struct KvDeviceTier<P> {
    budget: usize,
    bytes: usize,
    clock: u64,
    entries: HashMap<KvKey, Entry<P>>,
    templates: HashMap<String, u32>,
    id_sets: HashMap<Vec<usize>, u32>,
    hits: u64,
    misses: u64,
    insertions: u64,
    evictions: u64,
    rejected: u64,
    purged: u64,
    upload_faults: u64,
    faults: Option<Arc<FaultInjector>>,
}

impl<P> KvDeviceTier<P> {
    /// `budget` bytes of HBM; 0 disables the tier (every probe misses,
    /// every insert is refused).
    pub fn new(budget: usize) -> KvDeviceTier<P> {
        KvDeviceTier {
            budget,
            bytes: 0,
            clock: 0,
            entries: HashMap::new(),
            templates: HashMap::new(),
            id_sets: HashMap::new(),
            hits: 0,
            misses: 0,
            insertions: 0,
            evictions: 0,
            rejected: 0,
            purged: 0,
            upload_faults: 0,
            faults: None,
        }
    }

    /// Attach a fault injector (chaos testing); builder-style.
    pub fn with_faults(mut self, faults: Arc<FaultInjector>) -> KvDeviceTier<P> {
        self.faults = Some(faults);
        self
    }

    pub fn budget(&self) -> usize {
        self.budget
    }

    pub fn bytes(&self) -> usize {
        self.bytes
    }

    /// Intern a template id. Stable for the tier's lifetime (retirement
    /// purges the template's entries but keeps the intern slot, so a
    /// re-registered template reuses it — entries were purged, not
    /// poisoned).
    pub fn intern_template(&mut self, template_id: &str) -> u32 {
        let next = self.templates.len() as u32;
        *self.templates.entry(template_id.to_string()).or_insert(next)
    }

    /// Intern a cached-row id set by content.
    pub fn intern_ids(&mut self, ids: &[usize]) -> u32 {
        if let Some(&id) = self.id_sets.get(ids) {
            return id;
        }
        let next = self.id_sets.len() as u32;
        self.id_sets.insert(ids.to_vec(), next);
        next
    }

    /// Residency probe without touching LRU order or hit/miss counters
    /// (used to build the DP's warm mask before the step commits to a
    /// plan).
    pub fn contains(&self, key: &KvKey) -> bool {
        self.entries.contains_key(key)
    }

    /// Look up a resident buffer, refreshing its LRU position. Counts a
    /// hit or miss — call once per block per step, on the serving path.
    pub fn get(&mut self, key: &KvKey) -> Option<Rc<P>> {
        self.clock += 1;
        match self.entries.get_mut(key) {
            Some(e) => {
                e.last_used = self.clock;
                self.hits += 1;
                Some(Rc::clone(&e.payload))
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Insert an upload-once buffer, evicting unpinned LRU entries until
    /// it fits. Returns the shared payload and whether it was actually
    /// retained: when the budget cannot be met (entry larger than the
    /// budget, or everything else is pinned) the payload is handed back
    /// un-cached — the caller uses it for this step and it dies with the
    /// last `Rc`. The tier therefore *never* exceeds its byte budget.
    pub fn insert(&mut self, key: KvKey, payload: P, bytes: usize) -> (Rc<P>, bool) {
        let payload = Rc::new(payload);
        if let Some(prev) = self.entries.get(&key) {
            // racing re-insert of a resident key (e.g. re-upload after a
            // probe raced an eviction): keep the resident entry.
            return (Rc::clone(&prev.payload), true);
        }
        // injected upload/retention failure: the freshly uploaded buffer
        // still serves this step (correctness is untouched) but the tier
        // does not retain it — the block demotes to per-step re-upload
        if self
            .faults
            .as_ref()
            .is_some_and(|f| f.should(FaultSite::DeviceUpload))
        {
            self.rejected += 1;
            self.upload_faults += 1;
            return (payload, false);
        }
        if bytes > self.budget || !self.make_room(bytes) {
            self.rejected += 1;
            return (payload, false);
        }
        self.clock += 1;
        self.bytes += bytes;
        self.insertions += 1;
        self.entries.insert(
            key,
            Entry {
                payload: Rc::clone(&payload),
                bytes,
                pins: 0,
                last_used: self.clock,
            },
        );
        (payload, true)
    }

    /// Evict unpinned LRU entries until `incoming` more bytes fit.
    /// Returns false if that is impossible without evicting a pinned
    /// (in-use) entry.
    fn make_room(&mut self, incoming: usize) -> bool {
        while self.bytes + incoming > self.budget {
            let victim = self
                .entries
                .iter()
                .filter(|(_, e)| e.pins == 0)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| *k);
            match victim {
                Some(k) => {
                    let e = self.entries.remove(&k).expect("victim resident");
                    self.bytes -= e.bytes;
                    self.evictions += 1;
                }
                None => return false,
            }
        }
        true
    }

    /// Pin a resident entry for the duration of its use by the current
    /// batch — pinned entries are unevictable. No-op if absent.
    pub fn pin(&mut self, key: &KvKey) {
        if let Some(e) = self.entries.get_mut(key) {
            e.pins += 1;
        }
    }

    /// Release a pin. No-op if absent (the entry may have been purged by
    /// template retirement between pin and unpin — purge skips pinned
    /// entries, so this only happens after an explicit unpin).
    pub fn unpin(&mut self, key: &KvKey) {
        if let Some(e) = self.entries.get_mut(key) {
            e.pins = e.pins.saturating_sub(1);
        }
    }

    /// Drop every entry of a retired template (pinned entries are kept —
    /// retirement drains in-flight work first, so by the time the purge
    /// runs nothing should be pinned; if something is, it dies on its
    /// final unpin + next eviction instead of under the batch's feet).
    pub fn purge_template(&mut self, template_id: &str) {
        let Some(&tid) = self.templates.get(template_id) else {
            return;
        };
        let doomed: Vec<KvKey> = self
            .entries
            .iter()
            .filter(|(k, e)| k.template == tid && e.pins == 0)
            .map(|(k, _)| *k)
            .collect();
        for k in doomed {
            let e = self.entries.remove(&k).expect("doomed resident");
            self.bytes -= e.bytes;
            self.purged += 1;
        }
    }

    pub fn stats(&self) -> KvTierStats {
        KvTierStats {
            hits: self.hits,
            misses: self.misses,
            insertions: self.insertions,
            evictions: self.evictions,
            rejected: self.rejected,
            purged: self.purged,
            bytes: self.bytes as u64,
            entries: self.entries.len() as u64,
            upload_faults: self.upload_faults,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::prop_check;
    use crate::util::rng::Pcg;

    fn key(t: u32, step: u32, block: u32) -> KvKey {
        KvKey { template: t, ids: 0, step, block, bucket: 1 }
    }

    #[test]
    fn hit_after_insert_miss_when_cold() {
        let mut tier: KvDeviceTier<u32> = KvDeviceTier::new(100);
        let k = key(0, 0, 0);
        assert!(tier.get(&k).is_none());
        let (p, stored) = tier.insert(k, 7, 10);
        assert!(stored);
        assert_eq!(*p, 7);
        assert_eq!(*tier.get(&k).unwrap(), 7);
        let s = tier.stats();
        assert_eq!((s.hits, s.misses, s.bytes, s.entries), (1, 1, 10, 1));
    }

    #[test]
    fn lru_eviction_respects_budget_and_recency() {
        let mut tier: KvDeviceTier<u32> = KvDeviceTier::new(30);
        let (a, b, c) = (key(0, 0, 0), key(0, 0, 1), key(0, 0, 2));
        tier.insert(a, 1, 10);
        tier.insert(b, 2, 10);
        tier.insert(c, 3, 10);
        // touch a so b is LRU, then insert a fourth entry
        tier.get(&a);
        tier.insert(key(0, 0, 3), 4, 10);
        assert!(tier.contains(&a), "recently used survives");
        assert!(!tier.contains(&b), "LRU evicted");
        assert!(tier.contains(&c));
        assert_eq!(tier.bytes(), 30);
    }

    #[test]
    fn pinned_entries_are_unevictable_and_oversized_inserts_refused() {
        let mut tier: KvDeviceTier<u32> = KvDeviceTier::new(20);
        let a = key(0, 0, 0);
        tier.insert(a, 1, 20);
        tier.pin(&a);
        // no unpinned victim: the insert is refused, not over-budget
        let (p, stored) = tier.insert(key(0, 0, 1), 2, 10);
        assert!(!stored, "cannot evict the pinned entry");
        assert_eq!(*p, 2, "payload still handed back for one-shot use");
        assert!(tier.contains(&a));
        assert_eq!(tier.bytes(), 20);
        tier.unpin(&a);
        let (_, stored) = tier.insert(key(0, 0, 1), 2, 10);
        assert!(stored, "unpinned entry evictable again");
        // larger than the whole budget: always refused
        let (_, stored) = tier.insert(key(0, 0, 9), 9, 21);
        assert!(!stored);
    }

    #[test]
    fn purge_template_drops_only_that_template() {
        let mut tier: KvDeviceTier<u32> = KvDeviceTier::new(100);
        let ta = tier.intern_template("tpl-a");
        let tb = tier.intern_template("tpl-b");
        assert_eq!(tier.intern_template("tpl-a"), ta, "interning is stable");
        tier.insert(key(ta, 0, 0), 1, 10);
        tier.insert(key(ta, 1, 0), 2, 10);
        tier.insert(key(tb, 0, 0), 3, 10);
        tier.purge_template("tpl-a");
        assert!(!tier.contains(&key(ta, 0, 0)));
        assert!(!tier.contains(&key(ta, 1, 0)));
        assert!(tier.contains(&key(tb, 0, 0)));
        assert_eq!(tier.bytes(), 10);
        assert_eq!(tier.stats().purged, 2);
        tier.purge_template("never-seen"); // no-op
    }

    #[test]
    fn id_set_interning_is_content_exact() {
        let mut tier: KvDeviceTier<u32> = KvDeviceTier::new(100);
        let a = tier.intern_ids(&[1, 2, 3]);
        let b = tier.intern_ids(&[1, 2, 3]);
        let c = tier.intern_ids(&[1, 2, 4]);
        assert_eq!(a, b);
        assert_ne!(a, c, "different row sets must never share an entry");
    }

    #[test]
    fn zero_budget_disables_the_tier() {
        let mut tier: KvDeviceTier<u32> = KvDeviceTier::new(0);
        let (_, stored) = tier.insert(key(0, 0, 0), 1, 1);
        assert!(!stored);
        assert!(tier.get(&key(0, 0, 0)).is_none());
        assert_eq!(tier.bytes(), 0);
    }

    #[test]
    fn injected_upload_fault_serves_but_does_not_retain() {
        use crate::faults::{FaultPlan, FaultSite};
        let plan = FaultPlan::new(2).with_rate(FaultSite::DeviceUpload, 1.0);
        let mut tier: KvDeviceTier<u32> =
            KvDeviceTier::new(100).with_faults(Arc::new(FaultInjector::new(plan)));
        let k = key(0, 0, 0);
        let (p, stored) = tier.insert(k, 7, 10);
        assert!(!stored, "faulted upload must not be retained");
        assert_eq!(*p, 7, "the buffer still serves the current step");
        assert!(tier.get(&k).is_none(), "next step re-uploads");
        let s = tier.stats();
        assert_eq!((s.upload_faults, s.rejected, s.bytes, s.entries), (1, 1, 0, 0));
    }

    #[test]
    fn property_budget_and_pins_hold_under_random_ops() {
        // The acceptance invariants: bytes <= budget at every point, and
        // a pinned (in-use) entry is never evicted or purged.
        prop_check("kv tier budget + pin invariants", 120, |rng: &mut Pcg| {
            let budget = 16 + rng.below(64);
            let mut tier: KvDeviceTier<u64> = KvDeviceTier::new(budget);
            let mut pinned: Vec<KvKey> = Vec::new();
            for op in 0..200 {
                let k = key(rng.below(3) as u32, rng.below(4) as u32, rng.below(6) as u32);
                match rng.below(10) {
                    0..=4 => {
                        let bytes = 1 + rng.below(24);
                        let (_, _stored) = tier.insert(k, op as u64, bytes);
                    }
                    5..=6 => {
                        let _ = tier.get(&k);
                    }
                    7 => {
                        if tier.contains(&k) && pinned.len() < 4 {
                            tier.pin(&k);
                            pinned.push(k);
                        }
                    }
                    8 => {
                        if let Some(k) = pinned.pop() {
                            tier.unpin(&k);
                        }
                    }
                    _ => {
                        let t = rng.below(3) as u32;
                        // purge by interned name round-trip
                        let name = format!("t{t}");
                        let tid = tier.intern_template(&name);
                        if tid == t {
                            tier.purge_template(&name);
                        }
                    }
                }
                prop_assert!(
                    tier.bytes() <= budget,
                    "bytes {} exceeded budget {budget} after op {op}",
                    tier.bytes()
                );
                for p in &pinned {
                    prop_assert!(tier.contains(p), "pinned entry vanished after op {op}");
                }
            }
            let s = tier.stats();
            prop_assert!(s.bytes <= budget as u64, "stats bytes exceeded budget");
            Ok(())
        });
    }
}
