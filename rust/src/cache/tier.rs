//! Hierarchical activation storage — paper §4.2 "Hierarchical storage".
//!
//! Host tier: byte-budgeted map of templates with LRU eviction to the
//! disk tier (real spill files). A request whose template is only on disk
//! pays a promotion (real file IO + bandwidth pacing) — the paper hides
//! this under queuing time by starting promotion at enqueue, which the
//! worker reproduces by prefetching via the pre/post pool.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::store::{CacheEntry, TemplateActivations};

/// Counters for cache-behaviour observability (and tests).
#[derive(Debug, Default, Clone)]
pub struct TierStats {
    pub host_hits: u64,
    pub disk_promotions: u64,
    pub misses: u64,
    pub evictions: u64,
}

struct HostSlot {
    store: Arc<TemplateActivations>,
    last_used: Instant,
}

/// Byte-budgeted host tier + disk spill tier.
pub struct TieredStore {
    budget: usize,
    spill_dir: PathBuf,
    /// Simulated disk bandwidth (bytes/s); promotion pacing.
    disk_bandwidth: f64,
    inner: Mutex<Inner>,
}

struct Inner {
    host: HashMap<String, HostSlot>,
    bytes: usize,
    stats: TierStats,
}

impl TieredStore {
    pub fn new(budget: usize, spill_dir: PathBuf, disk_bandwidth: f64) -> TieredStore {
        TieredStore {
            budget,
            spill_dir,
            disk_bandwidth,
            inner: Mutex::new(Inner {
                host: HashMap::new(),
                bytes: 0,
                stats: TierStats::default(),
            }),
        }
    }

    pub fn stats(&self) -> TierStats {
        self.inner.lock().unwrap().stats.clone()
    }

    pub fn host_bytes(&self) -> usize {
        self.inner.lock().unwrap().bytes
    }

    /// Insert a freshly registered template (evicting LRU to disk if the
    /// budget overflows).
    pub fn insert(&self, store: Arc<TemplateActivations>) -> Result<()> {
        let size = store.size_bytes();
        let mut inner = self.inner.lock().unwrap();
        inner.bytes += size;
        inner.host.insert(
            store.template_id.clone(),
            HostSlot { store, last_used: Instant::now() },
        );
        self.evict_to_budget(&mut inner)?;
        Ok(())
    }

    /// Fetch a template's activations, promoting from disk if required.
    /// Returns `Ok(None)` when the template is unknown to both tiers
    /// (caller must register it).
    pub fn get(&self, template_id: &str) -> Result<Option<Arc<TemplateActivations>>> {
        {
            let mut inner = self.inner.lock().unwrap();
            if let Some(slot) = inner.host.get_mut(template_id) {
                slot.last_used = Instant::now();
                let store = Arc::clone(&slot.store);
                inner.stats.host_hits += 1;
                return Ok(Some(store));
            }
        }
        // disk promotion outside the lock (real IO)
        let path = self.spill_path(template_id);
        if !path.exists() {
            self.inner.lock().unwrap().stats.misses += 1;
            return Ok(None);
        }
        let t0 = Instant::now();
        let store = Arc::new(read_spill(&path)?);
        pace(store.size_bytes(), self.disk_bandwidth, t0);
        {
            let mut inner = self.inner.lock().unwrap();
            inner.stats.disk_promotions += 1;
            inner.bytes += store.size_bytes();
            inner.host.insert(
                template_id.to_string(),
                HostSlot { store: Arc::clone(&store), last_used: Instant::now() },
            );
            self.evict_to_budget(&mut inner)?;
        }
        Ok(Some(store))
    }

    /// True if the template is resident in the host tier.
    pub fn is_host_resident(&self, template_id: &str) -> bool {
        self.inner.lock().unwrap().host.contains_key(template_id)
    }

    fn evict_to_budget(&self, inner: &mut Inner) -> Result<()> {
        while inner.bytes > self.budget && inner.host.len() > 1 {
            // LRU victim
            let victim = inner
                .host
                .iter()
                .min_by_key(|(_, s)| s.last_used)
                .map(|(k, _)| k.clone())
                .expect("non-empty");
            let slot = inner.host.remove(&victim).unwrap();
            inner.bytes -= slot.store.size_bytes();
            inner.stats.evictions += 1;
            let path = self.spill_path(&victim);
            if !path.exists() {
                write_spill(&path, &slot.store)?;
            }
        }
        Ok(())
    }

    fn spill_path(&self, template_id: &str) -> PathBuf {
        // template ids are caller-controlled; sanitize for the filesystem
        let safe: String = template_id
            .chars()
            .map(|c| if c.is_ascii_alphanumeric() || c == '-' || c == '_' { c } else { '_' })
            .collect();
        self.spill_dir.join(format!("{safe}.actcache"))
    }
}

/// Sleep long enough that `bytes` took `bytes / bandwidth` seconds since
/// `t0` (bandwidth pacing for the simulated storage hierarchy).
fn pace(bytes: usize, bandwidth: f64, t0: Instant) {
    if bandwidth <= 0.0 {
        return;
    }
    let want = bytes as f64 / bandwidth;
    let spent = t0.elapsed().as_secs_f64();
    if want > spent {
        std::thread::sleep(std::time::Duration::from_secs_f64(want - spent));
    }
}

// -- spill file format -------------------------------------------------------
// header (little-endian u64s): magic, steps, blocks, tokens, hidden, seed,
// has_kv; then entries in (step, block) order, each y [+ k, v] as raw f32.

#[allow(clippy::unusual_byte_groupings)]
const SPILL_MAGIC: u64 = 0x1057_6e13_ac71_ca11;

fn write_spill(path: &PathBuf, store: &TemplateActivations) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let has_kv = store.entries().first().map(|e| e.kv.is_some()).unwrap_or(false);
    let mut buf: Vec<u8> = Vec::with_capacity(store.size_bytes() + 64);
    for v in [
        SPILL_MAGIC,
        store.steps as u64,
        store.blocks as u64,
        store.tokens as u64,
        store.hidden as u64,
        store.seed,
        has_kv as u64,
    ] {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    let mut push = |xs: &[f32]| {
        for x in xs {
            buf.extend_from_slice(&x.to_le_bytes());
        }
    };
    for e in store.entries() {
        push(&e.y);
        if let Some((k, v)) = &e.kv {
            push(k);
            push(v);
        }
    }
    std::fs::write(path, &buf).with_context(|| format!("writing spill {path:?}"))?;
    Ok(())
}

fn read_spill(path: &PathBuf) -> Result<TemplateActivations> {
    let bytes = std::fs::read(path).with_context(|| format!("reading spill {path:?}"))?;
    if bytes.len() < 56 {
        bail!("spill file too short");
    }
    let u64_at = |i: usize| {
        let mut b = [0u8; 8];
        b.copy_from_slice(&bytes[i * 8..(i + 1) * 8]);
        u64::from_le_bytes(b)
    };
    if u64_at(0) != SPILL_MAGIC {
        bail!("bad spill magic");
    }
    let steps = u64_at(1) as usize;
    let blocks = u64_at(2) as usize;
    let tokens = u64_at(3) as usize;
    let hidden = u64_at(4) as usize;
    let seed = u64_at(5);
    let has_kv = u64_at(6) != 0;
    let lh = tokens * hidden;
    let per_entry = lh * if has_kv { 3 } else { 1 };
    let want = 56 + steps * blocks * per_entry * 4;
    if bytes.len() != want {
        bail!("spill size mismatch: {} vs {}", bytes.len(), want);
    }
    let mut off = 56;
    let mut read_f32s = |n: usize| {
        let mut out = vec![0f32; n];
        for v in out.iter_mut() {
            let mut b = [0u8; 4];
            b.copy_from_slice(&bytes[off..off + 4]);
            *v = f32::from_le_bytes(b);
            off += 4;
        }
        out
    };
    let mut entries = Vec::with_capacity(steps * blocks);
    for _ in 0..steps * blocks {
        let y = read_f32s(lh);
        let kv = if has_kv {
            Some((read_f32s(lh), read_f32s(lh)))
        } else {
            None
        };
        entries.push(CacheEntry { y, kv });
    }
    let id = path
        .file_stem()
        .and_then(|s| s.to_str())
        .unwrap_or("unknown")
        .to_string();
    Ok(TemplateActivations::from_parts(
        id, String::new(), steps, blocks, tokens, hidden, seed, entries,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dummy(id: &str, steps: usize, blocks: usize, kv: bool) -> Arc<TemplateActivations> {
        let tokens = 4;
        let hidden = 2;
        let entries = (0..steps * blocks)
            .map(|i| CacheEntry {
                y: vec![i as f32; tokens * hidden],
                kv: kv.then(|| (vec![1.0; tokens * hidden], vec![2.0; tokens * hidden])),
            })
            .collect();
        Arc::new(TemplateActivations::from_parts(
            id.into(),
            "m".into(),
            steps,
            blocks,
            tokens,
            hidden,
            3,
            entries,
        ))
    }

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("ig-tier-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn spill_round_trip() {
        let dir = tmp_dir("rt");
        let s = dummy("abc", 2, 3, true);
        let path = dir.join("abc.actcache");
        write_spill(&path, &s).unwrap();
        let back = read_spill(&path).unwrap();
        assert_eq!(back.steps, 2);
        assert_eq!(back.blocks, 3);
        assert_eq!(back.entry(1, 2).y, s.entry(1, 2).y);
        assert_eq!(back.entry(0, 1).kv, s.entry(0, 1).kv);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn lru_evicts_to_disk_and_promotes_back() {
        let dir = tmp_dir("lru");
        let one_size = dummy("x", 2, 2, false).size_bytes();
        // budget fits exactly two templates
        let store = TieredStore::new(2 * one_size, dir.clone(), 0.0);
        store.insert(dummy("a", 2, 2, false)).unwrap();
        store.get("a").unwrap().unwrap(); // touch a
        std::thread::sleep(std::time::Duration::from_millis(2));
        store.insert(dummy("b", 2, 2, false)).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(2));
        store.insert(dummy("c", 2, 2, false)).unwrap(); // evicts LRU = a
        assert!(!store.is_host_resident("a"));
        assert!(store.is_host_resident("b") && store.is_host_resident("c"));
        // promotion from disk
        let a = store.get("a").unwrap().unwrap();
        assert_eq!(a.entry(1, 1).y[0], 3.0);
        let stats = store.stats();
        assert_eq!(stats.disk_promotions, 1);
        assert!(stats.evictions >= 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn unknown_template_is_none() {
        let dir = tmp_dir("none");
        let store = TieredStore::new(1 << 20, dir.clone(), 0.0);
        assert!(store.get("ghost").unwrap().is_none());
        assert_eq!(store.stats().misses, 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn disk_pacing_slows_promotion() {
        let dir = tmp_dir("pace");
        let s = dummy("slow", 4, 4, false);
        let size = s.size_bytes();
        let store = TieredStore::new(size, dir.clone(), size as f64 / 0.05); // 50ms/promotion
        store.insert(s).unwrap();
        store.insert(dummy("other", 4, 4, false)).unwrap(); // evict "slow"
        assert!(!store.is_host_resident("slow"));
        let t0 = Instant::now();
        store.get("slow").unwrap().unwrap();
        assert!(t0.elapsed().as_millis() >= 45, "promotion not paced");
        std::fs::remove_dir_all(&dir).ok();
    }
}
