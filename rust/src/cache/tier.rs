//! Hierarchical activation storage — paper §4.2 "Hierarchical storage".
//!
//! Host tier: byte-budgeted map of templates with LRU eviction to the
//! disk tier (real spill files). A request whose template is only on disk
//! pays a promotion (real file IO + bandwidth pacing) — the paper hides
//! this under queuing time by starting promotion at enqueue, which the
//! worker reproduces by prefetching via the pre/post pool.
//!
//! In a cluster each worker owns its own host tier (residency is what the
//! scheduler routes on) while the disk tier is shared: spill writes are
//! atomic (temp file + rename), so concurrent evictions of the same
//! template by different workers are safe, and [`TieredStore::remove`]
//! (template retirement) frees both tiers.
//!
//! Disk is the one tier backed by a medium that can actually rot, so its
//! failures are *typed*, never panics: every spill embeds a per-artifact
//! content checksum (bit-flips read back as [`TierError::Corrupt`], and
//! the poisoned file is dropped), read/write I/O errors surface as
//! [`TierError::Io`], and a run of consecutive disk failures trips a
//! [`CircuitBreaker`] that routes around the tier (reads skip to miss,
//! evictions drop instead of spilling) until a cooldown probe succeeds.
//! Callers treat every `Err` as "cache unavailable, recompute" — the
//! degradation ladder, never a request failure. A [`FaultInjector`] can
//! be attached to exercise all of it deterministically.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::store::{CacheEntry, TemplateActivations};
use crate::faults::{CircuitBreaker, FaultInjector, FaultSite, BREAKER_COOLDOWN, BREAKER_THRESHOLD};

/// Typed disk-tier failure. Every variant means "the cache copy is
/// unavailable"; none of them means the request must fail — the caller
/// falls back down the ladder (host → disk → full recompute).
#[derive(Debug, thiserror::Error)]
pub enum TierError {
    /// Real (or injected write-path) I/O failure; the spill file, if
    /// any, is left in place for a later retry.
    #[error("disk tier I/O on {path:?}: {source}")]
    Io {
        path: PathBuf,
        #[source]
        source: std::io::Error,
    },
    /// The artifact failed structural validation or its content
    /// checksum; the poisoned file has been dropped.
    #[error("corrupt spill {path:?}: {detail}")]
    Corrupt { path: PathBuf, detail: String },
    /// A deterministic injected fault (chaos testing).
    #[error("injected {0} fault")]
    Injected(&'static str),
}

/// Counters for cache-behaviour observability (and tests).
#[derive(Debug, Default, Clone)]
pub struct TierStats {
    pub host_hits: u64,
    pub disk_promotions: u64,
    pub misses: u64,
    pub evictions: u64,
    /// Disk read/write failures (I/O errors, corruption, injected).
    pub disk_faults: u64,
    /// Evictions that dropped the template without a disk copy (spill
    /// write failed or the breaker was open).
    pub spill_failures: u64,
}

/// Where a template currently lives in one worker's tier hierarchy — the
/// signal the cluster scheduler weighs as "cache loading" load (§4.4).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Residency {
    /// Hot in the host tier: serving needs no cache load.
    Host,
    /// Spilled to the disk tier: serving pays a promotion.
    Disk,
    /// Unknown to both tiers: serving needs a full registration.
    Absent,
}

impl Residency {
    /// Stable label for status endpoints.
    pub fn label(&self) -> &'static str {
        match self {
            Residency::Host => "host",
            Residency::Disk => "disk",
            Residency::Absent => "absent",
        }
    }
}

struct HostSlot {
    store: Arc<TemplateActivations>,
    last_used: Instant,
}

/// Byte-budgeted host tier + disk spill tier.
pub struct TieredStore {
    budget: usize,
    spill_dir: PathBuf,
    /// Simulated disk bandwidth (bytes/s); promotion pacing.
    disk_bandwidth: f64,
    /// Trips after [`BREAKER_THRESHOLD`] consecutive disk failures;
    /// while open, the disk tier is skipped entirely.
    breaker: CircuitBreaker,
    faults: Option<Arc<FaultInjector>>,
    inner: Mutex<Inner>,
}

struct Inner {
    host: HashMap<String, HostSlot>,
    bytes: usize,
    stats: TierStats,
    /// Templates removed (retired) since the last explicit insert: an
    /// in-flight disk promotion that raced [`TieredStore::remove`] must
    /// not resurrect their bytes in the host tier.
    tombstones: std::collections::HashSet<String>,
}

impl TieredStore {
    pub fn new(budget: usize, spill_dir: PathBuf, disk_bandwidth: f64) -> TieredStore {
        TieredStore {
            budget,
            spill_dir,
            disk_bandwidth,
            breaker: CircuitBreaker::new(BREAKER_THRESHOLD, BREAKER_COOLDOWN),
            faults: None,
            inner: Mutex::new(Inner {
                host: HashMap::new(),
                bytes: 0,
                stats: TierStats::default(),
                tombstones: std::collections::HashSet::new(),
            }),
        }
    }

    /// Attach a fault injector (chaos testing); builder-style, before the
    /// store is shared.
    pub fn with_faults(mut self, faults: Arc<FaultInjector>) -> TieredStore {
        self.faults = Some(faults);
        self
    }

    pub fn stats(&self) -> TierStats {
        self.inner.lock().unwrap().stats.clone()
    }

    /// Whether the disk tier's circuit breaker is currently open (the
    /// tier is being routed around). Feeds `/v1/readyz`.
    pub fn breaker_open(&self) -> bool {
        self.breaker.is_open()
    }

    /// Times the disk breaker has tripped.
    pub fn breaker_trips(&self) -> u64 {
        self.breaker.trips()
    }

    pub fn host_bytes(&self) -> usize {
        self.inner.lock().unwrap().bytes
    }

    /// Templates currently resident in the host tier.
    pub fn host_templates(&self) -> usize {
        self.inner.lock().unwrap().host.len()
    }

    /// Insert a freshly registered template (evicting LRU to disk if the
    /// budget overflows). Re-inserting a resident template replaces it
    /// without double-counting its bytes. Spill-write failures during
    /// eviction degrade (the victim is dropped and re-registers on next
    /// use) rather than erroring the insert.
    pub fn insert(&self, store: Arc<TemplateActivations>) -> Result<(), TierError> {
        let size = store.size_bytes();
        let mut inner = self.inner.lock().unwrap();
        inner.tombstones.remove(&store.template_id); // re-registration revives
        inner.bytes += size;
        if let Some(old) = inner.host.insert(
            store.template_id.clone(),
            HostSlot { store, last_used: Instant::now() },
        ) {
            inner.bytes -= old.store.size_bytes();
        }
        self.evict_to_budget(&mut inner);
        Ok(())
    }

    /// Drop a template from both tiers (retirement): frees its host-tier
    /// bytes and deletes its spill file. Returns the host bytes freed.
    pub fn remove(&self, template_id: &str) -> usize {
        let freed = {
            let mut inner = self.inner.lock().unwrap();
            // block concurrent in-flight promotions from re-inserting
            inner.tombstones.insert(template_id.to_string());
            match inner.host.remove(template_id) {
                Some(slot) => {
                    let size = slot.store.size_bytes();
                    inner.bytes -= size;
                    size
                }
                None => 0,
            }
        };
        let _ = std::fs::remove_file(self.spill_path(template_id));
        freed
    }

    /// Which tier (if any) holds the template right now.
    pub fn residency(&self, template_id: &str) -> Residency {
        if self.inner.lock().unwrap().host.contains_key(template_id) {
            Residency::Host
        } else if self.spill_path(template_id).exists() {
            Residency::Disk
        } else {
            Residency::Absent
        }
    }

    /// Fetch a template's activations, promoting from disk if required.
    /// Returns `Ok(None)` when the template is unknown to both tiers
    /// (caller must register it) and `Err` when a disk copy exists but
    /// cannot be served — the caller recomputes either way; `Err` is the
    /// degraded flavor.
    pub fn get(&self, template_id: &str) -> Result<Option<Arc<TemplateActivations>>, TierError> {
        {
            let mut inner = self.inner.lock().unwrap();
            if let Some(slot) = inner.host.get_mut(template_id) {
                slot.last_used = Instant::now();
                let store = Arc::clone(&slot.store);
                inner.stats.host_hits += 1;
                return Ok(Some(store));
            }
        }
        // disk promotion outside the lock (real IO)
        let path = self.spill_path(template_id);
        if !path.exists() {
            self.inner.lock().unwrap().stats.misses += 1;
            return Ok(None);
        }
        // open breaker: don't hammer a failing disk — read back as a
        // plain miss so the caller re-registers without the disk touch
        if !self.breaker.allow() {
            self.inner.lock().unwrap().stats.misses += 1;
            return Ok(None);
        }
        if let Some(inj) = &self.faults {
            if inj.should(FaultSite::DiskRead) {
                self.note_disk_failure();
                return Err(TierError::Injected("disk_read"));
            }
        }
        let t0 = Instant::now();
        let store = match read_spill(&path) {
            Ok(s) => Arc::new(s),
            Err(e) => {
                // corrupt or foreign-format spills are dropped (the next
                // attempt re-registers a clean copy); transient I/O
                // errors keep the file for a later retry
                if matches!(e, TierError::Corrupt { .. }) {
                    let _ = std::fs::remove_file(&path);
                }
                self.note_disk_failure();
                return Err(e);
            }
        };
        self.breaker.record_success();
        // the spill embeds its template id: a *different* id that merely
        // sanitizes to the same filename must never be served as ours
        // (the file legitimately belongs to the other template, so it is
        // left in place)
        if store.template_id != template_id {
            self.inner.lock().unwrap().stats.misses += 1;
            return Ok(None);
        }
        pace(store.size_bytes(), self.disk_bandwidth, t0);
        {
            let mut inner = self.inner.lock().unwrap();
            inner.stats.disk_promotions += 1;
            // a removal (retirement) raced this promotion: serve the
            // already-read activations to the draining caller, but do not
            // resurrect the template's bytes in the host tier
            if inner.tombstones.contains(template_id) {
                return Ok(Some(store));
            }
            inner.bytes += store.size_bytes();
            // a concurrent promotion (enqueue-time prefetch vs admission)
            // may have landed first: replace without double-counting
            if let Some(old) = inner.host.insert(
                template_id.to_string(),
                HostSlot { store: Arc::clone(&store), last_used: Instant::now() },
            ) {
                inner.bytes -= old.store.size_bytes();
            }
            self.evict_to_budget(&mut inner);
        }
        Ok(Some(store))
    }

    /// True if the template is resident in the host tier.
    pub fn is_host_resident(&self, template_id: &str) -> bool {
        self.inner.lock().unwrap().host.contains_key(template_id)
    }

    fn note_disk_failure(&self) {
        self.breaker.record_failure();
        self.inner.lock().unwrap().stats.disk_faults += 1;
    }

    fn evict_to_budget(&self, inner: &mut Inner) {
        while inner.bytes > self.budget && inner.host.len() > 1 {
            // LRU victim
            let Some(victim) = inner
                .host
                .iter()
                .min_by_key(|(_, s)| s.last_used)
                .map(|(k, _)| k.clone())
            else {
                break;
            };
            let Some(slot) = inner.host.remove(&victim) else { break };
            inner.bytes -= slot.store.size_bytes();
            inner.stats.evictions += 1;
            let path = self.spill_path(&victim);
            if path.exists() {
                continue;
            }
            // breaker open: drop the victim without a disk copy instead
            // of hammering a failing disk — it re-registers on next use
            if !self.breaker.allow() {
                inner.stats.spill_failures += 1;
                continue;
            }
            let injected = self
                .faults
                .as_ref()
                .is_some_and(|f| f.should(FaultSite::DiskWrite));
            let wrote = if injected {
                Err(TierError::Injected("disk_write"))
            } else {
                write_spill(&path, &slot.store, self.faults.as_deref())
            };
            match wrote {
                Ok(()) => self.breaker.record_success(),
                Err(_) => {
                    self.breaker.record_failure();
                    inner.stats.disk_faults += 1;
                    inner.stats.spill_failures += 1;
                }
            }
        }
    }

    fn spill_path(&self, template_id: &str) -> PathBuf {
        // template ids are caller-controlled; sanitize for the filesystem
        let safe: String = template_id
            .chars()
            .map(|c| if c.is_ascii_alphanumeric() || c == '-' || c == '_' { c } else { '_' })
            .collect();
        self.spill_dir.join(format!("{safe}.actcache"))
    }
}

/// Sleep long enough that `bytes` took `bytes / bandwidth` seconds since
/// `t0` (bandwidth pacing for the simulated storage hierarchy).
fn pace(bytes: usize, bandwidth: f64, t0: Instant) {
    if bandwidth <= 0.0 {
        return;
    }
    let want = bytes as f64 / bandwidth;
    let spent = t0.elapsed().as_secs_f64();
    if want > spent {
        std::thread::sleep(std::time::Duration::from_secs_f64(want - spent));
    }
}

// -- spill file format -------------------------------------------------------
// header (little-endian u64s): magic, steps, blocks, tokens, hidden, seed,
// has_kv, id_len, content checksum; then the template id (id_len raw bytes
// — filenames are sanitized, so distinct ids can share a path and the
// embedded id is the authority); then entries in (step, block) order, each
// y [+ k, v] as raw f32. The checksum is
// `TemplateActivations::content_checksum` over id + shape + every
// activation byte: any bit-flip in the payload reads back as
// `TierError::Corrupt` instead of silently denoising with garbage.

#[allow(clippy::unusual_byte_groupings)]
const SPILL_MAGIC: u64 = 0x1057_6e13_ac71_ca13; // ..12 was the unchecksummed v1

const SPILL_HEADER_BYTES: usize = 9 * 8;

/// Per-process unique suffix for atomic spill writes.
static SPILL_TMP_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);

fn write_spill(
    path: &PathBuf,
    store: &TemplateActivations,
    faults: Option<&FaultInjector>,
) -> Result<(), TierError> {
    let io_err = |p: &PathBuf| {
        let p = p.clone();
        move |source: std::io::Error| TierError::Io { path: p, source }
    };
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir).map_err(io_err(path))?;
    }
    let has_kv = store.entries().first().map(|e| e.kv.is_some()).unwrap_or(false);
    let id = store.template_id.as_bytes();
    let mut buf: Vec<u8> =
        Vec::with_capacity(store.size_bytes() + SPILL_HEADER_BYTES + id.len());
    for v in [
        SPILL_MAGIC,
        store.steps as u64,
        store.blocks as u64,
        store.tokens as u64,
        store.hidden as u64,
        store.seed,
        has_kv as u64,
        id.len() as u64,
        store.content_checksum(),
    ] {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    buf.extend_from_slice(id);
    let mut push = |xs: &[f32]| {
        for x in xs {
            buf.extend_from_slice(&x.to_le_bytes());
        }
    };
    for e in store.entries() {
        push(&e.y);
        if let Some((k, v)) = &e.kv {
            push(k);
            push(v);
        }
    }
    // injected bit rot: flip one bit anywhere in the artifact — the
    // checksum (or the structural validation) must catch it on read
    if let Some(inj) = faults {
        if inj.should(FaultSite::DiskCorrupt) {
            let bit = inj.word(FaultSite::DiskCorrupt) as usize % (buf.len() * 8);
            buf[bit / 8] ^= 1 << (bit % 8);
        }
    }
    // atomic publish: workers share the disk tier, so a concurrent
    // eviction of the same template must never interleave writes —
    // readers see either the old complete file or the new one
    let tmp = path.with_extension(format!(
        "tmp-{}-{}",
        std::process::id(),
        SPILL_TMP_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
    ));
    std::fs::write(&tmp, &buf).map_err(io_err(&tmp))?;
    std::fs::rename(&tmp, path).map_err(io_err(path))?;
    Ok(())
}

fn read_spill(path: &PathBuf) -> Result<TemplateActivations, TierError> {
    let corrupt = |detail: String| TierError::Corrupt { path: path.clone(), detail };
    let bytes = std::fs::read(path)
        .map_err(|source| TierError::Io { path: path.clone(), source })?;
    if bytes.len() < SPILL_HEADER_BYTES {
        return Err(corrupt("spill file too short".into()));
    }
    let u64_at = |i: usize| {
        let mut b = [0u8; 8];
        b.copy_from_slice(&bytes[i * 8..(i + 1) * 8]);
        u64::from_le_bytes(b)
    };
    if u64_at(0) != SPILL_MAGIC {
        return Err(corrupt("bad spill magic".into()));
    }
    let steps = u64_at(1) as usize;
    let blocks = u64_at(2) as usize;
    let tokens = u64_at(3) as usize;
    let hidden = u64_at(4) as usize;
    let seed = u64_at(5);
    let has_kv = u64_at(6) != 0;
    let id_len = u64_at(7) as usize;
    let checksum = u64_at(8);
    let lh = tokens * hidden;
    let per_entry = lh * if has_kv { 3 } else { 1 };
    let want = steps
        .checked_mul(blocks)
        .and_then(|n| n.checked_mul(per_entry))
        .and_then(|n| n.checked_mul(4))
        .and_then(|n| n.checked_add(SPILL_HEADER_BYTES))
        .and_then(|n| n.checked_add(id_len))
        .unwrap_or(usize::MAX);
    if bytes.len() != want {
        return Err(corrupt(format!("spill size mismatch: {} vs {}", bytes.len(), want)));
    }
    let id = String::from_utf8(
        bytes[SPILL_HEADER_BYTES..SPILL_HEADER_BYTES + id_len].to_vec(),
    )
    .map_err(|_| corrupt("spill template id not utf-8".into()))?;
    let mut off = SPILL_HEADER_BYTES + id_len;
    let mut read_f32s = |n: usize| {
        let mut out = vec![0f32; n];
        for v in out.iter_mut() {
            let mut b = [0u8; 4];
            b.copy_from_slice(&bytes[off..off + 4]);
            *v = f32::from_le_bytes(b);
            off += 4;
        }
        out
    };
    let mut entries = Vec::with_capacity(steps * blocks);
    for _ in 0..steps * blocks {
        let y = read_f32s(lh);
        let kv = if has_kv {
            Some((read_f32s(lh), read_f32s(lh)))
        } else {
            None
        };
        entries.push(CacheEntry { y, kv });
    }
    let acts = TemplateActivations::from_parts(
        id, String::new(), steps, blocks, tokens, hidden, seed, entries,
    );
    if acts.content_checksum() != checksum {
        return Err(corrupt("content checksum mismatch".into()));
    }
    Ok(acts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::faults::{FaultPlan, BREAKER_THRESHOLD};

    fn dummy(id: &str, steps: usize, blocks: usize, kv: bool) -> Arc<TemplateActivations> {
        let tokens = 4;
        let hidden = 2;
        let entries = (0..steps * blocks)
            .map(|i| CacheEntry {
                y: vec![i as f32; tokens * hidden],
                kv: kv.then(|| (vec![1.0; tokens * hidden], vec![2.0; tokens * hidden])),
            })
            .collect();
        Arc::new(TemplateActivations::from_parts(
            id.into(),
            "m".into(),
            steps,
            blocks,
            tokens,
            hidden,
            3,
            entries,
        ))
    }

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("ig-tier-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn spill_round_trip() {
        let dir = tmp_dir("rt");
        let s = dummy("abc", 2, 3, true);
        let path = dir.join("abc.actcache");
        write_spill(&path, &s, None).unwrap();
        let back = read_spill(&path).unwrap();
        assert_eq!(back.template_id, "abc", "spill embeds its template id");
        assert_eq!(back.steps, 2);
        assert_eq!(back.blocks, 3);
        assert_eq!(back.entry(1, 2).y, s.entry(1, 2).y);
        assert_eq!(back.entry(0, 1).kv, s.entry(0, 1).kv);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn lru_evicts_to_disk_and_promotes_back() {
        let dir = tmp_dir("lru");
        let one_size = dummy("x", 2, 2, false).size_bytes();
        // budget fits exactly two templates
        let store = TieredStore::new(2 * one_size, dir.clone(), 0.0);
        store.insert(dummy("a", 2, 2, false)).unwrap();
        store.get("a").unwrap().unwrap(); // touch a
        std::thread::sleep(std::time::Duration::from_millis(2));
        store.insert(dummy("b", 2, 2, false)).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(2));
        store.insert(dummy("c", 2, 2, false)).unwrap(); // evicts LRU = a
        assert!(!store.is_host_resident("a"));
        assert!(store.is_host_resident("b") && store.is_host_resident("c"));
        // promotion from disk
        let a = store.get("a").unwrap().unwrap();
        assert_eq!(a.entry(1, 1).y[0], 3.0);
        let stats = store.stats();
        assert_eq!(stats.disk_promotions, 1);
        assert!(stats.evictions >= 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn unknown_template_is_none() {
        let dir = tmp_dir("none");
        let store = TieredStore::new(1 << 20, dir.clone(), 0.0);
        assert!(store.get("ghost").unwrap().is_none());
        assert_eq!(store.stats().misses, 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn remove_frees_both_tiers() {
        let dir = tmp_dir("rm");
        let one_size = dummy("x", 2, 2, false).size_bytes();
        let store = TieredStore::new(one_size, dir.clone(), 0.0);
        store.insert(dummy("a", 2, 2, false)).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(2));
        store.insert(dummy("b", 2, 2, false)).unwrap(); // spills a to disk
        assert_eq!(store.residency("a"), Residency::Disk);
        assert_eq!(store.residency("b"), Residency::Host);
        assert_eq!(store.residency("ghost"), Residency::Absent);
        // removing frees host bytes and deletes the spill file
        assert_eq!(store.remove("b"), one_size);
        assert_eq!(store.remove("a"), 0, "a held no host bytes");
        assert_eq!(store.residency("a"), Residency::Absent);
        assert_eq!(store.host_bytes(), 0);
        assert_eq!(store.host_templates(), 0);
        assert!(store.get("a").unwrap().is_none(), "removed templates are gone");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sanitized_path_collision_never_serves_foreign_template() {
        let dir = tmp_dir("collide");
        let one_size = dummy("x", 2, 2, false).size_bytes();
        let store = TieredStore::new(one_size, dir.clone(), 0.0);
        // "a/b" sanitizes to the same spill path as "a_b"
        store.insert(dummy("a/b", 2, 2, false)).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(2));
        store.insert(dummy("a_b", 2, 2, false)).unwrap(); // spills "a/b"
        std::thread::sleep(std::time::Duration::from_millis(2));
        // evicts "a_b"; the shared path already exists, keeping "a/b"
        store.insert(dummy("other", 2, 2, false)).unwrap();
        // the spill embeds id "a/b": a get for "a_b" must refuse it
        // instead of serving a foreign template's activations
        assert!(store.get("a_b").unwrap().is_none());
        // the rightful owner still promotes
        let back = store.get("a/b").unwrap().expect("owner promotes");
        assert_eq!(back.template_id, "a/b");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_spill_is_typed_and_dropped() {
        let dir = tmp_dir("corrupt");
        let store = TieredStore::new(1 << 20, dir.clone(), 0.0);
        let path = store.spill_path("bad");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(&path, b"not a spill file").unwrap();
        let err = store.get("bad").expect_err("corrupt file is a typed failure");
        assert!(matches!(err, TierError::Corrupt { .. }), "got {err:?}");
        assert!(!path.exists(), "corrupt file is dropped");
        assert_eq!(store.stats().disk_faults, 1);
        // with the poisoned file gone, the next lookup is a clean miss
        // (the ladder's re-registration path)
        assert!(store.get("bad").unwrap().is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn checksum_catches_payload_bit_flip() {
        let dir = tmp_dir("bitflip");
        let s = dummy("flip", 2, 2, false);
        let path = dir.join("flip.actcache");
        write_spill(&path, &s, None).unwrap();
        // flip one bit in the activation payload; the size still matches
        let mut bytes = std::fs::read(&path).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0x10;
        std::fs::write(&path, &bytes).unwrap();
        let err = read_spill(&path).expect_err("bit rot must not round-trip");
        assert!(
            matches!(&err, TierError::Corrupt { detail, .. } if detail.contains("checksum")),
            "got {err:?}"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn injected_read_faults_trip_the_breaker() {
        let dir = tmp_dir("inj-read");
        let plan = FaultPlan::new(11).with_rate(crate::faults::FaultSite::DiskRead, 1.0);
        let inj = Arc::new(FaultInjector::new(plan));
        let one_size = dummy("x", 2, 2, false).size_bytes();
        let store =
            TieredStore::new(one_size, dir.clone(), 0.0).with_faults(Arc::clone(&inj));
        store.insert(dummy("a", 2, 2, false)).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(2));
        store.insert(dummy("b", 2, 2, false)).unwrap(); // spills a
        assert_eq!(store.residency("a"), Residency::Disk);
        for i in 0..BREAKER_THRESHOLD {
            let err = store.get("a").expect_err("injected read fault");
            assert!(matches!(err, TierError::Injected("disk_read")), "try {i}: {err:?}");
        }
        assert!(store.breaker_open(), "threshold failures open the breaker");
        assert_eq!(store.breaker_trips(), 1);
        // while open, the disk tier is skipped: a plain miss, no draw
        let before = inj.injected(crate::faults::FaultSite::DiskRead);
        assert!(store.get("a").unwrap().is_none(), "open breaker reads as miss");
        assert_eq!(inj.injected(crate::faults::FaultSite::DiskRead), before);
        assert_eq!(store.stats().disk_faults, BREAKER_THRESHOLD as u64);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn injected_write_corruption_is_caught_on_promotion() {
        let dir = tmp_dir("inj-corrupt");
        let plan = FaultPlan::new(5).with_rate(crate::faults::FaultSite::DiskCorrupt, 1.0);
        let store = TieredStore::new(dummy("x", 2, 2, false).size_bytes(), dir.clone(), 0.0)
            .with_faults(Arc::new(FaultInjector::new(plan)));
        store.insert(dummy("a", 2, 2, false)).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(2));
        store.insert(dummy("b", 2, 2, false)).unwrap(); // spills a, corrupted
        assert_eq!(store.residency("a"), Residency::Disk);
        let err = store.get("a").expect_err("corrupted spill must not serve");
        assert!(matches!(err, TierError::Corrupt { .. }), "got {err:?}");
        assert_eq!(store.residency("a"), Residency::Absent, "poisoned file dropped");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn injected_write_failure_drops_victim_without_spill() {
        let dir = tmp_dir("inj-write");
        let plan = FaultPlan::new(9).with_rate(crate::faults::FaultSite::DiskWrite, 1.0);
        let store = TieredStore::new(dummy("x", 2, 2, false).size_bytes(), dir.clone(), 0.0)
            .with_faults(Arc::new(FaultInjector::new(plan)));
        store.insert(dummy("a", 2, 2, false)).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(2));
        store.insert(dummy("b", 2, 2, false)).unwrap(); // eviction spill fails
        assert_eq!(store.residency("a"), Residency::Absent, "no disk copy");
        assert!(store.stats().spill_failures >= 1);
        // the degraded victim is a plain miss: callers re-register
        assert!(store.get("a").unwrap().is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn tombstoned_promotion_serves_but_does_not_resurrect() {
        let dir = tmp_dir("tomb");
        let one = dummy("a", 2, 2, false);
        let store = TieredStore::new(one.size_bytes(), dir.clone(), 0.0);
        store.insert(Arc::clone(&one)).unwrap();
        assert_eq!(store.remove("a"), one.size_bytes());
        // simulate a promotion racing the removal: the spill file is
        // still readable when the promotion gets to the host insert
        write_spill(&store.spill_path("a"), &one, None).unwrap();
        let got = store.get("a").unwrap().expect("draining reader is served");
        assert_eq!(got.entry(0, 0).y, one.entry(0, 0).y);
        assert!(!store.is_host_resident("a"), "retired bytes must not resurrect");
        assert_eq!(store.host_bytes(), 0);
        // explicit re-registration revives the template
        store.insert(Arc::clone(&one)).unwrap();
        assert!(store.is_host_resident("a"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn reinsert_does_not_double_count_bytes() {
        let dir = tmp_dir("dup");
        let store = TieredStore::new(1 << 20, dir.clone(), 0.0);
        let s = dummy("a", 2, 2, false);
        let size = s.size_bytes();
        store.insert(Arc::clone(&s)).unwrap();
        store.insert(s).unwrap();
        assert_eq!(store.host_bytes(), size);
        assert_eq!(store.host_templates(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Property: under random insert/get sequences with a byte budget that
    /// fits exactly two (equal-sized) templates, (1) host bytes never
    /// exceed the budget, (2) exactly the two least-recently-used
    /// templates have been evicted (host tier == 2 MRU set), and (3) a
    /// template promoted back from disk is bit-identical to what was
    /// inserted.
    #[test]
    fn property_random_ops_hold_tier_invariants() {
        use crate::prop_assert;
        use crate::util::prop::prop_check;

        // deterministic per-template payload so bit-identity is checkable
        let make = |i: usize| {
            let tokens = 4;
            let hidden = 2;
            let entries = (0..4)
                .map(|e| CacheEntry {
                    y: (0..tokens * hidden)
                        .map(|k| (i * 1000 + e * 10 + k) as f32 * 0.5)
                        .collect(),
                    kv: None,
                })
                .collect();
            Arc::new(TemplateActivations::from_parts(
                format!("p{i}"),
                "m".into(),
                2,
                2,
                tokens,
                hidden,
                3,
                entries,
            ))
        };
        let one_size = make(0).size_bytes();
        let budget = 2 * one_size;
        let base = tmp_dir("prop");
        let case = std::cell::Cell::new(0usize);

        prop_check("tiered store invariants", 12, |rng| {
            case.set(case.get() + 1);
            let dir = base.join(format!("case-{}", case.get()));
            std::fs::create_dir_all(&dir).map_err(|e| e.to_string())?;
            let store = TieredStore::new(budget, dir.clone(), 0.0);
            let mut inserted: Vec<bool> = vec![false; 4];
            let mut touched: Vec<usize> = Vec::new(); // recency, MRU last
            let touch = |touched: &mut Vec<usize>, i: usize| {
                touched.retain(|&t| t != i);
                touched.push(i);
            };
            for _ in 0..12 {
                let i = rng.below(4);
                if rng.below(2) == 0 {
                    store.insert(make(i)).map_err(|e| e.to_string())?;
                    inserted[i] = true;
                    touch(&mut touched, i);
                } else {
                    let got = store.get(&format!("p{i}")).map_err(|e| e.to_string())?;
                    if inserted[i] {
                        let got = got.ok_or("known template vanished")?;
                        let want = make(i);
                        for e in 0..4 {
                            prop_assert!(
                                got.entries()[e].y == want.entries()[e].y,
                                "promoted template p{i} not bit-identical at entry {e}"
                            );
                        }
                        touch(&mut touched, i);
                    } else {
                        prop_assert!(got.is_none(), "uninserted template p{i} resolved");
                    }
                }
                // distinct LRU timestamps for the next eviction decision
                std::thread::sleep(std::time::Duration::from_millis(2));
                prop_assert!(
                    store.host_bytes() <= budget,
                    "host bytes {} exceed budget {budget}",
                    store.host_bytes()
                );
                // the host tier holds exactly the MRU-2 of touched templates
                let expect: Vec<usize> =
                    touched.iter().rev().take(2).copied().collect();
                for t in 0..4 {
                    let id = format!("p{t}");
                    let want_host = expect.contains(&t);
                    prop_assert!(
                        store.is_host_resident(&id) == want_host,
                        "p{t}: host residency {} but LRU model says {want_host} \
                         (recency {touched:?})",
                        store.is_host_resident(&id)
                    );
                }
            }
            std::fs::remove_dir_all(&dir).ok();
            Ok(())
        });
        std::fs::remove_dir_all(&base).ok();
    }

    #[test]
    fn disk_pacing_slows_promotion() {
        let dir = tmp_dir("pace");
        let s = dummy("slow", 4, 4, false);
        let size = s.size_bytes();
        let store = TieredStore::new(size, dir.clone(), size as f64 / 0.05); // 50ms/promotion
        store.insert(s).unwrap();
        store.insert(dummy("other", 4, 4, false)).unwrap(); // evict "slow"
        assert!(!store.is_host_resident("slow"));
        let t0 = Instant::now();
        store.get("slow").unwrap().unwrap();
        assert!(t0.elapsed().as_millis() >= 45, "promotion not paced");
        std::fs::remove_dir_all(&dir).ok();
    }
}
