//! Cache-load stream — the simulated DRAM→HBM copy engine (paper §4.2).
//!
//! A dedicated loader thread plays the role of the CUDA copy stream: the
//! worker submits, in pipeline-plan order, one gather job per cached
//! block; the loader gathers each batch member's unmasked rows from its
//! template activations (a real memcpy) and *paces* the job to the
//! configured bandwidth, so the load:compute ratio matches the paper's
//! PCIe regime (DESIGN.md "Substitutions"). The worker blocks on the
//! completion channel when it reaches a cached block whose activations
//! have not landed — that wait is exactly the pipeline bubble the DP of
//! Algorithm 1 squeezes out.
//!
//! Cache-KV jobs stage K/V directly in the packed `(slots, L - n, H)`
//! layout the kernel consumes (padding slots replicate the last member),
//! so the worker uploads the staged buffers as-is instead of re-packing
//! them on the engine thread. Pacing charges only the *real* members'
//! bytes — padding replication is layout, not load. A job whose K/V is
//! already resident in the device KV tier is submitted with `skip_kv`:
//! only the Y rows are gathered and paced, so the copy stream is never
//! billed for a load that never happens (keeping Algorithm-2 estimates
//! honest).

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use crate::cache::store::TemplateActivations;
use crate::config::CacheMode;
use crate::faults::{FaultInjector, FaultSite};

/// What to stage for one batch member of one block.
#[derive(Clone)]
pub struct MemberGather {
    pub store: Arc<TemplateActivations>,
    /// Denoise step of this member (members batch at different steps
    /// under continuous batching).
    pub step: usize,
    /// Token ids (canonical order) whose cached rows to stage.
    pub ids: Arc<Vec<usize>>,
}

/// Staged activations of one block for the whole batch.
pub struct StagedBlock {
    pub block: usize,
    /// Per member: gathered Y rows `(|ids|, H)` (replenish at
    /// cached→full transitions, Fig. 5).
    pub y: Vec<Vec<f32>>,
    /// Cache-KV mode: K and V in the packed `(slots, L - n, H)` device
    /// layout, upload-ready (padding slots replicate the last member).
    pub kv_packed: Option<(Vec<f32>, Vec<f32>)>,
    /// Bytes genuinely loaded (pacing input; excludes padding slots).
    pub bytes: usize,
}

struct Job {
    block: usize,
    members: Vec<MemberGather>,
    mode: CacheMode,
    /// Batch-bucket slot count of the packed K/V layout (>= members).
    slots: usize,
    /// Cache-KV only: the block's K/V is device-resident — gather (and
    /// pace) only the Y rows.
    skip_kv: bool,
    done: Sender<StagedBlock>,
}

/// Handle to the loader thread.
pub struct CacheLoader {
    tx: Option<Sender<Job>>,
    handle: Option<JoinHandle<()>>,
    bandwidth: f64,
}

impl CacheLoader {
    /// Spawn the loader with the given simulated bandwidth (bytes/sec;
    /// `0` disables pacing — the "ideal" ablation of Fig. 4-Left).
    pub fn spawn(bandwidth: f64) -> CacheLoader {
        CacheLoader::spawn_with_faults(bandwidth, None)
    }

    /// Spawn with an optional fault injector. An injected `loader_fail`
    /// drops the job's completion sender without staging anything — the
    /// worker's recv error on the completion channel is its signal to
    /// fall back to a synchronous host-store gather (bit-identical, just
    /// unoverlapped).
    pub fn spawn_with_faults(
        bandwidth: f64,
        faults: Option<Arc<FaultInjector>>,
    ) -> CacheLoader {
        let (tx, rx): (Sender<Job>, Receiver<Job>) = channel();
        let handle = std::thread::Builder::new()
            .name("cache-loader".into())
            .spawn(move || {
                while let Ok(job) = rx.recv() {
                    if faults.as_ref().is_some_and(|f| f.should(FaultSite::LoaderFail)) {
                        // staging job "dies": the receiver observes a
                        // disconnected channel, never a hang
                        drop(job.done);
                        continue;
                    }
                    let t0 = Instant::now();
                    let staged =
                        gather(job.block, &job.members, job.mode, job.slots, job.skip_kv);
                    pace(staged.bytes, bandwidth, t0);
                    let _ = job.done.send(staged);
                }
            })
            .expect("spawn cache-loader");
        CacheLoader { tx: Some(tx), handle: Some(handle), bandwidth }
    }

    pub fn bandwidth(&self) -> f64 {
        self.bandwidth
    }

    /// Submit a gather job; completion arrives on the returned receiver.
    /// Jobs are processed FIFO — submission order *is* the load-stream
    /// order assumed by the pipeline DP. `slots` sets the packed K/V
    /// layout's batch-bucket size (ignored in cache-Y mode). Pass
    /// `skip_kv` when the block's K/V is already device-resident: the
    /// job then gathers (and is paced for) only the Y rows.
    pub fn submit(
        &self,
        block: usize,
        members: Vec<MemberGather>,
        mode: CacheMode,
        slots: usize,
        skip_kv: bool,
    ) -> Receiver<StagedBlock> {
        let (done_tx, done_rx) = channel();
        self.tx
            .as_ref()
            .expect("loader alive")
            .send(Job { block, members, mode, slots, skip_kv, done: done_tx })
            .expect("loader thread alive");
        done_rx
    }

    /// Synchronous gather without the loader thread (naive-loading
    /// ablation: the compute stream itself performs the load).
    pub fn gather_sync(
        &self,
        block: usize,
        members: Vec<MemberGather>,
        mode: CacheMode,
        slots: usize,
    ) -> StagedBlock {
        let t0 = Instant::now();
        let staged = gather(block, &members, mode, slots, false);
        pace(staged.bytes, self.bandwidth, t0);
        staged
    }
}

impl Drop for CacheLoader {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn gather(
    block: usize,
    members: &[MemberGather],
    mode: CacheMode,
    slots: usize,
    skip_kv: bool,
) -> StagedBlock {
    let mut y = Vec::with_capacity(members.len());
    let mut bytes = 0usize;
    for m in members {
        let entry = m.store.entry(m.step, block);
        let h = m.store.hidden;
        let mut rows = vec![0f32; m.ids.len() * h];
        gather_rows(&entry.y, h, &m.ids, &mut rows);
        bytes += rows.len() * 4;
        y.push(rows);
    }
    let want_kv = matches!(mode, CacheMode::CacheKV) && !skip_kv && !members.is_empty();
    let kv_packed = want_kv.then(|| {
        let slots = slots.max(members.len());
        let h = members[0].store.hidden;
        let rows = members[0].ids.len();
        let mut k = vec![0f32; slots * rows * h];
        let mut v = vec![0f32; slots * rows * h];
        for (s, m) in members.iter().enumerate() {
            debug_assert_eq!(m.ids.len(), rows, "uniform bucket per job");
            let (ks, vs) = m
                .store
                .entry(m.step, block)
                .kv
                .as_ref()
                .expect("cache-KV mode requires K/V-registered templates");
            gather_rows(ks, h, &m.ids, &mut k[s * rows * h..(s + 1) * rows * h]);
            gather_rows(vs, h, &m.ids, &mut v[s * rows * h..(s + 1) * rows * h]);
            bytes += 2 * rows * h * 4;
        }
        // padding slots replicate the last member: one contiguous memcpy
        // each (layout only — neither gathered again nor paced as load)
        let last = (members.len() - 1) * rows * h;
        for s in members.len()..slots {
            k.copy_within(last..last + rows * h, s * rows * h);
            v.copy_within(last..last + rows * h, s * rows * h);
        }
        (k, v)
    });
    StagedBlock { block, y, kv_packed, bytes }
}

fn gather_rows(src: &[f32], h: usize, ids: &[usize], out: &mut [f32]) {
    for (i, &id) in ids.iter().enumerate() {
        out[i * h..(i + 1) * h].copy_from_slice(&src[id * h..(id + 1) * h]);
    }
}

fn pace(bytes: usize, bandwidth: f64, t0: Instant) {
    if bandwidth <= 0.0 {
        return;
    }
    let want = bytes as f64 / bandwidth;
    let spent = t0.elapsed().as_secs_f64();
    if want > spent {
        std::thread::sleep(std::time::Duration::from_secs_f64(want - spent));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::store::CacheEntry;

    fn store(kv: bool) -> Arc<TemplateActivations> {
        let tokens = 4;
        let hidden = 2;
        let entries = (0..4)
            .map(|i| CacheEntry {
                y: (0..tokens * hidden).map(|j| (i * 10 + j) as f32).collect(),
                kv: kv.then(|| {
                    (
                        vec![(i * 100) as f32; tokens * hidden],
                        vec![(i * 1000) as f32; tokens * hidden],
                    )
                }),
            })
            .collect();
        Arc::new(TemplateActivations::from_parts(
            "t".into(),
            "m".into(),
            2,
            2,
            tokens,
            hidden,
            0,
            entries,
        ))
    }

    #[test]
    fn gathers_requested_rows_in_order() {
        let loader = CacheLoader::spawn(0.0);
        let m = MemberGather { store: store(false), step: 1, ids: Arc::new(vec![3, 1]) };
        let rx = loader.submit(0, vec![m], CacheMode::CacheY, 1, false);
        let staged = rx.recv().unwrap();
        assert_eq!(staged.block, 0);
        // entry(1, 0) has base 2*10; row 3 = [26, 27], row 1 = [22, 23]
        assert_eq!(staged.y[0], vec![26.0, 27.0, 22.0, 23.0]);
        assert!(staged.kv_packed.is_none());
        assert_eq!(staged.bytes, 4 * 4);
    }

    #[test]
    fn kv_mode_stages_packed_kv_with_padding() {
        let loader = CacheLoader::spawn(0.0);
        let m = MemberGather { store: store(true), step: 0, ids: Arc::new(vec![0]) };
        // 1 member, 2 slots: the padding slot replicates the member
        let staged = loader
            .submit(1, vec![m], CacheMode::CacheKV, 2, false)
            .recv()
            .unwrap();
        let (k, v) = staged.kv_packed.unwrap();
        assert_eq!(k, vec![100.0, 100.0, 100.0, 100.0]);
        assert_eq!(v, vec![1000.0, 1000.0, 1000.0, 1000.0]);
        // bytes: y (1 row x 2 floats) + real-member k/v (2 x 2 floats);
        // the padding slot is layout, not load
        assert_eq!(staged.bytes, (2 + 2 + 2) * 4);
    }

    #[test]
    fn device_served_kv_job_skips_kv_staging_and_pacing_bytes() {
        let loader = CacheLoader::spawn(0.0);
        let m = || MemberGather { store: store(true), step: 0, ids: Arc::new(vec![0]) };
        let cold = loader.submit(1, vec![m()], CacheMode::CacheKV, 2, false).recv().unwrap();
        let warm = loader.submit(1, vec![m()], CacheMode::CacheKV, 2, true).recv().unwrap();
        assert!(cold.kv_packed.is_some());
        assert!(warm.kv_packed.is_none(), "device-served job stages no K/V");
        assert_eq!(warm.y, cold.y, "Y rows still gathered for the replenish path");
        // the pacer is billed only for the Y rows — not for a K/V load
        // that the device tier made unnecessary
        assert_eq!(warm.bytes, 2 * 4, "y bytes only: 1 row x hidden 2 x 4B");
        assert_eq!(cold.bytes, warm.bytes + 2 * 2 * 4, "cold adds k+v bytes");
    }

    #[test]
    fn injected_loader_failure_disconnects_instead_of_hanging() {
        use crate::faults::FaultPlan;
        let plan = FaultPlan::new(1).with_rate(FaultSite::LoaderFail, 1.0);
        let loader =
            CacheLoader::spawn_with_faults(0.0, Some(Arc::new(FaultInjector::new(plan))));
        let m = MemberGather { store: store(false), step: 0, ids: Arc::new(vec![0]) };
        let rx = loader.submit(0, vec![m], CacheMode::CacheY, 1, false);
        assert!(rx.recv().is_err(), "dead job must disconnect, not hang");
        // the loader thread survives the injected death: the sync path
        // (the worker's fallback) still gathers correctly
        let m = MemberGather { store: store(false), step: 1, ids: Arc::new(vec![3, 1]) };
        let staged = loader.gather_sync(0, vec![m], CacheMode::CacheY, 1);
        assert_eq!(staged.y[0], vec![26.0, 27.0, 22.0, 23.0]);
    }

    #[test]
    fn fifo_order_preserved() {
        let loader = CacheLoader::spawn(0.0);
        let mk = |step| MemberGather { store: store(false), step, ids: Arc::new(vec![0]) };
        let rx0 = loader.submit(0, vec![mk(0)], CacheMode::CacheY, 1, false);
        let rx1 = loader.submit(1, vec![mk(0)], CacheMode::CacheY, 1, false);
        // both complete; block tags intact
        assert_eq!(rx0.recv().unwrap().block, 0);
        assert_eq!(rx1.recv().unwrap().block, 1);
    }

    #[test]
    fn pacing_enforces_bandwidth() {
        // 2 members x 2 rows x 2 floats x 4B = 32B staged... use a tiny
        // bandwidth so the job must take >= 40ms
        let loader = CacheLoader::spawn(32.0 / 0.04);
        let mk = || MemberGather { store: store(false), step: 0, ids: Arc::new(vec![0, 2]) };
        let t0 = Instant::now();
        let rx = loader.submit(0, vec![mk(), mk()], CacheMode::CacheY, 2, false);
        rx.recv().unwrap();
        assert!(t0.elapsed().as_millis() >= 35, "pacing skipped");
    }

    #[test]
    fn padding_slots_do_not_slow_the_copy_stream() {
        // same real payload, 4x the slots: pacing must not change
        let m = || MemberGather { store: store(true), step: 0, ids: Arc::new(vec![0, 2]) };
        let loader = CacheLoader::spawn(0.0);
        let tight = loader.gather_sync(0, vec![m()], CacheMode::CacheKV, 1);
        let padded = loader.gather_sync(0, vec![m()], CacheMode::CacheKV, 4);
        assert_eq!(tight.bytes, padded.bytes);
        let (k, _) = padded.kv_packed.unwrap();
        assert_eq!(k.len(), 4 * 2 * 2, "4 slots x 2 rows x hidden 2");
    }
}
