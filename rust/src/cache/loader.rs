//! Cache-load stream — the simulated DRAM→HBM copy engine (paper §4.2).
//!
//! A dedicated loader thread plays the role of the CUDA copy stream: the
//! worker submits, in pipeline-plan order, one gather job per cached
//! block; the loader gathers each batch member's unmasked rows from its
//! template activations (a real memcpy) and *paces* the job to the
//! configured bandwidth, so the load:compute ratio matches the paper's
//! PCIe regime (DESIGN.md "Substitutions"). The worker blocks on the
//! completion channel when it reaches a cached block whose activations
//! have not landed — that wait is exactly the pipeline bubble the DP of
//! Algorithm 1 squeezes out.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use crate::cache::store::TemplateActivations;
use crate::config::CacheMode;

/// What to stage for one batch member of one block.
#[derive(Clone)]
pub struct MemberGather {
    pub store: Arc<TemplateActivations>,
    /// Denoise step of this member (members batch at different steps
    /// under continuous batching).
    pub step: usize,
    /// Token ids (canonical order) whose cached rows to stage.
    pub ids: Arc<Vec<usize>>,
}

/// Staged activations of one block for the whole batch.
pub struct StagedBlock {
    pub block: usize,
    /// Per member: gathered Y rows `(|ids|, H)`.
    pub y: Vec<Vec<f32>>,
    /// Per member: gathered K/V rows (cache-KV mode only).
    pub kv: Option<Vec<(Vec<f32>, Vec<f32>)>>,
}

struct Job {
    block: usize,
    members: Vec<MemberGather>,
    mode: CacheMode,
    done: Sender<StagedBlock>,
}

/// Handle to the loader thread.
pub struct CacheLoader {
    tx: Option<Sender<Job>>,
    handle: Option<JoinHandle<()>>,
    bandwidth: f64,
}

impl CacheLoader {
    /// Spawn the loader with the given simulated bandwidth (bytes/sec;
    /// `0` disables pacing — the "ideal" ablation of Fig. 4-Left).
    pub fn spawn(bandwidth: f64) -> CacheLoader {
        let (tx, rx): (Sender<Job>, Receiver<Job>) = channel();
        let handle = std::thread::Builder::new()
            .name("cache-loader".into())
            .spawn(move || {
                while let Ok(job) = rx.recv() {
                    let t0 = Instant::now();
                    let staged = gather(job.block, &job.members, job.mode);
                    pace(staged_bytes(&staged), bandwidth, t0);
                    let _ = job.done.send(staged);
                }
            })
            .expect("spawn cache-loader");
        CacheLoader { tx: Some(tx), handle: Some(handle), bandwidth }
    }

    pub fn bandwidth(&self) -> f64 {
        self.bandwidth
    }

    /// Submit a gather job; completion arrives on the returned receiver.
    /// Jobs are processed FIFO — submission order *is* the load-stream
    /// order assumed by the pipeline DP.
    pub fn submit(
        &self,
        block: usize,
        members: Vec<MemberGather>,
        mode: CacheMode,
    ) -> Receiver<StagedBlock> {
        let (done_tx, done_rx) = channel();
        self.tx
            .as_ref()
            .expect("loader alive")
            .send(Job { block, members, mode, done: done_tx })
            .expect("loader thread alive");
        done_rx
    }

    /// Synchronous gather without the loader thread (naive-loading
    /// ablation: the compute stream itself performs the load).
    pub fn gather_sync(
        &self,
        block: usize,
        members: Vec<MemberGather>,
        mode: CacheMode,
    ) -> StagedBlock {
        let t0 = Instant::now();
        let staged = gather(block, &members, mode);
        pace(staged_bytes(&staged), self.bandwidth, t0);
        staged
    }
}

impl Drop for CacheLoader {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn gather(block: usize, members: &[MemberGather], mode: CacheMode) -> StagedBlock {
    let mut y = Vec::with_capacity(members.len());
    let mut kv = matches!(mode, CacheMode::CacheKV).then(Vec::new);
    for m in members {
        let entry = m.store.entry(m.step, block);
        let h = m.store.hidden;
        let mut rows = vec![0f32; m.ids.len() * h];
        gather_rows(&entry.y, h, &m.ids, &mut rows);
        y.push(rows);
        if let Some(kvs) = kv.as_mut() {
            let (ks, vs) = entry
                .kv
                .as_ref()
                .expect("cache-KV mode requires K/V-registered templates");
            let mut kr = vec![0f32; m.ids.len() * h];
            let mut vr = vec![0f32; m.ids.len() * h];
            gather_rows(ks, h, &m.ids, &mut kr);
            gather_rows(vs, h, &m.ids, &mut vr);
            kvs.push((kr, vr));
        }
    }
    StagedBlock { block, y, kv }
}

fn gather_rows(src: &[f32], h: usize, ids: &[usize], out: &mut [f32]) {
    for (i, &id) in ids.iter().enumerate() {
        out[i * h..(i + 1) * h].copy_from_slice(&src[id * h..(id + 1) * h]);
    }
}

fn staged_bytes(s: &StagedBlock) -> usize {
    let y: usize = s.y.iter().map(|v| v.len() * 4).sum();
    let kv: usize = s
        .kv
        .as_ref()
        .map(|kvs| kvs.iter().map(|(k, v)| (k.len() + v.len()) * 4).sum())
        .unwrap_or(0);
    y + kv
}

fn pace(bytes: usize, bandwidth: f64, t0: Instant) {
    if bandwidth <= 0.0 {
        return;
    }
    let want = bytes as f64 / bandwidth;
    let spent = t0.elapsed().as_secs_f64();
    if want > spent {
        std::thread::sleep(std::time::Duration::from_secs_f64(want - spent));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::store::CacheEntry;

    fn store(kv: bool) -> Arc<TemplateActivations> {
        let tokens = 4;
        let hidden = 2;
        let entries = (0..4)
            .map(|i| CacheEntry {
                y: (0..tokens * hidden).map(|j| (i * 10 + j) as f32).collect(),
                kv: kv.then(|| {
                    (
                        vec![(i * 100) as f32; tokens * hidden],
                        vec![(i * 1000) as f32; tokens * hidden],
                    )
                }),
            })
            .collect();
        Arc::new(TemplateActivations::from_parts(
            "t".into(),
            "m".into(),
            2,
            2,
            tokens,
            hidden,
            0,
            entries,
        ))
    }

    #[test]
    fn gathers_requested_rows_in_order() {
        let loader = CacheLoader::spawn(0.0);
        let m = MemberGather { store: store(false), step: 1, ids: Arc::new(vec![3, 1]) };
        let rx = loader.submit(0, vec![m], CacheMode::CacheY);
        let staged = rx.recv().unwrap();
        assert_eq!(staged.block, 0);
        // entry(1, 0) has base 2*10; row 3 = [26, 27], row 1 = [22, 23]
        assert_eq!(staged.y[0], vec![26.0, 27.0, 22.0, 23.0]);
        assert!(staged.kv.is_none());
    }

    #[test]
    fn kv_mode_stages_kv() {
        let loader = CacheLoader::spawn(0.0);
        let m = MemberGather { store: store(true), step: 0, ids: Arc::new(vec![0]) };
        let staged = loader.submit(1, vec![m], CacheMode::CacheKV).recv().unwrap();
        let kv = staged.kv.unwrap();
        assert_eq!(kv[0].0, vec![100.0, 100.0]);
        assert_eq!(kv[0].1, vec![1000.0, 1000.0]);
    }

    #[test]
    fn fifo_order_preserved() {
        let loader = CacheLoader::spawn(0.0);
        let mk = |step| MemberGather { store: store(false), step, ids: Arc::new(vec![0]) };
        let rx0 = loader.submit(0, vec![mk(0)], CacheMode::CacheY);
        let rx1 = loader.submit(1, vec![mk(0)], CacheMode::CacheY);
        // both complete; block tags intact
        assert_eq!(rx0.recv().unwrap().block, 0);
        assert_eq!(rx1.recv().unwrap().block, 1);
    }

    #[test]
    fn pacing_enforces_bandwidth() {
        // 2 members x 2 rows x 2 floats x 4B = 32B staged... use a tiny
        // bandwidth so the job must take >= 40ms
        let loader = CacheLoader::spawn(32.0 / 0.04);
        let mk = || MemberGather { store: store(false), step: 0, ids: Arc::new(vec![0, 2]) };
        let t0 = Instant::now();
        let rx = loader.submit(0, vec![mk(), mk()], CacheMode::CacheY);
        rx.recv().unwrap();
        assert!(t0.elapsed().as_millis() >= 35, "pacing skipped");
    }
}
