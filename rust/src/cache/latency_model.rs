//! Latency regression models — paper §4.4 (Fig. 11) and Table 1.
//!
//! Both the computational load and the cache-loading volume of a block
//! are linear in the masked-token count (Table 1):
//!
//!   feed-forward (XW1)W2 : O(B n L-free H^2)   -> FLOPs linear in n
//!   projection  XW       : O(B n H^2)
//!   attention   QK^T     : O(B n m H)          (m = n in cache-Y mode)
//!   cache shape          : (B, L - n, H)       -> bytes linear in L - n
//!
//! So latency = a * FLOPs + b and load = bytes / bandwidth + c fit with
//! plain least squares (the paper reports R^2 = 0.99). The models are
//! calibrated offline (`instgenie calibrate`) and used by both the
//! worker's pipeline DP (Algo 1) and the cluster scheduler (Algo 2).

use crate::config::{CacheMode, ModelConfig};
use crate::util::stats::LinearFit;

use super::pipeline::BlockCosts;

/// Analytic FLOP count of one transformer block over `n` compute tokens
/// with attention span `m` (Table 1; constants folded, batch excluded).
pub fn block_flops(cfg: &ModelConfig, n: usize, m: usize) -> f64 {
    let h = cfg.hidden as f64;
    let nf = n as f64;
    let mf = m as f64;
    let proj = 4.0 * 2.0 * nf * h * h; // Q,K,V,O projections
    let attn = 2.0 * 2.0 * nf * mf * h; // QK^T and AV
    let ffn = 2.0 * 2.0 * nf * h * (4.0 * h); // (XW1)W2
    proj + attn + ffn
}

/// FLOPs of a cache-mode block at bucket `n` (per batch member).
pub fn block_flops_cached(cfg: &ModelConfig, n: usize, mode: CacheMode) -> f64 {
    match mode {
        CacheMode::CacheY => block_flops(cfg, n, n),
        CacheMode::CacheKV => block_flops(cfg, n, cfg.tokens),
    }
}

/// FLOPs of a full block (all L tokens).
pub fn block_flops_full(cfg: &ModelConfig) -> f64 {
    block_flops(cfg, cfg.tokens, cfg.tokens)
}

/// Bytes of cached activations loaded per block for bucket `n`
/// (per batch member): the (L - n, H) Y rows, or 2x for K/V mode.
pub fn block_cache_bytes(cfg: &ModelConfig, n: usize, mode: CacheMode) -> f64 {
    let rows = (cfg.tokens - n) as f64;
    let base = rows * cfg.hidden as f64 * 4.0;
    match mode {
        CacheMode::CacheY => base,
        CacheMode::CacheKV => 2.0 * base,
    }
}

/// Bytes the host copy stream (loader) actually gathers + paces per
/// block for bucket `n` (per batch member). Cache-Y stages the Y rows;
/// cold cache-KV additionally stages packed K and V; a device-KV-tier
/// hit (`kv_warm`) skips the K/V gather entirely, leaving only Y.
pub fn block_stage_bytes(cfg: &ModelConfig, n: usize, mode: CacheMode, kv_warm: bool) -> f64 {
    let rows = (cfg.tokens - n) as f64;
    let base = rows * cfg.hidden as f64 * 4.0;
    match mode {
        CacheMode::CacheY => base,
        CacheMode::CacheKV if kv_warm => base,
        CacheMode::CacheKV => 3.0 * base,
    }
}

/// Bytes crossing host→device on the second copy stream per block for
/// bucket `n` (per batch member): the packed K and V. Zero in cache-Y
/// mode (rows are consumed host-side) and zero on a device-tier hit.
pub fn block_upload_bytes(cfg: &ModelConfig, n: usize, mode: CacheMode, kv_warm: bool) -> f64 {
    match mode {
        CacheMode::CacheY => 0.0,
        CacheMode::CacheKV if kv_warm => 0.0,
        CacheMode::CacheKV => 2.0 * (cfg.tokens - n) as f64 * cfg.hidden as f64 * 4.0,
    }
}

/// Nominal H2D bandwidth for the upload fit when no calibration exists:
/// a pinned-memory PCIe-class copy, far faster than the simulated
/// DRAM→HBM gather stream.
pub const NOMINAL_UPLOAD_BYTES_PER_SEC: f64 = 16.0 * 1024.0 * 1024.0 * 1024.0;

/// Calibrated latency model for one (model, worker) pair.
#[derive(Debug, Clone)]
pub struct LatencyModel {
    /// seconds = comp.slope * FLOPs + comp.intercept
    pub comp: LinearFit,
    /// seconds = load.slope * bytes + load.intercept (host gather stream)
    pub load: LinearFit,
    /// seconds = upload.slope * bytes + upload.intercept (H2D copy stream)
    pub upload: LinearFit,
}

impl LatencyModel {
    /// Fit from calibration samples: (flops, seconds) and (bytes, seconds).
    /// Intercepts are floored at zero (dispatch overhead is real and
    /// positive; a negative intercept would make the pipeline DP believe
    /// small blocks are free).
    pub fn fit(comp_samples: &[(f64, f64)], load_samples: &[(f64, f64)]) -> LatencyModel {
        use crate::util::stats::linear_fit_nonneg;
        let (cx, cy): (Vec<f64>, Vec<f64>) = comp_samples.iter().copied().unzip();
        let (lx, ly): (Vec<f64>, Vec<f64>) = load_samples.iter().copied().unzip();
        LatencyModel {
            comp: linear_fit_nonneg(&cx, &cy),
            load: linear_fit_nonneg(&lx, &ly),
            upload: nominal_upload_fit(),
        }
    }

    /// Synthetic model from nominal throughput numbers (tests / sims):
    /// `flops_per_sec` compute rate, `bytes_per_sec` copy bandwidth.
    pub fn nominal(flops_per_sec: f64, bytes_per_sec: f64) -> LatencyModel {
        LatencyModel {
            comp: LinearFit { slope: 1.0 / flops_per_sec, intercept: 0.0, r2: 1.0 },
            load: LinearFit { slope: 1.0 / bytes_per_sec, intercept: 0.0, r2: 1.0 },
            upload: nominal_upload_fit(),
        }
    }

    pub fn comp_seconds(&self, flops: f64) -> f64 {
        self.comp.predict(flops).max(0.0)
    }

    pub fn load_seconds(&self, bytes: f64) -> f64 {
        self.load.predict(bytes).max(0.0)
    }

    pub fn upload_seconds(&self, bytes: f64) -> f64 {
        self.upload.predict(bytes).max(0.0)
    }

    /// Per-block DP costs for a batch whose members use bucket `n`.
    ///
    /// `batch_members` scales compute FLOPs and both copy streams —
    /// each member loads its own activation rows (heterogeneous
    /// templates). `kv_warm` marks the block resident in the device KV
    /// tier: the K/V gather is skipped and the H2D upload collapses to
    /// zero.
    pub fn block_costs(
        &self,
        cfg: &ModelConfig,
        n: usize,
        batch_members: usize,
        mode: CacheMode,
        kv_warm: bool,
    ) -> BlockCosts {
        let b = batch_members.max(1) as f64;
        BlockCosts {
            c_cached: self.comp_seconds(b * block_flops_cached(cfg, n, mode)),
            c_full: self.comp_seconds(b * block_flops_full(cfg)),
            load: self.load_seconds(b * block_stage_bytes(cfg, n, mode, kv_warm)),
            upload: self.upload_seconds(b * block_upload_bytes(cfg, n, mode, kv_warm)),
        }
    }

    /// Step costs for the whole model (uniform blocks), device KV tier
    /// cold — what the scheduler's Algorithm-2 estimator assumes for a
    /// worker it has no warmth information about.
    pub fn step_costs(
        &self,
        cfg: &ModelConfig,
        n: usize,
        batch_members: usize,
        mode: CacheMode,
    ) -> Vec<BlockCosts> {
        self.step_costs_with(cfg, n, batch_members, mode, 0)
    }

    /// Step costs with per-block device-KV-tier warmth (`warm_mask` bit
    /// i set — block i's staged K/V is already device-resident).
    pub fn step_costs_with(
        &self,
        cfg: &ModelConfig,
        n: usize,
        batch_members: usize,
        mode: CacheMode,
        warm_mask: u64,
    ) -> Vec<BlockCosts> {
        (0..cfg.blocks)
            .map(|i| {
                let warm = i < 64 && warm_mask & (1u64 << i) != 0;
                self.block_costs(cfg, n, batch_members, mode, warm)
            })
            .collect()
    }
}

fn nominal_upload_fit() -> LinearFit {
    LinearFit { slope: 1.0 / NOMINAL_UPLOAD_BYTES_PER_SEC, intercept: 0.0, r2: 1.0 }
}

impl LatencyModel {
    /// JSON persistence (written by `instgenie calibrate`, consumed by the
    /// scheduler and the workers' pipeline DP).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let fit = |f: &LinearFit| {
            Json::obj(vec![
                ("slope", Json::num(f.slope)),
                ("intercept", Json::num(f.intercept)),
                ("r2", Json::num(f.r2)),
            ])
        };
        Json::obj(vec![
            ("comp", fit(&self.comp)),
            ("load", fit(&self.load)),
            ("upload", fit(&self.upload)),
        ])
    }

    pub fn from_json(j: &crate::util::json::Json) -> Option<LatencyModel> {
        let fit = |j: &crate::util::json::Json| {
            Some(LinearFit {
                slope: j.at("slope").as_f64()?,
                intercept: j.at("intercept").as_f64()?,
                r2: j.at("r2").as_f64().unwrap_or(0.0),
            })
        };
        Some(LatencyModel {
            comp: fit(j.at("comp"))?,
            load: fit(j.at("load"))?,
            // older persisted models predate the upload stage
            upload: fit(j.at("upload")).unwrap_or_else(nominal_upload_fit),
        })
    }

    /// Load a calibrated model from `<dir>/latency_model_<model>.json`,
    /// falling back to nominal rates when absent (tests, cold checkouts).
    pub fn load_or_nominal(dir: &str, model: &str) -> LatencyModel {
        let path = std::path::Path::new(dir).join(format!("latency_model_{model}.json"));
        std::fs::read_to_string(&path)
            .ok()
            .and_then(|t| crate::util::json::Json::parse(&t).ok())
            .and_then(|j| LatencyModel::from_json(&j))
            .unwrap_or_else(|| LatencyModel::nominal(2e9, 192.0 * 1024.0 * 1024.0))
    }

    pub fn save(&self, dir: &str, model: &str) -> std::io::Result<()> {
        let path = std::path::Path::new(dir).join(format!("latency_model_{model}.json"));
        std::fs::write(path, self.to_json().to_string())
    }
}

/// Offline calibration (paper §4.4 "fitted with the offline data"):
/// measure block latencies across the (token-bucket, batch-bucket) grid
/// and loader throughput across transfer sizes, then least-squares fit.
/// Returns (model, comp samples, load samples) so callers can print the
/// Fig.-11 style table.
pub fn calibrate(
    rt: &crate::runtime::ModelRuntime,
    sim_bandwidth: f64,
    reps: usize,
) -> anyhow::Result<(LatencyModel, Vec<(f64, f64)>, Vec<(f64, f64)>)> {
    use crate::model::Latent;
    let cfg = rt.config.clone();
    let mut comp = Vec::new();
    for &b in &[1usize, 2, 4, 8] {
        for n in cfg.all_token_counts() {
            let x = Latent::noise(b * n, cfg.hidden, 7, 1.0);
            // warmup (compile + caches)
            rt.run_block_y(0, n, b, x.data())?;
            let t0 = std::time::Instant::now();
            for _ in 0..reps {
                rt.run_block_y(0, n, b, x.data())?;
            }
            let secs = t0.elapsed().as_secs_f64() / reps as f64;
            comp.push((b as f64 * block_flops(&cfg, n, n), secs));
        }
    }
    // loader: pacing dominates, so the fit recovers 1/sim_bandwidth
    let mut load = Vec::new();
    for &rows in &[cfg.tokens / 8, cfg.tokens / 4, cfg.tokens / 2, cfg.tokens] {
        let bytes = (rows * cfg.hidden * 4) as f64;
        load.push((bytes, bytes / sim_bandwidth.max(1.0)));
    }
    let model = LatencyModel::fit(&comp, &load);
    Ok((model, comp, load))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            latent_hw: 8,
            tokens: 64,
            hidden: 64,
            heads: 4,
            blocks: 4,
            steps: 8,
            token_buckets: vec![4, 8, 16, 32],
            paper_analogue: String::new(),
        }
    }

    #[test]
    fn flops_linear_in_n_cache_y() {
        // Table 1: cached FLOPs at mask ratio m are ~m * full FLOPs
        let c = cfg();
        let full = block_flops_full(&c);
        let quarter = block_flops_cached(&c, 16, CacheMode::CacheY);
        let ratio = quarter / full;
        // attention term is quadratic in n, so ratio < n/L for cache-Y
        assert!(ratio < 0.25 + 1e-9, "ratio {ratio}");
        assert!(ratio > 0.15, "ratio {ratio}");
    }

    #[test]
    fn kv_mode_costs_more_flops_and_bytes_than_y() {
        let c = cfg();
        let n = 16;
        assert!(
            block_flops_cached(&c, n, CacheMode::CacheKV)
                > block_flops_cached(&c, n, CacheMode::CacheY)
        );
        assert!(
            (block_cache_bytes(&c, n, CacheMode::CacheKV)
                - 2.0 * block_cache_bytes(&c, n, CacheMode::CacheY))
            .abs()
                < 1e-9
        );
    }

    #[test]
    fn cache_bytes_match_table1_shape() {
        // Table 1: cache shape (B, (1-m)L, H) -> bytes = (L-n) * H * 4
        let c = cfg();
        assert_eq!(block_cache_bytes(&c, 16, CacheMode::CacheY), (64.0 - 16.0) * 64.0 * 4.0);
        assert_eq!(block_cache_bytes(&c, 64, CacheMode::CacheY), 0.0);
    }

    #[test]
    fn nominal_model_round_numbers() {
        let m = LatencyModel::nominal(1e9, 1e8);
        assert!((m.comp_seconds(1e9) - 1.0).abs() < 1e-12);
        assert!((m.load_seconds(1e8) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn fit_recovers_synthetic_rates() {
        let comp: Vec<(f64, f64)> = (1..10).map(|i| (i as f64 * 1e6, i as f64 * 1e-3 + 5e-4)).collect();
        let load: Vec<(f64, f64)> = (1..10).map(|i| (i as f64 * 1e5, i as f64 * 2e-3)).collect();
        let m = LatencyModel::fit(&comp, &load);
        assert!(m.comp.r2 > 0.999, "comp r2 {}", m.comp.r2);
        assert!(m.load.r2 > 0.999);
        assert!((m.comp_seconds(5e6) - 5.5e-3).abs() < 1e-6);
    }

    #[test]
    fn block_costs_scale_with_batch() {
        let c = cfg();
        let m = LatencyModel::nominal(1e9, 1e8);
        let b1 = m.block_costs(&c, 16, 1, CacheMode::CacheY, false);
        let b4 = m.block_costs(&c, 16, 4, CacheMode::CacheY, false);
        assert!((b4.c_cached - 4.0 * b1.c_cached).abs() < 1e-12);
        assert!((b4.load - 4.0 * b1.load).abs() < 1e-12);
    }

    #[test]
    fn warm_kv_collapses_upload_and_kv_stage_bytes() {
        let c = cfg();
        let m = LatencyModel::nominal(1e9, 1e8);
        let cold = m.block_costs(&c, 16, 1, CacheMode::CacheKV, false);
        let warm = m.block_costs(&c, 16, 1, CacheMode::CacheKV, true);
        assert!(cold.upload > 0.0, "cold KV pays the H2D stage");
        assert_eq!(warm.upload, 0.0, "device-tier hit uploads nothing");
        assert!(warm.load < cold.load, "tier hit skips the K/V gather");
        // warm stage bytes = Y only, same as cache-Y
        assert_eq!(
            block_stage_bytes(&c, 16, CacheMode::CacheKV, true),
            block_stage_bytes(&c, 16, CacheMode::CacheY, false)
        );
        // cache-Y never uploads
        assert_eq!(block_upload_bytes(&c, 16, CacheMode::CacheY, false), 0.0);
    }

    #[test]
    fn step_costs_with_applies_warm_mask_per_block() {
        let c = cfg();
        let m = LatencyModel::nominal(1e9, 1e8);
        let costs = m.step_costs_with(&c, 16, 1, CacheMode::CacheKV, 0b0101);
        assert_eq!(costs.len(), c.blocks);
        assert_eq!(costs[0].upload, 0.0);
        assert!(costs[1].upload > 0.0);
        assert_eq!(costs[2].upload, 0.0);
        assert!(costs[3].upload > 0.0);
    }

    #[test]
    fn json_round_trip_keeps_upload_fit() {
        let m = LatencyModel::nominal(1e9, 1e8);
        let j = m.to_json();
        let back = LatencyModel::from_json(&j).unwrap();
        assert!((back.upload.slope - m.upload.slope).abs() < 1e-18);
        // pre-upload-stage persisted models fall back to the nominal fit
        let legacy = crate::util::json::Json::parse(
            "{\"comp\":{\"slope\":1e-9,\"intercept\":0,\"r2\":1},\
             \"load\":{\"slope\":1e-8,\"intercept\":0,\"r2\":1}}",
        )
        .unwrap();
        let back = LatencyModel::from_json(&legacy).unwrap();
        assert!((back.upload.slope - 1.0 / NOMINAL_UPLOAD_BYTES_PER_SEC).abs() < 1e-18);
    }
}
