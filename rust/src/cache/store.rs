//! Activation store — the cached intermediate activations of registered
//! image templates (paper §3.1/§4.2).
//!
//! Registering a template runs the **full** model once (the registration
//! block taps Y and the K/V projections) and records, for every
//! (denoise step, block), the `(L, H)` activations in canonical token
//! order. A later edit request gathers the rows of *its* unmasked suffix
//! from these tensors — any mask shape can reuse the same template cache,
//! which is what makes the 35 000-fold template reuse of the production
//! trace (§2.2) pay off.

use std::sync::Arc;

use anyhow::Result;

use crate::config::CacheMode;
use crate::model::Latent;
use crate::runtime::ModelRuntime;
use crate::util::rng::{hash_str, splitmix64};

/// Cached activations of one (step, block).
#[derive(Debug, Clone)]
pub struct CacheEntry {
    /// Block output Y, (L, H) flattened — cache-Y mode (Fig. 5-Bottom).
    pub y: Vec<f32>,
    /// K/V projections, (L, H) each — cache-KV mode (Fig. 7). `None` when
    /// the store was registered Y-only (half the memory, per the paper's
    /// note that K/V caching doubles the cache size).
    pub kv: Option<(Vec<f32>, Vec<f32>)>,
}

/// All cached activations of one template on one model.
#[derive(Debug)]
pub struct TemplateActivations {
    pub template_id: String,
    pub model: String,
    pub steps: usize,
    pub blocks: usize,
    pub tokens: usize,
    pub hidden: usize,
    /// Noise seed of the template trajectory (requests start from the
    /// same x_T so their unmasked rows follow the template exactly).
    pub seed: u64,
    /// entries[step * blocks + block]
    entries: Vec<CacheEntry>,
}

impl TemplateActivations {
    pub fn entry(&self, step: usize, block: usize) -> &CacheEntry {
        &self.entries[step * self.blocks + block]
    }

    /// Template eps at `step` = final block's Y (the model predicts eps as
    /// its final hidden state); unmasked latent rows advance with this.
    pub fn eps(&self, step: usize) -> &[f32] {
        &self.entry(step, self.blocks - 1).y
    }

    /// Total cache footprint in bytes.
    pub fn size_bytes(&self) -> usize {
        self.entries
            .iter()
            .map(|e| {
                4 * (e.y.len() + e.kv.as_ref().map(|(k, v)| k.len() + v.len()).unwrap_or(0))
            })
            .sum()
    }

    /// Deterministic noise seed for a template id.
    pub fn seed_for(template_id: &str) -> u64 {
        hash_str(template_id)
    }

    /// Order-sensitive content checksum over the template id, shape,
    /// seed, and every activation byte (FNV-1a folded through
    /// splitmix64). Embedded in disk-tier spill artifacts so bit rot is
    /// detected on promotion and demoted to a recompute instead of
    /// silently denoising with garbage. The `model` field is excluded:
    /// spills do not persist it, and the checksum must verify on the
    /// deserialized copy.
    pub fn content_checksum(&self) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64; // FNV-1a offset basis
        h = fnv_extend(h, self.template_id.as_bytes());
        for d in [
            self.steps as u64,
            self.blocks as u64,
            self.tokens as u64,
            self.hidden as u64,
            self.seed,
        ] {
            h = fnv_extend(h, &d.to_le_bytes());
        }
        for e in &self.entries {
            for x in &e.y {
                h = fnv_extend(h, &x.to_le_bytes());
            }
            if let Some((k, v)) = &e.kv {
                for x in k.iter().chain(v.iter()) {
                    h = fnv_extend(h, &x.to_le_bytes());
                }
            }
        }
        splitmix64(h)
    }

    /// Rebuild the template's initial latent x_T.
    pub fn initial_latent(&self) -> Latent {
        Latent::noise(self.tokens, self.hidden, self.seed, 1.0)
    }

    /// Construct from raw parts (disk-tier deserialization).
    pub fn from_parts(
        template_id: String,
        model: String,
        steps: usize,
        blocks: usize,
        tokens: usize,
        hidden: usize,
        seed: u64,
        entries: Vec<CacheEntry>,
    ) -> TemplateActivations {
        assert_eq!(entries.len(), steps * blocks);
        TemplateActivations {
            template_id,
            model,
            steps,
            blocks,
            tokens,
            hidden,
            seed,
            entries,
        }
    }

    pub fn entries(&self) -> &[CacheEntry] {
        &self.entries
    }
}

fn fnv_extend(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Run the full model on a template and capture its activations.
///
/// `mode` controls whether K/V taps are stored alongside Y (doubling the
/// cache, Fig. 7). Returns the populated store plus the final denoised
/// template latent (useful for quality baselines).
pub fn register_template(
    rt: &ModelRuntime,
    template_id: &str,
    mode: CacheMode,
) -> Result<(Arc<TemplateActivations>, Latent)> {
    let cfg = &rt.config;
    let seed = TemplateActivations::seed_for(template_id);
    let mut x = Latent::noise(cfg.tokens, cfg.hidden, seed, 1.0);
    let sched = rt.schedule().clone();
    let all_ids: Vec<usize> = (0..cfg.tokens).collect();
    let mut entries = Vec::with_capacity(cfg.steps * cfg.blocks);

    for t in 0..cfg.steps {
        // h = x + temb[t] (template conditioning is zero; DESIGN.md)
        let temb = rt.weights().temb_row(t).to_vec();
        let mut h = x.data().to_vec();
        for (i, v) in h.iter_mut().enumerate() {
            *v += temb[i % cfg.hidden];
        }
        for b in 0..cfg.blocks {
            let (y, k, v) = rt.run_block_reg(b, &h)?;
            entries.push(CacheEntry {
                y: y.clone(),
                kv: match mode {
                    CacheMode::CacheY => None,
                    CacheMode::CacheKV => Some((k, v)),
                },
            });
            h = y;
        }
        // eps = final hidden; advance all rows
        sched.update_rows(t, x.data_mut(), cfg.hidden, &all_ids, &h);
    }

    let store = TemplateActivations::from_parts(
        template_id.to_string(),
        cfg.name.clone(),
        cfg.steps,
        cfg.blocks,
        cfg.tokens,
        cfg.hidden,
        seed,
        entries,
    );
    Ok((Arc::new(store), x))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dummy(steps: usize, blocks: usize) -> TemplateActivations {
        let tokens = 4;
        let hidden = 2;
        let entries = (0..steps * blocks)
            .map(|i| CacheEntry { y: vec![i as f32; tokens * hidden], kv: None })
            .collect();
        TemplateActivations::from_parts(
            "t".into(),
            "m".into(),
            steps,
            blocks,
            tokens,
            hidden,
            7,
            entries,
        )
    }

    #[test]
    fn entry_indexing() {
        let s = dummy(3, 2);
        assert_eq!(s.entry(0, 0).y[0], 0.0);
        assert_eq!(s.entry(0, 1).y[0], 1.0);
        assert_eq!(s.entry(1, 0).y[0], 2.0);
        assert_eq!(s.entry(2, 1).y[0], 5.0);
        // eps(t) is the final block's Y
        assert_eq!(s.eps(1)[0], 3.0);
    }

    #[test]
    fn size_accounts_kv() {
        let mut s = dummy(1, 1);
        assert_eq!(s.size_bytes(), 4 * 8);
        s.entries[0].kv = Some((vec![0.0; 8], vec![0.0; 8]));
        assert_eq!(s.size_bytes(), 4 * 24);
    }

    #[test]
    fn checksum_is_stable_and_content_sensitive() {
        let a = dummy(2, 2);
        let b = dummy(2, 2);
        assert_eq!(a.content_checksum(), b.content_checksum());
        let mut c = dummy(2, 2);
        c.entries[3].y[5] += 1.0;
        assert_ne!(a.content_checksum(), c.content_checksum());
        let mut d = dummy(2, 2);
        d.template_id = "other".into();
        assert_ne!(a.content_checksum(), d.content_checksum());
        // model is excluded: spills don't persist it
        let mut e = dummy(2, 2);
        e.model = String::new();
        assert_eq!(a.content_checksum(), e.content_checksum());
    }

    #[test]
    fn seed_is_stable_per_template() {
        assert_eq!(
            TemplateActivations::seed_for("tpl-1"),
            TemplateActivations::seed_for("tpl-1")
        );
        assert_ne!(
            TemplateActivations::seed_for("tpl-1"),
            TemplateActivations::seed_for("tpl-2")
        );
    }
}
