//! Bubble-free pipeline planning — paper §4.2, Algorithm 1.
//!
//! Per denoise step, the worker must decide for each transformer block
//! whether to run it *cached* (compute only the bucket's n tokens, but
//! wait for that block's activations to arrive) or *full* (compute all
//! L tokens, no load). Cached activations traverse up to three
//! sequential stages, each its own stream:
//!
//!   1. host gather   — the loader thread gathers/stages the rows
//!                      (the "copy stream" of the original two-stage DP);
//!   2. H2D upload    — the staged K/V crosses host→device on the second
//!                      copy stream (zero when the block is already
//!                      resident in the device KV tier, and zero in
//!                      cache-Y mode where rows are consumed host-side);
//!   3. compute       — the block program runs.
//!
//! Each stream is sequential (one copy engine each), so a cached block's
//! gather can only start after the previous cached block's gather, its
//! upload after both its own gather and the previous upload, and its
//! compute after both its upload and the previous block's compute:
//!
//!   load_end(i)   = load_end(prev cached)   + load(i)
//!   upload_end(i) = max(upload_end(prev cached), load_end(i)) + upload(i)
//!   comp_start(i) = max(comp_end(i-1), upload_end(i) if cached else 0)
//!   comp_end(i)   = comp_start(i) + (c_cached(i) | c_full(i))
//!
//! The paper solves the two-stage version with an O(N) DP; we implement
//! an exact DP over the Pareto frontier of (comp_end, load_end,
//! upload_end) states — the frontier stays tiny (usually 2-4 states), so
//! the cost is negligible versus a denoise step, matching the paper's
//! observation.

/// Per-block latency inputs for the DP.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BlockCosts {
    /// Compute latency with cached activations (bucket-n tokens only).
    pub c_cached: f64,
    /// Compute latency without cache (all L tokens).
    pub c_full: f64,
    /// Latency of gathering/staging this block's cached activations on
    /// the host copy stream.
    pub load: f64,
    /// Latency of the host→device upload of this block's staged K/V on
    /// the second copy stream. Zero in cache-Y mode (rows are consumed
    /// host-side) and zero on a device-KV-tier hit (already resident).
    pub upload: f64,
}

/// The plan for one denoise step.
#[derive(Debug, Clone, PartialEq)]
pub struct PipelinePlan {
    /// `use_cache[i]` — run block i in cached mode.
    pub use_cache: Vec<bool>,
    /// Predicted makespan of the step under the plan.
    pub latency: f64,
}

#[derive(Clone)]
struct State {
    comp_end: f64,
    load_end: f64,
    upload_end: f64,
    decisions: u64, // bitmask, block i -> bit i (N <= 64 blocks)
}

/// Algorithm 1: choose per-block cache usage minimizing step latency.
pub fn plan(costs: &[BlockCosts]) -> PipelinePlan {
    assert!(costs.len() <= 64, "bitmask supports <= 64 blocks");
    let mut frontier =
        vec![State { comp_end: 0.0, load_end: 0.0, upload_end: 0.0, decisions: 0 }];
    for (i, c) in costs.iter().enumerate() {
        let mut next: Vec<State> = Vec::with_capacity(frontier.len() * 2);
        for s in &frontier {
            // decision: full recompute (no load, no upload)
            next.push(State {
                comp_end: s.comp_end + c.c_full,
                load_end: s.load_end,
                upload_end: s.upload_end,
                decisions: s.decisions,
            });
            // decision: cached (sequential gather then upload streams)
            let load_end = s.load_end + c.load;
            let upload_end = s.upload_end.max(load_end) + c.upload;
            next.push(State {
                comp_end: upload_end.max(s.comp_end) + c.c_cached,
                load_end,
                upload_end,
                decisions: s.decisions | (1 << i),
            });
        }
        frontier = pareto_prune(next);
    }
    let best = frontier
        .iter()
        .min_by(|a, b| a.comp_end.partial_cmp(&b.comp_end).unwrap())
        .expect("non-empty frontier");
    PipelinePlan {
        use_cache: (0..costs.len()).map(|i| best.decisions & (1 << i) != 0).collect(),
        latency: best.comp_end,
    }
}

fn dominates(a: &State, b: &State) -> bool {
    a.comp_end <= b.comp_end + 1e-15
        && a.load_end <= b.load_end + 1e-15
        && a.upload_end <= b.upload_end + 1e-15
}

fn pareto_prune(mut states: Vec<State>) -> Vec<State> {
    // Sort by comp_end so earlier states can only dominate later ones,
    // then keep each state unless an already-kept state dominates it in
    // all three stage clocks. The frontier stays tiny, so the quadratic
    // scan is cheaper than anything fancier.
    states.sort_by(|a, b| {
        a.comp_end
            .partial_cmp(&b.comp_end)
            .unwrap()
            .then(a.load_end.partial_cmp(&b.load_end).unwrap())
            .then(a.upload_end.partial_cmp(&b.upload_end).unwrap())
    });
    let mut kept: Vec<State> = Vec::with_capacity(states.len());
    for s in states {
        if !kept.iter().any(|k| dominates(k, &s)) {
            kept.push(s);
        }
    }
    kept
}

/// Memoized Algorithm-1 plans. `BlockCosts` are a pure function of
/// (token bucket, batch size, cache mode, device-tier warmth) for a
/// fixed latency model, so the DP result is reusable across every step
/// of every batch with that shape — the seed re-ran the DP each step of
/// each batch. `warm_mask` carries per-block device-KV-tier residency
/// (bit i set — block i's upload collapses to 0), so plans adapt to
/// warmth without recomputing for the two common cases (fully cold,
/// fully warm). Plans are `Arc`-shared so a cache hit is two hash
/// probes and a refcount bump.
#[derive(Default)]
pub struct PlanCache {
    entries: std::collections::HashMap<(usize, usize, u8, u64), std::sync::Arc<PipelinePlan>>,
    hits: u64,
    misses: u64,
}

impl PlanCache {
    pub fn new() -> PlanCache {
        PlanCache::default()
    }

    /// Plan for `(n, b, mode_tag, warm_mask)`, computing block costs +
    /// DP only on the first request for that shape.
    pub fn plan_for(
        &mut self,
        n: usize,
        b: usize,
        mode_tag: u8,
        warm_mask: u64,
        costs: impl FnOnce() -> Vec<BlockCosts>,
    ) -> std::sync::Arc<PipelinePlan> {
        if let Some(p) = self.entries.get(&(n, b, mode_tag, warm_mask)) {
            self.hits += 1;
            return std::sync::Arc::clone(p);
        }
        self.misses += 1;
        let p = std::sync::Arc::new(plan(&costs()));
        self.entries.insert((n, b, mode_tag, warm_mask), std::sync::Arc::clone(&p));
        p
    }

    /// (hits, misses) — observability for the overhead bench.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits, self.misses)
    }
}

/// Fig. 9-Top: naive loading — stage, upload, then compute (no overlap).
pub fn naive_latency(costs: &[BlockCosts]) -> f64 {
    let load: f64 = costs.iter().map(|c| c.load + c.upload).sum();
    let comp: f64 = costs.iter().map(|c| c.c_cached).sum();
    load + comp
}

/// Fig. 9-Middle: strawman pipeline — every block cached, stages
/// overlapped but bubbles remain when the load streams outrun compute.
pub fn strawman_latency(costs: &[BlockCosts]) -> f64 {
    let mut comp_end = 0.0f64;
    let mut load_end = 0.0f64;
    let mut upload_end = 0.0f64;
    for c in costs {
        load_end += c.load;
        upload_end = upload_end.max(load_end) + c.upload;
        comp_end = upload_end.max(comp_end) + c.c_cached;
    }
    comp_end
}

/// Ideal lower bound: cache loading is free (paper Fig. 4-Left "ideal").
pub fn ideal_latency(costs: &[BlockCosts]) -> f64 {
    costs.iter().map(|c| c.c_cached).sum()
}

/// Full recompute (mask-agnostic baseline): no cache at all.
pub fn full_latency(costs: &[BlockCosts]) -> f64 {
    costs.iter().map(|c| c.c_full).sum()
}

/// Brute-force reference for tests (exponential; N <= ~16).
#[doc(hidden)]
pub fn plan_bruteforce(costs: &[BlockCosts]) -> PipelinePlan {
    let n = costs.len();
    assert!(n <= 16);
    let mut best_mask = 0u64;
    let mut best = f64::INFINITY;
    for mask in 0..(1u64 << n) {
        let mut comp_end = 0.0;
        let mut load_end = 0.0;
        let mut upload_end = 0.0f64;
        for (i, c) in costs.iter().enumerate() {
            if mask & (1 << i) != 0 {
                load_end += c.load;
                upload_end = upload_end.max(load_end) + c.upload;
                comp_end = upload_end.max(comp_end) + c.c_cached;
            } else {
                comp_end += c.c_full;
            }
        }
        if comp_end < best {
            best = comp_end;
            best_mask = mask;
        }
    }
    PipelinePlan {
        use_cache: (0..n).map(|i| best_mask & (1 << i) != 0).collect(),
        latency: best,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::prop_check;
    use crate::util::rng::Pcg;

    fn uniform(n: usize, c_cached: f64, c_full: f64, load: f64) -> Vec<BlockCosts> {
        vec![BlockCosts { c_cached, c_full, load, upload: 0.0 }; n]
    }

    fn uniform_up(n: usize, c_cached: f64, c_full: f64, load: f64, upload: f64) -> Vec<BlockCosts> {
        vec![BlockCosts { c_cached, c_full, load, upload }; n]
    }

    #[test]
    fn all_cached_when_loads_are_cheap() {
        // load << cached compute: pipeline hides everything after block 0
        let plan = plan(&uniform(8, 10.0, 40.0, 1.0));
        assert!(plan.use_cache.iter().all(|&u| u));
        // bubble only before block 0
        assert!((plan.latency - (1.0 + 8.0 * 10.0)).abs() < 1e-9);
    }

    #[test]
    fn all_full_when_cache_gains_nothing() {
        // cached compute ~ full compute but loads are huge
        let plan = plan(&uniform(6, 9.0, 10.0, 100.0));
        assert!(plan.use_cache.iter().all(|&u| !u));
        assert!((plan.latency - 60.0).abs() < 1e-9);
    }

    #[test]
    fn mixes_to_fill_bubbles() {
        // load == 2x cached compute: running everything cached leaves
        // bubbles; the optimum interleaves full blocks to absorb loads
        // (paper Fig. 9-Bottom).
        let costs = uniform(8, 5.0, 12.0, 10.0);
        let p = plan(&costs);
        let s = strawman_latency(&costs);
        assert!(p.latency < s, "DP {} vs strawman {}", p.latency, s);
        assert!(p.use_cache.iter().any(|&u| u), "should still use some cache");
        assert!(p.use_cache.iter().any(|&u| !u), "should recompute some blocks");
    }

    #[test]
    fn ordering_naive_ge_strawman_ge_dp_ge_ideal() {
        let costs = uniform_up(10, 4.0, 11.0, 4.0, 2.0);
        let n = naive_latency(&costs);
        let s = strawman_latency(&costs);
        let d = plan(&costs).latency;
        let i = ideal_latency(&costs);
        assert!(n >= s && s >= d && d >= i, "{n} {s} {d} {i}");
    }

    #[test]
    fn upload_stage_shifts_plan_toward_full() {
        // With a cold device tier the upload stream is the bottleneck;
        // when it collapses to 0 (warm tier) the same blocks flip back
        // to cached — the DP must see the difference.
        let cold = uniform_up(8, 5.0, 11.0, 3.0, 7.0);
        let warm = uniform_up(8, 5.0, 11.0, 3.0, 0.0);
        let pc = plan(&cold);
        let pw = plan(&warm);
        assert!(pw.latency <= pc.latency, "warm {} vs cold {}", pw.latency, pc.latency);
        assert!(pw.use_cache.iter().all(|&u| u), "warm tier: everything cached");
        let cached_cold = pc.use_cache.iter().filter(|&&u| u).count();
        let cached_warm = pw.use_cache.iter().filter(|&&u| u).count();
        assert!(cached_warm >= cached_cold, "warmth never reduces caching");
    }

    #[test]
    fn matches_bruteforce_property() {
        prop_check("pareto DP == brute force", 300, |rng: &mut Pcg| {
            let n = 1 + rng.below(10);
            let costs: Vec<BlockCosts> = (0..n)
                .map(|_| BlockCosts {
                    c_cached: rng.range_f64(0.5, 5.0),
                    c_full: rng.range_f64(1.0, 20.0),
                    load: rng.range_f64(0.0, 15.0),
                    upload: rng.range_f64(0.0, 8.0),
                })
                .collect();
            let dp = plan(&costs);
            let bf = plan_bruteforce(&costs);
            prop_assert!(
                (dp.latency - bf.latency).abs() < 1e-9,
                "dp {} != bf {} for {:?}",
                dp.latency,
                bf.latency,
                costs
            );
            Ok(())
        });
    }

    #[test]
    fn plan_latency_is_consistent_with_replay() {
        // replaying the chosen decisions through the timing model gives
        // exactly the reported latency
        prop_check("plan replay consistency", 200, |rng: &mut Pcg| {
            let n = 1 + rng.below(12);
            let costs: Vec<BlockCosts> = (0..n)
                .map(|_| BlockCosts {
                    c_cached: rng.range_f64(0.1, 5.0),
                    c_full: rng.range_f64(0.1, 20.0),
                    load: rng.range_f64(0.0, 10.0),
                    upload: rng.range_f64(0.0, 6.0),
                })
                .collect();
            let p = plan(&costs);
            let mut comp_end = 0.0;
            let mut load_end = 0.0;
            let mut upload_end = 0.0f64;
            for (i, c) in costs.iter().enumerate() {
                if p.use_cache[i] {
                    load_end += c.load;
                    upload_end = upload_end.max(load_end) + c.upload;
                    comp_end = upload_end.max(comp_end) + c.c_cached;
                } else {
                    comp_end += c.c_full;
                }
            }
            prop_assert!(
                (comp_end - p.latency).abs() < 1e-9,
                "replay {comp_end} vs plan {}",
                p.latency
            );
            Ok(())
        });
    }

    #[test]
    fn plan_cache_memoizes_per_shape() {
        let mut cache = PlanCache::new();
        let costs = uniform(6, 4.0, 11.0, 6.0);
        let computed = std::cell::Cell::new(0u32);
        let mk = || {
            computed.set(computed.get() + 1);
            costs.clone()
        };
        let a = cache.plan_for(16, 2, 0, 0, mk);
        let b = cache.plan_for(16, 2, 0, 0, mk);
        assert!(std::sync::Arc::ptr_eq(&a, &b), "hit returns the same plan");
        assert_eq!(computed.get(), 1, "costs computed once per shape");
        assert_eq!(cache.stats(), (1, 1));
        // distinct shape (different b / mode tag / warmth) recomputes
        let _ = cache.plan_for(16, 3, 0, 0, mk);
        let _ = cache.plan_for(16, 2, 1, 0, mk);
        let _ = cache.plan_for(16, 2, 1, 0b111111, mk);
        assert_eq!(computed.get(), 4);
        assert_eq!(*a, plan(&costs), "cached plan is the DP plan");
    }

    #[test]
    fn compute_bound_regime_keeps_cache() {
        // paper: when mask ratio is large (compute > load), bubbles sit in
        // the load stream but caching still wins — DP must keep caching.
        let costs = uniform(8, 8.0, 20.0, 2.0);
        let p = plan(&costs);
        assert!(p.use_cache.iter().all(|&u| u));
    }
}
