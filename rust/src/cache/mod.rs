//! Activation caching (paper §3.1/§4.2): template activation store,
//! tiered host/disk storage, the device-resident KV working set, the
//! simulated copy streams, the bubble-free pipeline DP (Algo 1) and the
//! latency regression models (§4.4).

pub mod device;
pub mod latency_model;
pub mod loader;
pub mod pipeline;
pub mod store;
pub mod tier;

pub use device::{KvDeviceTier, KvKey, KvTierStats};
pub use latency_model::LatencyModel;
pub use loader::{CacheLoader, MemberGather, StagedBlock};
pub use pipeline::{plan, BlockCosts, PipelinePlan};
pub use store::{register_template, CacheEntry, TemplateActivations};
pub use tier::{Residency, TierError, TierStats, TieredStore};
