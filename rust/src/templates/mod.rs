//! Cluster-wide template lifecycle — the authoritative online template
//! set (paper §2.2: templates arrive continuously and are reused up to
//! 35 000×; §4.2: their activations live in a storage hierarchy).
//!
//! The [`TemplateRegistry`] owns which `(model, template)` pairs exist,
//! what lifecycle state each is in, its cache footprint, and how many
//! edits are in flight against it. Per-worker residency (hot-in-host /
//! on-disk / absent) stays with each worker's
//! [`crate::cache::tier::TieredStore`]; the cluster combines both views
//! for routing and the `/v1/templates` endpoints.
//!
//! Lifecycle: `registering → ready ⇄ (spilled per worker) → retired`,
//! with `failed` as the terminal state of a registration that errored.
//! Registration is online — `POST /v1/templates` enqueues a full-model
//! trace on a background low-priority lane while serving continues — and
//! retirement drains: in-flight edits finish, new submissions are
//! rejected with [`EditError::TemplateRetired`], and the last release
//! triggers the purge of every worker tier.

use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::engine::request::EditError;

/// Where a template is in its cluster-wide life.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TemplateState {
    /// A registration job (full-model trace) is queued or running.
    Registering,
    /// Activations are registered; edits against it are servable.
    Ready,
    /// Registration failed; submissions are rejected until re-registered.
    Failed(String),
    /// Retired: draining in-flight edits, rejecting new ones.
    Retired,
}

impl TemplateState {
    /// Stable label for status endpoints.
    pub fn label(&self) -> &'static str {
        match self {
            TemplateState::Registering => "registering",
            TemplateState::Ready => "ready",
            TemplateState::Failed(_) => "failed",
            TemplateState::Retired => "retired",
        }
    }
}

/// Snapshot of one template's registry entry.
#[derive(Debug, Clone)]
pub struct TemplateInfo {
    pub template_id: String,
    pub state: TemplateState,
    /// Cache footprint when resident (0 while registering / cold-adopted).
    pub bytes: usize,
    /// Edits currently queued or running against this template.
    pub inflight: usize,
    /// Bumped on every (re-)registration; stale jobs check it.
    pub epoch: u64,
    /// Seconds since the last state transition.
    pub age_secs: f64,
}

/// What [`TemplateRegistry::begin_register`] decided.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RegisterAdmission {
    /// A new registration was started; run the trace, then call
    /// `complete_register` (or `fail_register`) with this epoch.
    Started { epoch: u64 },
    /// The `(model, template)` pair is already registered — skip the
    /// trace (launch dedupe / idempotent POST).
    AlreadyReady,
    /// A registration for this template is already in flight.
    InProgress,
}

/// What [`TemplateRegistry::retire`] decided.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RetireOutcome {
    /// No in-flight edits: the caller should purge worker tiers now.
    Retired,
    /// In-flight edits are draining; the purge happens on last release.
    Draining { inflight: usize },
    /// No such template.
    NotFound,
}

struct Entry {
    state: TemplateState,
    bytes: usize,
    inflight: usize,
    epoch: u64,
    since: Instant,
}

impl Entry {
    fn transition(&mut self, state: TemplateState) {
        self.state = state;
        self.since = Instant::now();
    }
}

#[derive(Default)]
struct Inner {
    entries: HashMap<String, Entry>,
    /// request id -> template id, for releasing in-flight references.
    requests: HashMap<u64, String>,
}

/// The cluster-level template table. Shared by the cluster frontends
/// (admission checks), the collector (in-flight release), the background
/// registration lane, and every worker (wait-for-ready on tier misses).
pub struct TemplateRegistry {
    /// Model the templates were traced on; registry keys are effectively
    /// `(model, template)` pairs.
    model: String,
    inner: Mutex<Inner>,
    cv: Condvar,
}

impl TemplateRegistry {
    pub fn new(model: impl Into<String>) -> Arc<TemplateRegistry> {
        Arc::new(TemplateRegistry {
            model: model.into(),
            inner: Mutex::new(Inner::default()),
            cv: Condvar::new(),
        })
    }

    pub fn model(&self) -> &str {
        &self.model
    }

    /// Admit (or dedupe) a registration. Absent, failed, and retired
    /// templates start a fresh registration epoch; ready and in-progress
    /// ones are skipped — the launch-path dedupe and the idempotency of
    /// `POST /v1/templates` both fall out of this.
    pub fn begin_register(&self, template_id: &str) -> RegisterAdmission {
        let mut g = self.inner.lock().unwrap();
        // fresh templates enter as a zero-epoch retired placeholder and
        // are promoted by the shared re-registration path below
        let e = g.entries.entry(template_id.to_string()).or_insert(Entry {
            state: TemplateState::Retired,
            bytes: 0,
            inflight: 0,
            epoch: 0,
            since: Instant::now(),
        });
        match e.state {
            TemplateState::Ready => RegisterAdmission::AlreadyReady,
            TemplateState::Registering => RegisterAdmission::InProgress,
            TemplateState::Failed(_) | TemplateState::Retired => {
                e.epoch += 1;
                e.transition(TemplateState::Registering);
                RegisterAdmission::Started { epoch: e.epoch }
            }
        }
    }

    /// Registration finished: publish the template. Returns `false` when
    /// the registration is stale (retired or re-registered meanwhile) —
    /// the caller must then un-insert whatever it staged into the tiers.
    pub fn complete_register(&self, template_id: &str, epoch: u64, bytes: usize) -> bool {
        let mut g = self.inner.lock().unwrap();
        let fresh = match g.entries.get_mut(template_id) {
            Some(e) if e.epoch == epoch && e.state == TemplateState::Registering => {
                e.bytes = bytes;
                e.transition(TemplateState::Ready);
                true
            }
            _ => false,
        };
        drop(g);
        self.cv.notify_all();
        fresh
    }

    /// Registration failed: park the entry in `Failed` so waiting
    /// requests resolve with a typed error instead of timing out.
    pub fn fail_register(&self, template_id: &str, epoch: u64, reason: &str) {
        let mut g = self.inner.lock().unwrap();
        if let Some(e) = g.entries.get_mut(template_id) {
            if e.epoch == epoch && e.state == TemplateState::Registering {
                e.transition(TemplateState::Failed(reason.to_string()));
            }
        }
        drop(g);
        self.cv.notify_all();
    }

    /// Publish a template registered synchronously (cluster launch path).
    pub fn mark_ready(&self, template_id: &str, bytes: usize) {
        let mut g = self.inner.lock().unwrap();
        let e = g.entries.entry(template_id.to_string()).or_insert(Entry {
            state: TemplateState::Ready,
            bytes: 0,
            inflight: 0,
            epoch: 1,
            since: Instant::now(),
        });
        e.bytes = bytes;
        e.transition(TemplateState::Ready);
        drop(g);
        self.cv.notify_all();
    }

    /// Retire a template: new submissions are rejected immediately;
    /// in-flight edits drain. When none are in flight the caller purges
    /// worker tiers now; otherwise [`TemplateRegistry::release_request`]
    /// reports the drain completion.
    pub fn retire(&self, template_id: &str) -> RetireOutcome {
        let mut g = self.inner.lock().unwrap();
        let out = match g.entries.get_mut(template_id) {
            None => RetireOutcome::NotFound,
            Some(e) => {
                e.transition(TemplateState::Retired);
                if e.inflight == 0 {
                    RetireOutcome::Retired
                } else {
                    RetireOutcome::Draining { inflight: e.inflight }
                }
            }
        };
        drop(g);
        self.cv.notify_all();
        out
    }

    /// Whether a submission against this template would be accepted
    /// (ready, or queued behind an in-flight registration).
    pub fn is_submittable(&self, template_id: &str) -> bool {
        self.check_submittable(template_id).is_ok()
    }

    /// Typed admission check for the frontends.
    pub fn check_submittable(&self, template_id: &str) -> Result<(), EditError> {
        let g = self.inner.lock().unwrap();
        match g.entries.get(template_id).map(|e| &e.state) {
            Some(TemplateState::Ready) | Some(TemplateState::Registering) => Ok(()),
            Some(TemplateState::Retired) => {
                Err(EditError::TemplateRetired(template_id.to_string()))
            }
            Some(TemplateState::Failed(reason)) => Err(EditError::Internal(format!(
                "template {template_id:?} failed registration: {reason}"
            ))),
            None => Err(EditError::UnknownTemplate(template_id.to_string())),
        }
    }

    /// Take an in-flight reference for a routed request. Unknown
    /// templates are adopted as cold `Ready` entries (direct submitters
    /// bypass the HTTP admission check and cold-register on the worker).
    pub fn acquire(&self, request_id: u64, template_id: &str) {
        let mut g = self.inner.lock().unwrap();
        let inner = &mut *g;
        let e = inner.entries.entry(template_id.to_string()).or_insert(Entry {
            state: TemplateState::Ready,
            bytes: 0,
            inflight: 0,
            epoch: 1,
            since: Instant::now(),
        });
        e.inflight += 1;
        inner.requests.insert(request_id, template_id.to_string());
    }

    /// Drop the in-flight reference of a finished/cancelled request.
    /// Returns `Some(template_id)` when this release drained a retired
    /// template — the caller must purge it from every worker tier.
    /// Idempotent per request id.
    pub fn release_request(&self, request_id: u64) -> Option<String> {
        let mut g = self.inner.lock().unwrap();
        let template_id = g.requests.remove(&request_id)?;
        let drained = match g.entries.get_mut(&template_id) {
            Some(e) => {
                e.inflight = e.inflight.saturating_sub(1);
                e.inflight == 0 && e.state == TemplateState::Retired
            }
            None => false,
        };
        drop(g);
        self.cv.notify_all();
        drained.then_some(template_id)
    }

    /// Block until the template leaves `Registering` (submit-during-
    /// registration queues here), with typed resolution: `Ok` when ready,
    /// the matching [`EditError`] when retired / failed / unknown, and
    /// [`EditError::Timeout`] when the deadline passes first.
    pub fn wait_ready(&self, template_id: &str, timeout: Duration) -> Result<(), EditError> {
        let deadline = Instant::now() + timeout;
        let mut g = self.inner.lock().unwrap();
        loop {
            match g.entries.get(template_id).map(|e| &e.state) {
                Some(TemplateState::Ready) => return Ok(()),
                Some(TemplateState::Retired) => {
                    return Err(EditError::TemplateRetired(template_id.to_string()))
                }
                Some(TemplateState::Failed(reason)) => {
                    return Err(EditError::Internal(format!(
                        "template {template_id:?} failed registration: {reason}"
                    )))
                }
                Some(TemplateState::Registering) => {}
                None => return Err(EditError::UnknownTemplate(template_id.to_string())),
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(EditError::Timeout);
            }
            let (guard, _) = self.cv.wait_timeout(g, deadline - now).unwrap();
            g = guard;
        }
    }

    /// Non-blocking state lookup (worker admission path).
    pub fn state(&self, template_id: &str) -> Option<TemplateState> {
        self.inner.lock().unwrap().entries.get(template_id).map(|e| e.state.clone())
    }

    /// Registered cache footprint (None for unknown templates).
    pub fn bytes(&self, template_id: &str) -> Option<usize> {
        self.inner.lock().unwrap().entries.get(template_id).map(|e| e.bytes)
    }

    pub fn info(&self, template_id: &str) -> Option<TemplateInfo> {
        let g = self.inner.lock().unwrap();
        g.entries.get(template_id).map(|e| TemplateInfo {
            template_id: template_id.to_string(),
            state: e.state.clone(),
            bytes: e.bytes,
            inflight: e.inflight,
            epoch: e.epoch,
            age_secs: e.since.elapsed().as_secs_f64(),
        })
    }

    /// All known templates, sorted by id (stable endpoint output).
    pub fn list(&self) -> Vec<TemplateInfo> {
        let g = self.inner.lock().unwrap();
        let mut out: Vec<TemplateInfo> = g
            .entries
            .iter()
            .map(|(id, e)| TemplateInfo {
                template_id: id.clone(),
                state: e.state.clone(),
                bytes: e.bytes,
                inflight: e.inflight,
                epoch: e.epoch,
                age_secs: e.since.elapsed().as_secs_f64(),
            })
            .collect();
        out.sort_by(|a, b| a.template_id.cmp(&b.template_id));
        out
    }

    /// Number of known templates (any state).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn register_lifecycle_and_dedupe() {
        let reg = TemplateRegistry::new("m");
        assert_eq!(reg.model(), "m");
        let RegisterAdmission::Started { epoch } = reg.begin_register("t") else {
            panic!("fresh template must start registration");
        };
        assert_eq!(epoch, 1);
        assert_eq!(reg.state("t"), Some(TemplateState::Registering));
        // duplicate (model, template) pairs never re-run the trace
        assert_eq!(reg.begin_register("t"), RegisterAdmission::InProgress);
        assert!(reg.complete_register("t", epoch, 128));
        assert_eq!(reg.begin_register("t"), RegisterAdmission::AlreadyReady);
        assert_eq!(reg.state("t"), Some(TemplateState::Ready));
        assert_eq!(reg.bytes("t"), Some(128));
        assert!(reg.is_submittable("t"));
        assert_eq!(reg.len(), 1);
    }

    #[test]
    fn failed_registration_is_typed_and_retryable() {
        let reg = TemplateRegistry::new("m");
        let RegisterAdmission::Started { epoch } = reg.begin_register("t") else {
            panic!("started")
        };
        reg.fail_register("t", epoch, "boom");
        assert!(matches!(
            reg.check_submittable("t"),
            Err(EditError::Internal(_))
        ));
        assert!(matches!(
            reg.wait_ready("t", Duration::from_millis(5)),
            Err(EditError::Internal(_))
        ));
        // a failed template can be re-registered at a fresh epoch
        let RegisterAdmission::Started { epoch } = reg.begin_register("t") else {
            panic!("retry")
        };
        assert_eq!(epoch, 2);
        assert!(reg.complete_register("t", epoch, 64));
        assert!(reg.is_submittable("t"));
    }

    #[test]
    fn retire_drains_inflight_then_reports_purge() {
        let reg = TemplateRegistry::new("m");
        reg.mark_ready("t", 256);
        reg.acquire(1, "t");
        reg.acquire(2, "t");
        assert_eq!(reg.retire("t"), RetireOutcome::Draining { inflight: 2 });
        // retired templates reject new submissions with the typed error
        assert!(matches!(
            reg.check_submittable("t"),
            Err(EditError::TemplateRetired(_))
        ));
        assert_eq!(reg.release_request(1), None, "still one in flight");
        assert_eq!(reg.release_request(1), None, "release is idempotent");
        assert_eq!(
            reg.release_request(2).as_deref(),
            Some("t"),
            "last release reports the drained template for tier purge"
        );
        // already drained: retiring again purges immediately
        assert_eq!(reg.retire("t"), RetireOutcome::Retired);
        assert_eq!(reg.retire("ghost"), RetireOutcome::NotFound);
    }

    #[test]
    fn reregister_after_retire_bumps_epoch_and_ignores_stale_jobs() {
        let reg = TemplateRegistry::new("m");
        let RegisterAdmission::Started { epoch: e1 } = reg.begin_register("t") else {
            panic!()
        };
        // retire while the registration job is still running
        assert_eq!(reg.retire("t"), RetireOutcome::Retired);
        // the stale job must not publish into the retired entry
        assert!(!reg.complete_register("t", e1, 99));
        assert_eq!(reg.state("t"), Some(TemplateState::Retired));
        // re-registration runs at a fresh epoch and wins
        let RegisterAdmission::Started { epoch: e2 } = reg.begin_register("t") else {
            panic!()
        };
        assert!(e2 > e1);
        assert!(reg.complete_register("t", e2, 100));
        assert_eq!(reg.bytes("t"), Some(100));
        assert!(reg.is_submittable("t"));
    }

    #[test]
    fn wait_ready_unblocks_on_completion() {
        let reg = TemplateRegistry::new("m");
        let RegisterAdmission::Started { epoch } = reg.begin_register("t") else {
            panic!()
        };
        assert!(matches!(
            reg.wait_ready("t", Duration::from_millis(20)),
            Err(EditError::Timeout)
        ));
        let reg2 = Arc::clone(&reg);
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            reg2.complete_register("t", epoch, 32);
        });
        assert!(reg.wait_ready("t", Duration::from_secs(5)).is_ok());
        h.join().unwrap();
        assert!(matches!(
            reg.wait_ready("ghost", Duration::from_millis(1)),
            Err(EditError::UnknownTemplate(_))
        ));
    }

    #[test]
    fn acquire_adopts_unknown_templates_for_direct_submitters() {
        let reg = TemplateRegistry::new("m");
        reg.acquire(7, "cold");
        assert_eq!(reg.state("cold"), Some(TemplateState::Ready));
        assert_eq!(reg.info("cold").unwrap().inflight, 1);
        assert_eq!(reg.release_request(7), None);
        assert_eq!(reg.info("cold").unwrap().inflight, 0);
    }

    #[test]
    fn list_is_sorted_and_complete() {
        let reg = TemplateRegistry::new("m");
        reg.mark_ready("b", 1);
        reg.mark_ready("a", 2);
        reg.begin_register("c");
        let infos = reg.list();
        let ids: Vec<&str> = infos.iter().map(|i| i.template_id.as_str()).collect();
        assert_eq!(ids, vec!["a", "b", "c"]);
        assert_eq!(infos[2].state.label(), "registering");
        assert!(!reg.is_empty());
    }
}
