//! InstGenIE CLI — the leader entrypoint.
//!
//! Subcommands:
//!   serve           launch a cluster + HTTP frontend
//!   run             replay a generated trace through a cluster, report
//!   calibrate       fit + save the latency regression models (Fig. 11)
//!   workload-stats  mask-ratio distribution statistics (Fig. 3)
//!   register        pre-register templates into the spill tier
//!   info            print manifest / model inventory

use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use instgenie::cache::latency_model::{calibrate, LatencyModel};
use instgenie::cluster::{Cluster, ClusterOpts, RequestState};
use instgenie::config::{BatchingPolicy, CacheMode, EngineConfig, SystemKind};
use instgenie::dist::{DistConfig, Router, WorkerNode};
use instgenie::durable::{install_shutdown_handler, shutdown_requested, FsyncPolicy};
use instgenie::faults::FaultPlan;
use instgenie::metrics::Recorder;
use instgenie::qos::{AdmissionController, Priority};
use instgenie::runtime::{Manifest, ModelRuntime};
use instgenie::scheduler;
use instgenie::server::HttpServer;
use instgenie::util::cli::Args;
use instgenie::util::stats::Summary;
use instgenie::workload::{
    replay, ArrivalShape, ClassMix, MaskDist, Popularity, SessionGen, TraceEvent, TraceGen,
};

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.command.as_str() {
        "serve" => cmd_serve(&args),
        "run" => cmd_run(&args),
        "calibrate" => cmd_calibrate(&args),
        "workload-stats" => cmd_workload_stats(&args),
        "register" => cmd_register(&args),
        "info" => cmd_info(&args),
        "" | "help" => {
            print_help();
            Ok(())
        }
        other => {
            print_help();
            bail!("unknown command {other:?}")
        }
    }
}

fn print_help() {
    println!(
        "instgenie — mask-aware image-editing serving (paper reproduction)\n\
         commands:\n\
         \x20 serve          --model sdxlm --workers 2 --addr 127.0.0.1:8801 --system instgenie\n\
         \x20                [--role cluster|router|worker]   distributed plane:\n\
         \x20                  router: --addr 127.0.0.1:8801 [--heartbeat-ms 500 --suspect-after-ms 2000\n\
         \x20                          --dead-after-ms 5000 --poll-ms 100 --rpc-timeout-ms 10000]\n\
         \x20                          [--retry-budget 10 --retry-refill-per-sec 1 --retry-attempts 3\n\
         \x20                          --retry-backoff-base-ms 10 --retry-backoff-cap-ms 500]\n\
         \x20                          [--journal <dir> --fsync always|batched|off]  write-ahead journal:\n\
         \x20                          crash recovery replays it; restart with the same --journal dir\n\
         \x20                          [--standby-of 127.0.0.1:8801 --standby-takeover-ms 3000]  warm\n\
         \x20                          standby: tails the primary's journal, takes over on silence\n\
         \x20                  worker: --rpc-addr 127.0.0.1:0 --router 127.0.0.1:8801 --name worker-a\n\
         \x20                          [--checkpoint-every-steps 4]  step-boundary latent checkpoints\n\
         \x20                          --router accepts a primary,standby list (failover rotation)\n\
         \x20                  all roles drain + exit 0 on SIGTERM/SIGINT\n\
         \x20 run            --model sdxlm --workers 2 --rps 1.0 --requests 40 --system instgenie\n\
         \x20                --scheduler round-robin|request-lb|token-lb|cache-aware|mask-aware|qos-aware|session-affinity\n\
         \x20                --dist production --templates 4 --class-mix 0.2,0.5,0.3\n\
         \x20                [--popularity quadratic|zipf:<s>] [--shape steady|diurnal:<p>:<d>|bursts:<p>:<w>:<a>]\n\
         \x20                [--no-qos] [--aging-ms 2000] [--max-pending 4096] [--host-step-loop]\n\
         \x20                [--faults seed=7,disk_read=0.05,rpc_drop=0.01,delay_ms=20]  chaos injection\n\
         \x20                [--no-kv-device-tier] [--kv-device-budget <bytes>]\n\
         \x20                [--sessions 8 --rounds-per-session 4 --mask-drift 0.2]  multi-round\n\
         \x20                  interactive sessions instead of one-shot edits (delta-mask reuse)\n\
         \x20 calibrate      --model fluxm [--reps 20]\n\
         \x20 workload-stats --dist production|public|viton\n\
         \x20 register       --model sdxlm --templates 4\n\
         \x20 info\n\
         \n\
         serve exposes the v1 request-lifecycle HTTP API:\n\
         \x20 POST   /v1/edits       async submit -> 202 {{id, status_url}}; over capacity -> 429 + Retry-After\n\
         \x20        curl -s localhost:8801/v1/edits -d '{{\"template\":\"tpl-0\",\"mask_ratio\":0.2,\"prompt_seed\":7,\n\
         \x20                \"priority\":\"interactive\",\"deadline_ms\":2000}}'\n\
         \x20 GET    /v1/edits/{{id}}  poll: queued|running|done (+ timing, image stats)\n\
         \x20        curl -s localhost:8801/v1/edits/1000000\n\
         \x20 DELETE /v1/edits/{{id}}  cancel while queued -> cancelled\n\
         \x20        curl -s -X DELETE localhost:8801/v1/edits/1000000\n\
         \x20 POST   /v1/templates   register a template online (background trace)\n\
         \x20        curl -s localhost:8801/v1/templates -d '{{\"template\":\"tpl-9\"}}'\n\
         \x20 GET    /v1/templates[/{{id}}]  state + bytes + per-worker residency\n\
         \x20 DELETE /v1/templates/{{id}}    retire (drain in-flight, free tiers)\n\
         \x20 POST   /v1/sessions    open an interactive session (pins its template)\n\
         \x20        curl -s localhost:8801/v1/sessions -d '{{\"template\":\"tpl-0\"}}'\n\
         \x20 POST   /v1/sessions/{{id}}/rounds   submit the next round (interactive QoS by default;\n\
         \x20                                   unchanged mask -> warm: plan/gather/KV reused)\n\
         \x20 GET    /v1/sessions/{{id}}          state, owner, epoch, per-round records\n\
         \x20 GET    /v1/sessions/{{id}}/rounds/{{n}}/events   SSE step-progress stream\n\
         \x20 DELETE /v1/sessions/{{id}}          close (drains in-flight, releases the template pin)\n\
         \x20 GET    /v1/stats       per-worker queue depths + cache tiers + completions\n\
         \x20 POST   /edit           synchronous submit+wait wrapper\n\
         \x20 GET    /healthz        liveness\n\
         \n\
         a --role router additionally exposes the membership plane:\n\
         \x20 GET    /v1/cluster     member list (joining|ready|draining|suspect|dead), epoch,\n\
         \x20                        heartbeat age, per-member + aggregate queue depths\n\
         \x20 POST   /v1/drain/{{name}}  live-drain a member (finishes held work, takes no more)\n\
         \x20 POST   /rpc/announce   (worker->router) join/rejoin with rpc_addr + templates\n\
         \x20 POST   /rpc/heartbeat  (worker->router) liveness + load snapshot"
    );
}

fn engine_config(args: &Args) -> Result<EngineConfig> {
    let system = SystemKind::parse(&args.str("system", "instgenie"))
        .context("bad --system (instgenie|diffusers|fisedit|teacache)")?;
    let mut cfg = EngineConfig::for_system(system);
    if let Some(b) = args.flags.get("batching") {
        cfg.batching = match b.as_str() {
            "static" => BatchingPolicy::Static,
            "continuous-inline" => BatchingPolicy::ContinuousInline,
            "continuous" => BatchingPolicy::ContinuousDisaggregated,
            other => bail!("bad --batching {other:?}"),
        };
    }
    if args.str("cache-mode", "y") == "kv" {
        cfg.cache_mode = CacheMode::CacheKV;
    }
    cfg.max_batch = args.usize("max-batch", cfg.max_batch);
    cfg.sim_bandwidth = args.f64("bandwidth", cfg.sim_bandwidth);
    cfg.prepost_cpu_us = args.u64("prepost-us", cfg.prepost_cpu_us);
    cfg.registration_wait_ms = args.u64("registration-wait-ms", cfg.registration_wait_ms);
    cfg.force_all_cached = args.bool("force-all-cached");
    cfg.naive_loading = args.bool("naive-loading");
    // device-resident step loop is the default; --host-step-loop runs the
    // per-block host-round-trip reference (golden baseline / debugging)
    cfg.device_resident = !args.bool("host-step-loop");
    // device KV working set: on by default with an HBM budget;
    // --no-kv-device-tier re-uploads staged K/V every step (the pre-tier
    // behavior, for ablations and the overhead bench baseline)
    cfg.kv_device_budget_bytes = args.usize("kv-device-budget", cfg.kv_device_budget_bytes);
    if args.bool("no-kv-device-tier") {
        cfg.kv_device_budget_bytes = 0;
    }
    // QoS: on by default; --no-qos reverts to the FIFO baseline
    if args.bool("no-qos") {
        cfg.qos.enabled = false;
    }
    cfg.qos.aging_ms = args.u64("aging-ms", cfg.qos.aging_ms);
    cfg.qos.max_pending = args.usize("max-pending", cfg.qos.max_pending);
    // deterministic fault injection (chaos testing):
    //   --faults "seed=7,disk_read=0.05,rpc_drop=0.01,delay_ms=20"
    if let Some(spec) = args.flags.get("faults") {
        let plan = FaultPlan::parse(spec).map_err(|e| anyhow::anyhow!("bad --faults: {e}"))?;
        cfg.faults = Some(plan);
    }
    // step-boundary latent checkpoints (crash resume); 0 disables
    cfg.checkpoint_every_steps =
        args.usize("checkpoint-every-steps", cfg.checkpoint_every_steps);
    Ok(cfg)
}

fn launch_cluster(args: &Args) -> Result<Cluster> {
    let model = args.str("model", "sdxlm");
    let artifact_dir = args.str("artifacts", "artifacts");
    let engine = engine_config(args)?;
    let templates: Vec<String> = (0..args.usize("templates", 4))
        .map(|i| format!("tpl-{i}"))
        .collect();
    let lat = LatencyModel::load_or_nominal(&artifact_dir, &model);
    let manifest = Manifest::load(&artifact_dir)?;
    let mcfg = manifest.model(&model)?.config.clone();
    let sched = scheduler::by_name(
        &args.str("scheduler", "mask-aware"),
        &mcfg,
        &lat,
        engine.cache_mode,
        engine.max_batch,
    )
    .context("bad --scheduler")?;
    Cluster::launch(
        ClusterOpts {
            workers: args.usize("workers", 2),
            engine,
            model,
            artifact_dir,
            templates,
            lat_model: lat,
            warmup: args.bool("warmup"),
        },
        sched,
    )
}

fn dist_config(args: &Args) -> Result<DistConfig> {
    let d = DistConfig::default();
    // transport faults on the router's RPC clients ride the same --faults
    // spec as the engine sites (one chaos knob for the whole deployment)
    let faults = match args.flags.get("faults") {
        Some(spec) => {
            Some(FaultPlan::parse(spec).map_err(|e| anyhow::anyhow!("bad --faults: {e}"))?)
        }
        None => None,
    };
    let journal_fsync = match args.flags.get("fsync") {
        Some(s) => FsyncPolicy::parse(s)
            .ok_or_else(|| anyhow::anyhow!("bad --fsync {s:?} (always|batched|off)"))?,
        None => d.journal_fsync,
    };
    Ok(DistConfig {
        heartbeat_ms: args.u64("heartbeat-ms", d.heartbeat_ms),
        suspect_after_ms: args.u64("suspect-after-ms", d.suspect_after_ms),
        dead_after_ms: args.u64("dead-after-ms", d.dead_after_ms),
        poll_ms: args.u64("poll-ms", d.poll_ms),
        rpc_timeout_ms: args.u64("rpc-timeout-ms", d.rpc_timeout_ms),
        retry_budget: args.f64("retry-budget", d.retry_budget),
        retry_refill_per_sec: args.f64("retry-refill-per-sec", d.retry_refill_per_sec),
        retry_backoff_base_ms: args.u64("retry-backoff-base-ms", d.retry_backoff_base_ms),
        retry_backoff_cap_ms: args.u64("retry-backoff-cap-ms", d.retry_backoff_cap_ms),
        retry_attempts: args.u64("retry-attempts", d.retry_attempts as u64) as u32,
        faults,
        // durable control plane: --journal <dir> turns on the write-ahead
        // journal; without it the router is volatile (pre-journal behavior)
        journal_dir: args.flags.get("journal").map(std::path::PathBuf::from),
        journal_fsync,
        journal_segment_bytes: args.u64("journal-segment-bytes", d.journal_segment_bytes),
        journal_snapshot_every: args.u64("journal-snapshot-every", d.journal_snapshot_every),
        journal_batch_ms: args.u64("journal-batch-ms", d.journal_batch_ms),
        standby_takeover_ms: args.u64("standby-takeover-ms", d.standby_takeover_ms),
    })
}

/// Block until a SIGTERM/SIGINT arrives (the graceful-shutdown signal
/// plane shared by all three serve roles).
fn wait_for_shutdown_signal() {
    install_shutdown_handler();
    while !shutdown_requested() {
        std::thread::sleep(Duration::from_millis(200));
    }
}

fn cmd_serve(args: &Args) -> Result<()> {
    match args.str("role", "cluster").as_str() {
        "cluster" => {
            let cluster = Arc::new(launch_cluster(args)?);
            let addr = args.str("addr", "127.0.0.1:8801");
            let server = Arc::new(HttpServer::new(Arc::clone(&cluster), 1_000_000));
            // SIGTERM/SIGINT: close the listener so serve() returns, then
            // drain below before exiting 0
            let watcher = Arc::clone(&server);
            std::thread::spawn(move || {
                wait_for_shutdown_signal();
                eprintln!("[serve] shutdown signal: closing listener");
                watcher.shutdown();
            });
            server.serve(&addr)?;
            // stop the engines and let running members finish at their
            // step boundaries (the run loop drains before breaking)
            cluster.request_stop();
            let deadline = std::time::Instant::now() + Duration::from_secs(10);
            while std::time::Instant::now() < deadline
                && cluster
                    .worker_snapshots()
                    .iter()
                    .any(|s| s.running > 0 || s.queued > 0)
            {
                std::thread::sleep(Duration::from_millis(50));
            }
            eprintln!("[serve] drained; exiting");
            Ok(())
        }
        "router" => cmd_serve_router(args),
        "worker" => cmd_serve_worker(args),
        other => bail!("bad --role {other:?} (cluster|router|worker)"),
    }
}

/// `serve --role router`: the distributed plane's front process. Serves
/// the public `/v1/*` API plus the worker-facing `/rpc/*` control
/// endpoints; workers join via `--router <this addr>`.
fn cmd_serve_router(args: &Args) -> Result<()> {
    let model = args.str("model", "sdxlm");
    let artifact_dir = args.str("artifacts", "artifacts");
    let engine = engine_config(args)?;
    let lat = LatencyModel::load_or_nominal(&artifact_dir, &model);
    let manifest = Manifest::load(&artifact_dir)?;
    let mcfg = manifest.model(&model)?.config.clone();
    let sched = scheduler::by_name(
        &args.str("scheduler", "mask-aware"),
        &mcfg,
        &lat,
        engine.cache_mode,
        engine.max_batch,
    )
    .context("bad --scheduler")?;
    let admission = engine.qos.enabled.then(|| {
        AdmissionController::new(
            mcfg.clone(),
            lat.clone(),
            engine.cache_mode,
            engine.max_batch,
            engine.qos.clone(),
        )
    });
    let router = Router::new(mcfg, sched, admission, dist_config(args)?);
    let bind = args.str("addr", "127.0.0.1:8801");
    if let Some(primary) = args.flags.get("standby-of") {
        // warm standby: tail the primary's journal, refuse writes (503)
        // until the primary goes silent, then take over in place
        let addr = router.start_standby(&bind, primary)?;
        eprintln!("[router] standby on {addr} (tailing primary {primary})");
    } else {
        let addr = router.start(&bind)?;
        eprintln!("[router] listening on {addr} (public api + worker rpc)");
    }
    wait_for_shutdown_signal();
    eprintln!("[router] shutdown signal: draining");
    router.graceful_shutdown(Duration::from_secs(10));
    eprintln!("[router] drained; exiting");
    Ok(())
}

/// `serve --role worker`: one worker process of the distributed plane.
/// Wraps a single-worker engine behind `/rpc/*` and (when `--router` is
/// given) announces + heartbeats to the router.
fn cmd_serve_worker(args: &Args) -> Result<()> {
    let model = args.str("model", "sdxlm");
    let artifact_dir = args.str("artifacts", "artifacts");
    let engine = engine_config(args)?;
    let templates: Vec<String> = (0..args.usize("templates", 4))
        .map(|i| format!("tpl-{i}"))
        .collect();
    let lat = LatencyModel::load_or_nominal(&artifact_dir, &model);
    let name = args.str("name", &format!("worker-{}", std::process::id()));
    let node = Arc::new(WorkerNode::launch(
        name,
        ClusterOpts {
            workers: 1,
            engine,
            model,
            artifact_dir,
            templates,
            lat_model: lat,
            warmup: args.bool("warmup"),
        },
    )?);
    let addr = node.start(&args.str("rpc-addr", "127.0.0.1:0"))?;
    eprintln!("[worker] {} serving rpc on {addr}", node.name());
    if let Some(router) = args.flags.get("router") {
        // comma-separated list: primary first, warm standby second — the
        // node rotates to the standby when the primary goes silent
        node.announce_to(router, &dist_config(args)?);
    } else {
        eprintln!("[worker] no --router given: standalone rpc mode");
    }
    wait_for_shutdown_signal();
    eprintln!("[worker] {} shutdown signal: draining", node.name());
    node.stop();
    // running members finish at their step boundaries before the engine
    // loop breaks; wait for that drain so the exit is clean
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    while std::time::Instant::now() < deadline
        && node
            .cluster()
            .worker_snapshots()
            .iter()
            .any(|s| s.running > 0 || s.queued > 0)
    {
        std::thread::sleep(Duration::from_millis(50));
    }
    eprintln!("[worker] {} drained; exiting", node.name());
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    if args.flags.contains_key("sessions") {
        return cmd_run_sessions(args);
    }
    let cluster = launch_cluster(args)?;
    let mut gen = TraceGen::new(
        args.f64("rps", 1.0),
        MaskDist::parse(&args.str("dist", "production")).context("bad --dist")?,
        args.usize("templates", 4),
        args.u64("seed", 42),
    );
    if let Some(mix) = args.flags.get("class-mix") {
        gen = gen.with_mix(ClassMix::parse(mix).context("bad --class-mix (i,s,b weights)")?);
    }
    if let Some(p) = args.flags.get("popularity") {
        gen = gen.with_popularity(
            Popularity::parse(p).context("bad --popularity (quadratic|zipf:<s>)")?,
        );
    }
    if let Some(s) = args.flags.get("shape") {
        gen = gen.with_shape(
            ArrivalShape::parse(s)
                .context("bad --shape (steady|diurnal:<period>:<depth>|bursts:<period>:<width>:<amplitude>)")?,
        );
    }
    let events = gen.generate(args.usize("requests", 40));
    eprintln!(
        "[run] {} requests at {} rps over {} workers (system={}, scheduler={})",
        events.len(),
        args.f64("rps", 1.0),
        cluster.workers(),
        args.str("system", "instgenie"),
        args.str("scheduler", "mask-aware"),
    );
    let t0 = std::time::Instant::now();
    let mut rec = Recorder::new();
    let mut tickets = Vec::with_capacity(events.len());
    replay(&events, |ev| {
        // the guarded path: QoS admission sheds over-capacity or
        // deadline-infeasible requests up front (counted as failures)
        match cluster.submit_guarded(cluster.event_request(ev)) {
            Ok(t) => tickets.push(t),
            Err(e) => rec.record_failure(&e),
        }
    });
    cluster.await_completed(tickets.len(), std::time::Duration::from_secs(600));
    let makespan = t0.elapsed().as_secs_f64();
    for t in &tickets {
        if let Some(st) = t.status() {
            if let RequestState::Failed(e) = st.state {
                rec.record_failure(&e);
            }
        }
    }
    let responses = cluster.shutdown()?;
    for r in &responses {
        rec.record(r);
    }
    let report = rec.report(makespan);
    println!("{}", report.line());
    println!("{}", report.to_json());
    Ok(())
}

/// `run --sessions`: replay multi-round interactive editing sessions
/// through the session plane instead of independent one-shot edits.
/// Each script opens a session (pinning its template), submits K rounds
/// through `submit_session_round` (warm rounds reuse the previous
/// round's plan/gather/KV when the mask didn't drift), then closes.
fn cmd_run_sessions(args: &Args) -> Result<()> {
    let cluster = launch_cluster(args)?;
    let mut gen = SessionGen::new(
        args.usize("sessions", 8),
        args.usize("rounds-per-session", 4),
        args.f64("mask-drift", 0.2),
        MaskDist::parse(&args.str("dist", "production")).context("bad --dist")?,
        args.usize("templates", 4),
        args.u64("seed", 42),
    );
    if let Some(p) = args.flags.get("popularity") {
        gen = gen.with_popularity(
            Popularity::parse(p).context("bad --popularity (quadratic|zipf:<s>)")?,
        );
    }
    let scripts = gen.generate();
    let total_rounds: usize = scripts.iter().map(|s| s.rounds.len()).sum();
    eprintln!(
        "[run] {} sessions x {} rounds (drift={}) over {} workers (scheduler={})",
        scripts.len(),
        args.usize("rounds-per-session", 4),
        args.f64("mask-drift", 0.2),
        cluster.workers(),
        args.str("scheduler", "mask-aware"),
    );
    let t0 = std::time::Instant::now();
    let mut next_id = 1u64;
    let mut ok = 0usize;
    let mut failed = 0usize;
    let mut warm_lat = Vec::new();
    let mut cold_lat = Vec::new();
    for script in &scripts {
        let sid = match cluster.open_session(&script.template) {
            Ok(sid) => sid,
            Err(e) => {
                eprintln!("[run] open_session({}) failed: {e}", script.template);
                failed += script.rounds.len();
                continue;
            }
        };
        for round in &script.rounds {
            let ev = TraceEvent {
                id: next_id,
                at: 0.0,
                template: script.template.clone(),
                mask_ratio: round.mask_ratio,
                prompt_seed: round.prompt_seed,
                priority: Priority::Interactive,
                deadline_ms: None,
            };
            next_id += 1;
            match cluster.submit_session_round(sid, cluster.event_request(&ev)) {
                Ok((ticket, plan)) => {
                    match ticket.wait(std::time::Duration::from_secs(600)) {
                        Ok(resp) => {
                            ok += 1;
                            if plan.warm {
                                warm_lat.push(resp.timing.e2e);
                            } else {
                                cold_lat.push(resp.timing.e2e);
                            }
                        }
                        Err(_) => failed += 1,
                    }
                }
                Err(e) => {
                    eprintln!("[run] session {sid} round {} rejected: {e}", round.round);
                    failed += 1;
                }
            }
        }
        if let Err(e) = cluster.close_session(sid, std::time::Duration::from_secs(10)) {
            eprintln!("[run] close_session({sid}) failed: {e}");
        }
    }
    let makespan = t0.elapsed().as_secs_f64();
    cluster.shutdown()?;
    let mean = |xs: &[f64]| {
        if xs.is_empty() {
            f64::NAN
        } else {
            xs.iter().sum::<f64>() / xs.len() as f64
        }
    };
    println!(
        "sessions={} rounds={} ok={} failed={} warm={} cold={} rounds_per_sec={:.2} \
         warm_mean_s={:.4} cold_mean_s={:.4}",
        scripts.len(),
        total_rounds,
        ok,
        failed,
        warm_lat.len(),
        cold_lat.len(),
        ok as f64 / makespan.max(1e-9),
        mean(&warm_lat),
        mean(&cold_lat),
    );
    Ok(())
}

fn cmd_calibrate(args: &Args) -> Result<()> {
    let artifact_dir = args.str("artifacts", "artifacts");
    let models: Vec<String> = match args.flags.get("model") {
        Some(m) => vec![m.clone()],
        None => vec!["sd21m".into(), "sdxlm".into(), "fluxm".into()],
    };
    for model in models {
        let rt = ModelRuntime::create(&artifact_dir, &model)?;
        let bw = args.f64("bandwidth", EngineConfig::instgenie().sim_bandwidth);
        let (lat, comp, load) = calibrate(&rt, bw, args.usize("reps", 10))?;
        lat.save(&artifact_dir, &model)?;
        println!(
            "[calibrate] {model}: comp fit slope={:.3e}s/FLOP intercept={:.1}µs R²={:.4} ({} pts)",
            lat.comp.slope,
            lat.comp.intercept * 1e6,
            lat.comp.r2,
            comp.len()
        );
        println!(
            "[calibrate] {model}: load fit slope={:.3e}s/B  intercept={:.1}µs R²={:.4} ({} pts)",
            lat.load.slope,
            lat.load.intercept * 1e6,
            lat.load.r2,
            load.len()
        );
    }
    Ok(())
}

fn cmd_workload_stats(args: &Args) -> Result<()> {
    use instgenie::util::rng::Pcg;
    let dists = match args.flags.get("dist") {
        Some(d) => vec![MaskDist::parse(d).context("bad --dist")?],
        None => vec![MaskDist::Production, MaskDist::PublicTrace, MaskDist::VitonHD],
    };
    println!("Fig. 3 — mask-ratio distributions (paper means: 0.11 / 0.19 / 0.35)");
    for dist in dists {
        let mut rng = Pcg::new(args.u64("seed", 1));
        let xs: Vec<f64> = (0..args.usize("samples", 50_000))
            .map(|_| dist.sample(&mut rng))
            .collect();
        let s = Summary::of(&xs);
        println!(
            "{:?}: mean={:.3} p50={:.3} p95={:.3} max={:.3}",
            dist, s.mean, s.p50, s.p95, s.max
        );
    }
    Ok(())
}

fn cmd_register(args: &Args) -> Result<()> {
    use instgenie::cache::store::register_template;
    use instgenie::cache::tier::TieredStore;
    let artifact_dir = args.str("artifacts", "artifacts");
    let model = args.str("model", "sdxlm");
    let rt = ModelRuntime::create(&artifact_dir, &model)?;
    let mode = if args.str("cache-mode", "y") == "kv" {
        CacheMode::CacheKV
    } else {
        CacheMode::CacheY
    };
    let tiers = TieredStore::new(
        0, // zero budget: spill immediately, pre-warming the disk tier
        format!("{artifact_dir}/cache_spill").into(),
        0.0,
    );
    for i in 0..args.usize("templates", 4) {
        let id = format!("tpl-{i}");
        let t0 = std::time::Instant::now();
        let (acts, _) = register_template(&rt, &id, mode)?;
        let mb = acts.size_bytes() as f64 / 1e6;
        tiers.insert(acts)?;
        println!("[register] {id}: {mb:.1} MB in {:?}", t0.elapsed());
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let manifest = Manifest::load(args.str("artifacts", "artifacts"))?;
    println!("artifact dir: {:?}", manifest.dir);
    println!("batch buckets: {:?}", manifest.batch_buckets);
    for (name, m) in &manifest.models {
        let c = &m.config;
        println!(
            "{name}: L={} H={} heads={} blocks={} steps={} buckets={:?} ({} artifacts, analogue: {})",
            c.tokens,
            c.hidden,
            c.heads,
            c.blocks,
            c.steps,
            c.token_buckets,
            m.artifacts.len(),
            c.paper_analogue,
        );
    }
    Ok(())
}
