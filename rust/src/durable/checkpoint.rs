//! Step-boundary latent checkpoints, spilled through the same
//! checksummed-atomic-rename discipline as the disk cache tier
//! (`cache/tier.rs`): magic + u64 LE header + payload checksum, written
//! to a tmp file and renamed into place.
//!
//! A checkpoint binds to its request through a `request_checksum` over
//! (id, prompt seed, masked-row count, template), so a stale file left by
//! an id reuse or a different request shape is rejected, not resumed.
//! The engine is deterministic, so the latent at a checkpointed step is
//! bit-identical to the fault-free run's — resuming from it yields the
//! same final latent as never crashing.

use std::fs::{self, File};
use std::io::{self, Read, Write};
use std::path::{Path, PathBuf};

use crate::util::rng::{hash_str, splitmix64};

const CHECKPOINT_MAGIC: u64 = 0x1057_6e13_c4ec_9013;
const CHECKPOINT_VERSION: u64 = 1;
/// magic, version, id, step, len, request checksum, payload checksum.
const HEADER_WORDS: usize = 7;

/// Binds a checkpoint to the request that wrote it.
pub fn request_checksum(id: u64, prompt_seed: u64, masked: usize, template: &str) -> u64 {
    splitmix64(
        id ^ prompt_seed.rotate_left(17)
            ^ (masked as u64).rotate_left(33)
            ^ hash_str(template),
    )
}

pub fn checkpoint_path(dir: &Path, id: u64) -> PathBuf {
    dir.join(format!("ckpt-{id}.bin"))
}

fn payload_checksum(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    splitmix64(h)
}

/// Atomically persist `data` (the latent at step `step`, row-major f32).
pub fn save_checkpoint(
    dir: &Path,
    id: u64,
    step: usize,
    req_sum: u64,
    data: &[f32],
) -> io::Result<()> {
    fs::create_dir_all(dir)?;
    let mut payload = Vec::with_capacity(data.len() * 4);
    for &v in data {
        payload.extend_from_slice(&v.to_le_bytes());
    }
    let header: [u64; HEADER_WORDS] = [
        CHECKPOINT_MAGIC,
        CHECKPOINT_VERSION,
        id,
        step as u64,
        data.len() as u64,
        req_sum,
        payload_checksum(&payload),
    ];
    let tmp = dir.join(format!("tmp-{}-{id}", std::process::id()));
    {
        let mut f = File::create(&tmp)?;
        for w in header {
            f.write_all(&w.to_le_bytes())?;
        }
        f.write_all(&payload)?;
        f.sync_data()?;
    }
    fs::rename(&tmp, checkpoint_path(dir, id))
}

/// Load and validate a checkpoint: `Some((step, data))` only when the
/// magic, version, request binding, length, and payload checksum all
/// match. Any mismatch removes the file (it can only mislead).
pub fn load_checkpoint(dir: &Path, id: u64, req_sum: u64, len: usize) -> Option<(usize, Vec<f32>)> {
    let path = checkpoint_path(dir, id);
    let loaded = read_validated(&path, id, req_sum, len);
    if loaded.is_none() {
        let _ = fs::remove_file(&path);
    }
    loaded
}

fn read_validated(path: &Path, id: u64, req_sum: u64, len: usize) -> Option<(usize, Vec<f32>)> {
    let mut f = File::open(path).ok()?;
    let mut header = [0u64; HEADER_WORDS];
    let mut word = [0u8; 8];
    for w in header.iter_mut() {
        f.read_exact(&mut word).ok()?;
        *w = u64::from_le_bytes(word);
    }
    let [magic, version, file_id, step, file_len, file_sum, pay_sum] = header;
    if magic != CHECKPOINT_MAGIC
        || version != CHECKPOINT_VERSION
        || file_id != id
        || file_sum != req_sum
        || file_len as usize != len
    {
        return None;
    }
    let mut payload = vec![0u8; len * 4];
    f.read_exact(&mut payload).ok()?;
    if payload_checksum(&payload) != pay_sum {
        return None;
    }
    let data = payload
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    Some((step as usize, data))
}

/// Drop a request's checkpoint (request finished or was resolved).
pub fn remove_checkpoint(dir: &Path, id: u64) {
    let _ = fs::remove_file(checkpoint_path(dir, id));
}
