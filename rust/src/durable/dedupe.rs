//! Bounded dedupe sets for at-least-once delivery.
//!
//! [`BoundedDedupe`] caps the worker-side wire-id dedupe set (PR 9 left
//! it implicit in the registry, growing per submit): a capacity bound
//! with insertion-order eviction plus a TTL, so a dropped-ack retry
//! inside the window still dedupes while the set stays O(cap).
//!
//! [`IdemKeys`] is the router-side `Idempotency-Key` -> request-id map,
//! same capped insertion-order discipline (first write wins; the journal
//! is the durable copy, this is the hot-path view).

use std::collections::{HashMap, VecDeque};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Capped, TTL-bounded id set. `insert_at`/`contains_at` take an explicit
/// clock so property tests drive time deterministically.
pub struct BoundedDedupe {
    cap: usize,
    ttl: Duration,
    inner: Mutex<DedupeInner>,
}

struct DedupeInner {
    map: HashMap<u64, Instant>,
    order: VecDeque<(u64, Instant)>,
}

impl BoundedDedupe {
    pub fn new(cap: usize, ttl: Duration) -> BoundedDedupe {
        BoundedDedupe {
            cap: cap.max(1),
            ttl,
            inner: Mutex::new(DedupeInner { map: HashMap::new(), order: VecDeque::new() }),
        }
    }

    pub fn insert(&self, id: u64) {
        self.insert_at(id, Instant::now());
    }

    pub fn contains(&self, id: u64) -> bool {
        self.contains_at(id, Instant::now())
    }

    pub fn insert_at(&self, id: u64, now: Instant) {
        let mut g = self.inner.lock().unwrap();
        // Evict: capacity overflow (oldest first) and expired entries.
        while g.order.len() >= self.cap
            || g.order
                .front()
                .is_some_and(|&(_, at)| now.saturating_duration_since(at) > self.ttl)
        {
            let Some((old, at)) = g.order.pop_front() else { break };
            // A re-inserted id has a fresher stamp in the map; only drop
            // the map entry when this order entry is its current one.
            if g.map.get(&old) == Some(&at) {
                g.map.remove(&old);
            }
        }
        g.map.insert(id, now);
        g.order.push_back((id, now));
    }

    pub fn contains_at(&self, id: u64, now: Instant) -> bool {
        self.inner
            .lock()
            .unwrap()
            .map
            .get(&id)
            .is_some_and(|&at| now.saturating_duration_since(at) <= self.ttl)
    }

    /// Live (unexpired-by-eviction) entries; an upper bound on distinct ids.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Capped `Idempotency-Key` -> request-id map; first write wins.
pub struct IdemKeys {
    cap: usize,
    inner: Mutex<(HashMap<String, u64>, VecDeque<String>)>,
}

impl IdemKeys {
    pub fn new(cap: usize) -> IdemKeys {
        IdemKeys {
            cap: cap.max(1),
            inner: Mutex::new((HashMap::new(), VecDeque::new())),
        }
    }

    pub fn get(&self, key: &str) -> Option<u64> {
        self.inner.lock().unwrap().0.get(key).copied()
    }

    /// Record `key -> id` unless the key is already mapped (first wins).
    pub fn put(&self, key: &str, id: u64) {
        let mut g = self.inner.lock().unwrap();
        let (map, order) = &mut *g;
        if map.contains_key(key) {
            return;
        }
        while map.len() >= self.cap {
            let Some(old) = order.pop_front() else { break };
            map.remove(&old);
        }
        map.insert(key.to_string(), id);
        order.push_back(key.to_string());
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().0.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}
