//! Durable control plane: write-ahead journal, crash recovery, standby
//! tailing, latent checkpoints, and bounded dedupe.
//!
//! The router journals every externally visible state transition —
//! request accepted/placed/running/terminal, member announce, session
//! open/round/owner/close, template register/retire — *before*
//! acknowledging it. A restarted router folds snapshot + journal back
//! into a [`RecoveredState`] and adopts it: accepted work is re-placed
//! (worker-side wire-id dedupe makes re-submission safe), in-flight work
//! reconciles against `/rpc/poll`, and no accepted request is lost. A
//! warm standby tails the same stream over `GET /rpc/journal/tail` and
//! takes over on primary silence.

pub mod checkpoint;
pub mod dedupe;
pub mod journal;
pub mod recover;
pub mod signals;

pub use checkpoint::{
    checkpoint_path, load_checkpoint, remove_checkpoint, request_checksum, save_checkpoint,
};
pub use dedupe::{BoundedDedupe, IdemKeys};
pub use journal::{FsyncPolicy, Journal, JournalConfig, JournalReplay};
pub use recover::{RecoveredMember, RecoveredRequest, RecoveredSession, RecoveredState};
pub use signals::{install_shutdown_handler, shutdown_requested, trigger_shutdown};

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::dist::proto::SubmitWire;
use crate::util::json::Json;

/// Records the standby tail endpoint serves from memory before falling
/// back to a full snapshot resync.
const RING_CAP: usize = 4096;

/// The journal plus the state mirror compaction snapshots serialize and
/// an in-memory ring serving the standby tail without file reads.
pub struct DurableLog {
    inner: Mutex<LogInner>,
}

struct LogInner {
    journal: Journal,
    mirror: RecoveredState,
    ring: VecDeque<(u64, Json)>,
    since_snapshot: u64,
}

impl DurableLog {
    /// Open the journal and fold what is on disk into a [`RecoveredState`]
    /// for the caller to adopt.
    pub fn open(cfg: JournalConfig) -> Result<(Arc<DurableLog>, RecoveredState)> {
        let (journal, replay) = Journal::open(cfg)?;
        let state = RecoveredState::from_journal(replay.snapshot.as_ref(), &replay.records);
        let log = Arc::new(DurableLog {
            inner: Mutex::new(LogInner {
                journal,
                mirror: state.clone(),
                ring: VecDeque::new(),
                since_snapshot: 0,
            }),
        });
        Ok((log, state))
    }

    /// Append one record, mirror it, and compact on schedule. Journal I/O
    /// errors are reported, not propagated: an unwritable journal degrades
    /// durability, never availability.
    pub fn record(&self, rec: Json) {
        let mut g = self.inner.lock().unwrap();
        let seq = match g.journal.append(&rec) {
            Ok(seq) => seq,
            Err(e) => {
                eprintln!("[durable] journal append failed: {e:#}");
                return;
            }
        };
        g.mirror.apply(seq, &rec);
        g.ring.push_back((seq, rec));
        while g.ring.len() > RING_CAP {
            g.ring.pop_front();
        }
        g.since_snapshot += 1;
        if g.since_snapshot >= g.journal.config().snapshot_every {
            g.since_snapshot = 0;
            let snap = g.mirror.to_snapshot_json();
            if let Err(e) = g.journal.snapshot(&snap) {
                eprintln!("[durable] snapshot compaction failed: {e:#}");
            }
        }
    }

    /// Force everything to the platter (shutdown path).
    pub fn flush(&self) {
        if let Err(e) = self.inner.lock().unwrap().journal.flush() {
            eprintln!("[durable] journal flush failed: {e:#}");
        }
    }

    pub fn last_seq(&self) -> u64 {
        self.inner.lock().unwrap().journal.last_seq()
    }

    /// Serve a standby's tail request: records with `seq >= from` when
    /// the ring still holds them, else a full snapshot to resync from.
    pub fn tail(&self, from: u64) -> Json {
        let g = self.inner.lock().unwrap();
        let last = g.journal.last_seq();
        if from > last {
            return Json::obj(vec![
                ("last_seq", Json::num(last as f64)),
                ("records", Json::arr(vec![])),
            ]);
        }
        if let Some(&(front, _)) = g.ring.front() {
            if front <= from {
                let records = g
                    .ring
                    .iter()
                    .filter(|(s, _)| *s >= from)
                    .map(|(s, r)| {
                        Json::obj(vec![("seq", Json::num(*s as f64)), ("rec", r.clone())])
                    })
                    .collect();
                return Json::obj(vec![
                    ("last_seq", Json::num(last as f64)),
                    ("records", Json::arr(records)),
                ]);
            }
        }
        Json::obj(vec![
            ("last_seq", Json::num(last as f64)),
            ("snapshot_seq", Json::num(last as f64)),
            ("snapshot", g.mirror.to_snapshot_json()),
            ("records", Json::arr(vec![])),
        ])
    }

    /// Seed this (standby's) journal with an adopted state at takeover:
    /// the sequence counter jumps to continue the primary's logical
    /// stream, then the state is compacted in as the recovery base.
    pub fn adopt_state(&self, state: &RecoveredState) {
        let mut g = self.inner.lock().unwrap();
        g.mirror = state.clone();
        g.ring.clear();
        g.since_snapshot = 0;
        if let Err(e) = g.journal.advance_to(state.last_seq + 1) {
            eprintln!("[durable] journal advance failed: {e:#}");
        }
        let snap = g.mirror.to_snapshot_json();
        if let Err(e) = g.journal.snapshot(&snap) {
            eprintln!("[durable] adoption snapshot failed: {e:#}");
        }
    }
}

// -- record constructors (the journal's write-side schema) ------------------

pub fn rec_req_accepted(wire: &SubmitWire, idem: Option<&str>) -> Json {
    let mut pairs = vec![
        ("t", Json::str("req")),
        ("st", Json::str("accepted")),
        ("id", Json::num(wire.id as f64)),
        ("wire", wire.to_json()),
    ];
    if let Some(key) = idem {
        pairs.push(("idem", Json::str(key)));
    }
    Json::obj(pairs)
}

pub fn rec_req_placed(id: u64, slot: usize) -> Json {
    Json::obj(vec![
        ("t", Json::str("req")),
        ("st", Json::str("placed")),
        ("id", Json::num(id as f64)),
        ("slot", Json::num(slot as f64)),
    ])
}

/// `st` is one of `running` / `done` / `failed` / `cancelled`.
pub fn rec_req_state(id: u64, st: &str) -> Json {
    Json::obj(vec![
        ("t", Json::str("req")),
        ("st", Json::str(st)),
        ("id", Json::num(id as f64)),
    ])
}

pub fn rec_member(name: &str, addr: &str, slot: usize, epoch: u64) -> Json {
    Json::obj(vec![
        ("t", Json::str("member")),
        ("st", Json::str("announce")),
        ("name", Json::str(name)),
        ("addr", Json::str(addr)),
        ("slot", Json::num(slot as f64)),
        ("epoch", Json::num(epoch as f64)),
    ])
}

pub fn rec_session_open(sid: u64, template: &str) -> Json {
    Json::obj(vec![
        ("t", Json::str("session")),
        ("st", Json::str("open")),
        ("sid", Json::num(sid as f64)),
        ("template", Json::str(template)),
    ])
}

pub fn rec_session_round(sid: u64, rid: u64) -> Json {
    Json::obj(vec![
        ("t", Json::str("session")),
        ("st", Json::str("round")),
        ("sid", Json::num(sid as f64)),
        ("rid", Json::num(rid as f64)),
    ])
}

pub fn rec_session_owner(sid: u64, slot: usize, epoch: u64) -> Json {
    Json::obj(vec![
        ("t", Json::str("session")),
        ("st", Json::str("owner")),
        ("sid", Json::num(sid as f64)),
        ("slot", Json::num(slot as f64)),
        ("epoch", Json::num(epoch as f64)),
    ])
}

pub fn rec_session_close(sid: u64) -> Json {
    Json::obj(vec![
        ("t", Json::str("session")),
        ("st", Json::str("close")),
        ("sid", Json::num(sid as f64)),
    ])
}

/// `st` is the template lifecycle label (`registering` / `retiring` ...).
pub fn rec_template(id: &str, st: &str) -> Json {
    Json::obj(vec![
        ("t", Json::str("template")),
        ("st", Json::str(st)),
        ("id", Json::str(id)),
    ])
}
