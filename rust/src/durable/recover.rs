//! Replayable control-plane state: the reduction of a journal stream.
//!
//! [`RecoveredState`] is used three ways: (1) cold recovery — a restarted
//! router folds snapshot + records into one and adopts it; (2) compaction
//! — the live router keeps a mirror updated on every append, so a
//! snapshot is just the mirror serialized (no live-registry traversal);
//! (3) warm standby — the standby folds the tailed record stream and
//! adopts the result at takeover. All three paths run the same `apply`,
//! so they cannot drift.

use std::collections::BTreeMap;

use crate::dist::proto::SubmitWire;
use crate::util::json::Json;

/// One membership slot as the journal last saw it. Slots are Vec indices
/// assigned in announce order, so replaying members in slot order
/// reproduces the slot assignment exactly — a re-announcing live worker
/// lands back on its old slot.
#[derive(Debug, Clone)]
pub struct RecoveredMember {
    pub name: String,
    pub addr: String,
    pub epoch: u64,
}

/// One accepted request's lifecycle as journaled.
#[derive(Debug, Clone)]
pub struct RecoveredRequest {
    pub wire: SubmitWire,
    /// Last slot the request was placed on (None: accepted, never placed).
    pub slot: Option<usize>,
    pub running: bool,
    /// Terminal state label (`done` / `failed` / `cancelled`), if reached.
    pub terminal: Option<String>,
    /// Idempotency key the request was accepted under, if any.
    pub idem: Option<String>,
}

impl RecoveredRequest {
    pub fn is_terminal(&self) -> bool {
        self.terminal.is_some()
    }
}

/// One session's lifecycle as journaled.
#[derive(Debug, Clone)]
pub struct RecoveredSession {
    pub template: String,
    pub closed: bool,
    pub epoch: u64,
    pub owner: Option<usize>,
    pub rounds: u64,
    /// Request ids of rounds that had not reached a terminal state.
    pub inflight: Vec<u64>,
}

/// The full reduction of a journal stream.
#[derive(Debug, Clone, Default)]
pub struct RecoveredState {
    pub last_seq: u64,
    pub next_request_id: u64,
    pub next_session_id: u64,
    pub members: Vec<RecoveredMember>,
    pub requests: BTreeMap<u64, RecoveredRequest>,
    pub sessions: BTreeMap<u64, RecoveredSession>,
    /// Template id -> last journaled state label.
    pub templates: BTreeMap<String, String>,
    /// Idempotency key -> original request id.
    pub idempotency: BTreeMap<String, u64>,
}

impl RecoveredState {
    pub fn new() -> RecoveredState {
        RecoveredState::default()
    }

    /// Fold snapshot (if any) + ordered records into one state.
    pub fn from_journal(snapshot: Option<&Json>, records: &[(u64, Json)]) -> RecoveredState {
        let mut st = snapshot.map(RecoveredState::from_snapshot_json).unwrap_or_default();
        for (seq, rec) in records {
            st.apply(*seq, rec);
        }
        st
    }

    /// Accepted-but-not-terminal request ids, ascending.
    pub fn pending_ids(&self) -> Vec<u64> {
        self.requests
            .iter()
            .filter(|(_, r)| !r.is_terminal())
            .map(|(&id, _)| id)
            .collect()
    }

    /// Apply one journal record. Unknown record shapes are ignored so an
    /// older standby can tail a newer primary without wedging.
    pub fn apply(&mut self, seq: u64, rec: &Json) {
        self.last_seq = self.last_seq.max(seq);
        match rec.at("t").as_str().unwrap_or("") {
            "req" => self.apply_req(rec),
            "member" => self.apply_member(rec),
            "session" => self.apply_session(rec),
            "template" => {
                if let (Some(id), Some(st)) =
                    (rec.at("id").as_str(), rec.at("st").as_str())
                {
                    self.templates.insert(id.to_string(), st.to_string());
                }
            }
            _ => {}
        }
    }

    fn apply_req(&mut self, rec: &Json) {
        let Some(id) = rec.at("id").as_f64().map(|x| x as u64) else { return };
        match rec.at("st").as_str().unwrap_or("") {
            "accepted" => {
                let Some(wire) = SubmitWire::parse(rec.at("wire")) else { return };
                let idem = rec.at("idem").as_str().map(String::from);
                if let Some(key) = &idem {
                    self.idempotency.insert(key.clone(), id);
                }
                self.next_request_id = self.next_request_id.max(id + 1);
                self.requests.insert(
                    id,
                    RecoveredRequest { wire, slot: None, running: false, terminal: None, idem },
                );
            }
            "placed" => {
                if let (Some(r), Some(slot)) =
                    (self.requests.get_mut(&id), rec.at("slot").as_usize())
                {
                    r.slot = Some(slot);
                }
            }
            "running" => {
                if let Some(r) = self.requests.get_mut(&id) {
                    r.running = true;
                }
            }
            st @ ("done" | "failed" | "cancelled") => {
                let sid = match self.requests.get_mut(&id) {
                    Some(r) => {
                        r.terminal = Some(st.to_string());
                        r.wire.session
                    }
                    None => None,
                };
                if let Some(sid) = sid {
                    if let Some(s) = self.sessions.get_mut(&sid) {
                        s.inflight.retain(|&rid| rid != id);
                    }
                }
            }
            _ => {}
        }
    }

    fn apply_member(&mut self, rec: &Json) {
        let (Some(slot), Some(name), Some(addr)) = (
            rec.at("slot").as_usize(),
            rec.at("name").as_str(),
            rec.at("addr").as_str(),
        ) else {
            return;
        };
        let epoch = rec.at("epoch").as_f64().unwrap_or(1.0) as u64;
        while self.members.len() <= slot {
            self.members.push(RecoveredMember {
                name: String::new(),
                addr: String::new(),
                epoch: 0,
            });
        }
        self.members[slot] =
            RecoveredMember { name: name.to_string(), addr: addr.to_string(), epoch };
    }

    fn apply_session(&mut self, rec: &Json) {
        let Some(sid) = rec.at("sid").as_f64().map(|x| x as u64) else { return };
        match rec.at("st").as_str().unwrap_or("") {
            "open" => {
                let template = rec.at("template").as_str().unwrap_or("").to_string();
                self.next_session_id = self.next_session_id.max(sid + 1);
                self.sessions.insert(
                    sid,
                    RecoveredSession {
                        template,
                        closed: false,
                        epoch: 0,
                        owner: None,
                        rounds: 0,
                        inflight: Vec::new(),
                    },
                );
            }
            "round" => {
                if let (Some(s), Some(rid)) = (
                    self.sessions.get_mut(&sid),
                    rec.at("rid").as_f64().map(|x| x as u64),
                ) {
                    s.rounds += 1;
                    s.inflight.push(rid);
                }
            }
            "owner" => {
                if let Some(s) = self.sessions.get_mut(&sid) {
                    s.owner = rec.at("slot").as_usize();
                    s.epoch = rec.at("epoch").as_f64().unwrap_or(0.0) as u64;
                }
            }
            "close" => {
                if let Some(s) = self.sessions.get_mut(&sid) {
                    s.closed = true;
                }
            }
            _ => {}
        }
    }

    // -- snapshot (de)serialization -----------------------------------------

    pub fn to_snapshot_json(&self) -> Json {
        let members = self
            .members
            .iter()
            .map(|m| {
                Json::obj(vec![
                    ("name", Json::str(m.name.clone())),
                    ("addr", Json::str(m.addr.clone())),
                    ("epoch", Json::num(m.epoch as f64)),
                ])
            })
            .collect();
        let requests = self
            .requests
            .iter()
            .map(|(&id, r)| {
                let mut pairs = vec![
                    ("id", Json::num(id as f64)),
                    ("wire", r.wire.to_json()),
                    ("running", Json::Bool(r.running)),
                ];
                if let Some(slot) = r.slot {
                    pairs.push(("slot", Json::num(slot as f64)));
                }
                if let Some(t) = &r.terminal {
                    pairs.push(("terminal", Json::str(t.clone())));
                }
                if let Some(k) = &r.idem {
                    pairs.push(("idem", Json::str(k.clone())));
                }
                Json::obj(pairs)
            })
            .collect();
        let sessions = self
            .sessions
            .iter()
            .map(|(&sid, s)| {
                let mut pairs = vec![
                    ("sid", Json::num(sid as f64)),
                    ("template", Json::str(s.template.clone())),
                    ("closed", Json::Bool(s.closed)),
                    ("epoch", Json::num(s.epoch as f64)),
                    ("rounds", Json::num(s.rounds as f64)),
                    (
                        "inflight",
                        Json::arr(s.inflight.iter().map(|&r| Json::num(r as f64)).collect()),
                    ),
                ];
                if let Some(owner) = s.owner {
                    pairs.push(("owner", Json::num(owner as f64)));
                }
                Json::obj(pairs)
            })
            .collect();
        let templates = self
            .templates
            .iter()
            .map(|(id, st)| {
                Json::obj(vec![
                    ("id", Json::str(id.clone())),
                    ("state", Json::str(st.clone())),
                ])
            })
            .collect();
        let idempotency = self
            .idempotency
            .iter()
            .map(|(k, &id)| {
                Json::obj(vec![
                    ("key", Json::str(k.clone())),
                    ("id", Json::num(id as f64)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("last_seq", Json::num(self.last_seq as f64)),
            ("next_request_id", Json::num(self.next_request_id as f64)),
            ("next_session_id", Json::num(self.next_session_id as f64)),
            ("members", Json::arr(members)),
            ("requests", Json::arr(requests)),
            ("sessions", Json::arr(sessions)),
            ("templates", Json::arr(templates)),
            ("idempotency", Json::arr(idempotency)),
        ])
    }

    pub fn from_snapshot_json(j: &Json) -> RecoveredState {
        let mut st = RecoveredState {
            last_seq: j.at("last_seq").as_f64().unwrap_or(0.0) as u64,
            next_request_id: j.at("next_request_id").as_f64().unwrap_or(0.0) as u64,
            next_session_id: j.at("next_session_id").as_f64().unwrap_or(0.0) as u64,
            ..RecoveredState::default()
        };
        for m in j.at("members").as_arr().unwrap_or(&[]) {
            st.members.push(RecoveredMember {
                name: m.at("name").as_str().unwrap_or("").to_string(),
                addr: m.at("addr").as_str().unwrap_or("").to_string(),
                epoch: m.at("epoch").as_f64().unwrap_or(1.0) as u64,
            });
        }
        for r in j.at("requests").as_arr().unwrap_or(&[]) {
            let (Some(id), Some(wire)) = (
                r.at("id").as_f64().map(|x| x as u64),
                SubmitWire::parse(r.at("wire")),
            ) else {
                continue;
            };
            st.requests.insert(
                id,
                RecoveredRequest {
                    wire,
                    slot: r.at("slot").as_usize(),
                    running: r.at("running").as_bool().unwrap_or(false),
                    terminal: r.at("terminal").as_str().map(String::from),
                    idem: r.at("idem").as_str().map(String::from),
                },
            );
        }
        for s in j.at("sessions").as_arr().unwrap_or(&[]) {
            let Some(sid) = s.at("sid").as_f64().map(|x| x as u64) else { continue };
            st.sessions.insert(
                sid,
                RecoveredSession {
                    template: s.at("template").as_str().unwrap_or("").to_string(),
                    closed: s.at("closed").as_bool().unwrap_or(false),
                    epoch: s.at("epoch").as_f64().unwrap_or(0.0) as u64,
                    owner: s.at("owner").as_usize(),
                    rounds: s.at("rounds").as_f64().unwrap_or(0.0) as u64,
                    inflight: s
                        .at("inflight")
                        .as_arr()
                        .map(|v| v.iter().filter_map(|x| x.as_f64().map(|x| x as u64)).collect())
                        .unwrap_or_default(),
                },
            );
        }
        for t in j.at("templates").as_arr().unwrap_or(&[]) {
            if let (Some(id), Some(state)) = (t.at("id").as_str(), t.at("state").as_str()) {
                st.templates.insert(id.to_string(), state.to_string());
            }
        }
        for e in j.at("idempotency").as_arr().unwrap_or(&[]) {
            if let (Some(key), Some(id)) =
                (e.at("key").as_str(), e.at("id").as_f64().map(|x| x as u64))
            {
                st.idempotency.insert(key.to_string(), id);
            }
        }
        st
    }
}
