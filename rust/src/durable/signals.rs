//! Graceful-shutdown signal plumbing (no libc dependency).
//!
//! `install_shutdown_handler` points SIGINT/SIGTERM at a handler that
//! sets a process-wide flag; serve loops poll [`shutdown_requested`] and
//! run their drain path (stop accepting, finish the running batch at a
//! step boundary, flush the journal) instead of dying mid-batch.

use std::sync::atomic::{AtomicBool, Ordering};

static SHUTDOWN: AtomicBool = AtomicBool::new(false);

/// Has a shutdown signal (or [`trigger_shutdown`]) been seen?
pub fn shutdown_requested() -> bool {
    SHUTDOWN.load(Ordering::SeqCst)
}

/// Programmatic equivalent of receiving SIGTERM (tests, embedding).
pub fn trigger_shutdown() {
    SHUTDOWN.store(true, Ordering::SeqCst);
}

#[cfg(unix)]
extern "C" fn on_signal(_sig: i32) {
    // async-signal-safe: a relaxed-store-free atomic flag set, nothing else
    SHUTDOWN.store(true, Ordering::SeqCst);
}

/// Route SIGINT (2) and SIGTERM (15) to the shutdown flag. Idempotent.
#[cfg(unix)]
pub fn install_shutdown_handler() {
    unsafe extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }
    unsafe {
        signal(2, on_signal); // SIGINT
        signal(15, on_signal); // SIGTERM
    }
}

/// Non-unix: no signal plumbing; [`trigger_shutdown`] still works.
#[cfg(not(unix))]
pub fn install_shutdown_handler() {}
