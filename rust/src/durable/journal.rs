//! Checksummed, segmented write-ahead journal for the control plane.
//!
//! Line-oriented: each record is `"<seq> <checksum-hex> <json>\n"` where
//! the checksum is FNV-1a over the sequence number and the JSON body,
//! finalized with splitmix64. Segments (`seg-<first_seq>.wal`) rotate at
//! `segment_bytes`; [`Journal::snapshot`] compacts the log by writing the
//! caller's state snapshot (`snapshot-<last_seq>.json`, atomic tmp+rename)
//! and deleting every older segment and snapshot.
//!
//! Replay tolerates a torn tail: the first malformed line, checksum
//! mismatch, or sequence gap ends the replay — records past a tear were
//! never acknowledged, so dropping them preserves the write-ahead
//! contract — and the journal resumes appending into a *fresh* segment so
//! a torn line is never extended.

use std::fs::{self, File};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::util::json::Json;
use crate::util::rng::splitmix64;

/// When acknowledged appends reach the disk platter.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FsyncPolicy {
    /// fsync after every append — zero loss on host power-cut, slowest.
    Always,
    /// fsync at most every `batch_ms` / 256 appends (default): bounded
    /// loss window on power-cut, none on process crash (appends always
    /// reach the OS page cache before being acknowledged).
    #[default]
    Batched,
    /// Never fsync — process-crash durability only.
    Off,
}

impl FsyncPolicy {
    pub fn parse(s: &str) -> Option<FsyncPolicy> {
        match s {
            "always" => Some(FsyncPolicy::Always),
            "batched" => Some(FsyncPolicy::Batched),
            "off" | "none" => Some(FsyncPolicy::Off),
            _ => None,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            FsyncPolicy::Always => "always",
            FsyncPolicy::Batched => "batched",
            FsyncPolicy::Off => "off",
        }
    }
}

/// Batched policy syncs at this many unsynced appends even if the time
/// window has not elapsed.
const BATCH_RECORDS: u64 = 256;

/// Journal location and durability knobs.
#[derive(Debug, Clone)]
pub struct JournalConfig {
    pub dir: PathBuf,
    pub fsync: FsyncPolicy,
    /// Rotate to a new segment once the current one reaches this size.
    pub segment_bytes: u64,
    /// Compact (snapshot + drop old segments) every this many records.
    pub snapshot_every: u64,
    /// Max time an acknowledged append stays unsynced under `Batched`.
    pub batch_ms: u64,
}

impl JournalConfig {
    pub fn new(dir: impl Into<PathBuf>) -> JournalConfig {
        JournalConfig {
            dir: dir.into(),
            fsync: FsyncPolicy::Batched,
            segment_bytes: 1 << 20,
            snapshot_every: 4096,
            batch_ms: 20,
        }
    }
}

/// What [`Journal::open`] recovered from disk: the newest intact snapshot
/// (if any) plus every intact record after it, in sequence order.
#[derive(Debug)]
pub struct JournalReplay {
    pub snapshot: Option<Json>,
    pub snapshot_seq: u64,
    pub records: Vec<(u64, Json)>,
}

/// Append-only journal writer. Single-owner: callers serialize access
/// (the control plane wraps it in a mutex).
pub struct Journal {
    cfg: JournalConfig,
    writer: BufWriter<File>,
    seg_path: PathBuf,
    seg_bytes: u64,
    next_seq: u64,
    unsynced: u64,
    last_sync: Instant,
}

impl Journal {
    /// Open (creating the directory if needed), replay what is on disk,
    /// and position the writer on a fresh segment at the next sequence
    /// number. Sequence numbers start at 1; 0 means "nothing recorded".
    pub fn open(cfg: JournalConfig) -> Result<(Journal, JournalReplay)> {
        fs::create_dir_all(&cfg.dir)
            .with_context(|| format!("creating journal dir {}", cfg.dir.display()))?;

        // Newest intact snapshot wins; corrupt ones fall back to older.
        let mut snapshot = None;
        let mut snapshot_seq = 0;
        for (seq, path) in list(&cfg.dir, "snapshot-", ".json").into_iter().rev() {
            if let Ok(text) = fs::read_to_string(&path) {
                if let Ok(j) = Json::parse(&text) {
                    snapshot = Some(j);
                    snapshot_seq = seq;
                    break;
                }
            }
        }

        // Replay segments in order; stop at the first tear or gap. A torn
        // segment is truncated back to its valid prefix so the garbage does
        // not mask records appended to later segments after this recovery.
        let mut records = Vec::new();
        let mut expect = snapshot_seq + 1;
        'replay: for (_first, path) in list(&cfg.dir, "seg-", ".wal") {
            let Ok(file) = File::open(&path) else { break };
            let mut valid = 0u64; // byte length of the intact line prefix
            for line in BufReader::new(file).lines() {
                let Ok(line) = line else {
                    truncate_to(&path, valid);
                    break 'replay;
                };
                match parse_line(&line) {
                    _ if line.is_empty() => {}
                    Some((seq, _)) if seq < expect => {} // covered by snapshot
                    Some((seq, rec)) if seq == expect => {
                        records.push((seq, rec));
                        expect += 1;
                    }
                    _ => {
                        // torn tail, corruption, or gap
                        truncate_to(&path, valid);
                        break 'replay;
                    }
                }
                valid += line.len() as u64 + 1;
            }
        }

        let next_seq = expect;
        let seg_path = cfg.dir.join(segment_name(next_seq));
        let file = File::create(&seg_path)
            .with_context(|| format!("creating journal segment {}", seg_path.display()))?;
        let journal = Journal {
            cfg,
            writer: BufWriter::new(file),
            seg_path,
            seg_bytes: 0,
            next_seq,
            unsynced: 0,
            last_sync: Instant::now(),
        };
        Ok((journal, JournalReplay { snapshot, snapshot_seq, records }))
    }

    pub fn config(&self) -> &JournalConfig {
        &self.cfg
    }

    /// Highest sequence number written (0 when empty).
    pub fn last_seq(&self) -> u64 {
        self.next_seq - 1
    }

    /// Append one record. The line always reaches the OS before the call
    /// returns (process-crash durability); the fsync policy decides when
    /// it reaches the platter.
    pub fn append(&mut self, rec: &Json) -> Result<u64> {
        let seq = self.next_seq;
        let body = rec.to_string();
        let sum = line_checksum(seq, &body);
        let line = format!("{seq} {sum:016x} {body}\n");
        self.writer.write_all(line.as_bytes()).context("journal write")?;
        self.writer.flush().context("journal flush")?;
        self.next_seq += 1;
        self.seg_bytes += line.len() as u64;
        self.unsynced += 1;
        match self.cfg.fsync {
            FsyncPolicy::Always => self.sync()?,
            FsyncPolicy::Batched => {
                if self.unsynced >= BATCH_RECORDS
                    || self.last_sync.elapsed().as_millis() as u64 >= self.cfg.batch_ms
                {
                    self.sync()?;
                }
            }
            FsyncPolicy::Off => {}
        }
        if self.seg_bytes >= self.cfg.segment_bytes {
            self.rotate()?;
        }
        Ok(seq)
    }

    /// Flush buffered lines and fsync regardless of policy (shutdown path).
    pub fn flush(&mut self) -> Result<()> {
        self.writer.flush().context("journal flush")?;
        self.sync()
    }

    /// Compact: persist `state` (which must reflect every record up to
    /// `last_seq`) as the new recovery base, rotate to a fresh segment,
    /// and delete everything the snapshot covers.
    pub fn snapshot(&mut self, state: &Json) -> Result<()> {
        let last = self.last_seq();
        let tmp = self.cfg.dir.join(format!("tmp-snap-{}", std::process::id()));
        {
            let mut f = File::create(&tmp).context("snapshot tmp create")?;
            f.write_all(state.to_string().as_bytes()).context("snapshot write")?;
            f.sync_data().context("snapshot sync")?;
        }
        fs::rename(&tmp, self.cfg.dir.join(snapshot_name(last))).context("snapshot rename")?;
        self.rotate()?;
        for (_seq, path) in list(&self.cfg.dir, "seg-", ".wal") {
            if path != self.seg_path {
                let _ = fs::remove_file(path);
            }
        }
        for (seq, path) in list(&self.cfg.dir, "snapshot-", ".json") {
            if seq < last {
                let _ = fs::remove_file(path);
            }
        }
        Ok(())
    }

    /// Jump the sequence counter forward (standby takeover: continue the
    /// primary's logical stream instead of restarting at 1). No-op when
    /// `next` is not ahead.
    pub fn advance_to(&mut self, next: u64) -> Result<()> {
        if next > self.next_seq {
            self.next_seq = next;
            self.rotate()?;
        }
        Ok(())
    }

    fn sync(&mut self) -> Result<()> {
        self.writer.get_ref().sync_data().context("journal fsync")?;
        self.unsynced = 0;
        self.last_sync = Instant::now();
        Ok(())
    }

    fn rotate(&mut self) -> Result<()> {
        self.writer.flush().context("journal flush")?;
        self.writer.get_ref().sync_data().context("journal fsync")?;
        let path = self.cfg.dir.join(segment_name(self.next_seq));
        let file = File::create(&path)
            .with_context(|| format!("creating journal segment {}", path.display()))?;
        self.writer = BufWriter::new(file);
        self.seg_path = path;
        self.seg_bytes = 0;
        Ok(())
    }
}

/// FNV-1a over the sequence number and record body, splitmix64-finalized.
pub fn line_checksum(seq: u64, body: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in seq.to_le_bytes() {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    for &b in body.as_bytes() {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    splitmix64(h)
}

fn segment_name(first_seq: u64) -> String {
    format!("seg-{first_seq:012}.wal")
}

fn snapshot_name(last_seq: u64) -> String {
    format!("snapshot-{last_seq:012}.json")
}

/// Best-effort repair of a torn segment: drop everything past the intact
/// prefix so stale bytes cannot mask records in later segments.
fn truncate_to(path: &Path, len: u64) {
    if let Ok(file) = fs::OpenOptions::new().write(true).open(path) {
        let _ = file.set_len(len);
    }
}

fn parse_line(line: &str) -> Option<(u64, Json)> {
    let mut it = line.splitn(3, ' ');
    let seq: u64 = it.next()?.parse().ok()?;
    let sum = u64::from_str_radix(it.next()?, 16).ok()?;
    let body = it.next()?;
    if line_checksum(seq, body) != sum {
        return None;
    }
    Some((seq, Json::parse(body).ok()?))
}

/// `(seq, path)` pairs for `<prefix><seq><suffix>` files, sequence-sorted.
fn list(dir: &Path, prefix: &str, suffix: &str) -> Vec<(u64, PathBuf)> {
    let mut out = Vec::new();
    if let Ok(rd) = fs::read_dir(dir) {
        for entry in rd.flatten() {
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            if let Some(mid) = name.strip_prefix(prefix).and_then(|r| r.strip_suffix(suffix)) {
                if let Ok(seq) = mid.parse::<u64>() {
                    out.push((seq, entry.path()));
                }
            }
        }
    }
    out.sort();
    out
}
