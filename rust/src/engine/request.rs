//! Request/response types and per-request lifecycle timing.

use std::time::Instant;

use crate::model::MaskSpec;
use crate::util::tensor::Tensor;

/// An image-editing request (paper §2.1: template + mask + conditions).
#[derive(Debug, Clone)]
pub struct EditRequest {
    pub id: u64,
    /// Image template to edit; its activations may already be cached.
    pub template_id: String,
    /// The edit mask (token ids to regenerate).
    pub mask: MaskSpec,
    /// Seed deriving the conditioning vector (the "prompt").
    pub prompt_seed: u64,
    /// Arrival time at the system boundary.
    pub arrival: Instant,
}

impl EditRequest {
    pub fn new(id: u64, template_id: impl Into<String>, mask: MaskSpec, prompt_seed: u64) -> Self {
        EditRequest {
            id,
            template_id: template_id.into(),
            mask,
            prompt_seed,
            arrival: Instant::now(),
        }
    }
}

/// Lifecycle timing of one served request (all in seconds).
#[derive(Debug, Clone, Default)]
pub struct RequestTiming {
    /// arrival -> joined the running batch (paper's queuing time).
    pub queue: f64,
    /// joined -> last denoise step done (model inference latency).
    pub inference: f64,
    /// arrival -> response ready (end-to-end latency, Fig. 12's metric).
    pub e2e: f64,
    /// Times the member's denoising was interrupted by CPU-bound
    /// pre/post-processing on the engine thread (§6.4 microbenchmark).
    pub interruptions: u32,
    /// Denoise steps executed (TeaCache skips reduce this).
    pub steps_computed: u32,
}

/// The served result.
#[derive(Debug, Clone)]
pub struct EditResponse {
    pub id: u64,
    pub template_id: String,
    /// Decoded "image": (L, C) patch tensor.
    pub image: Tensor,
    /// Final latent (L, H) — kept for quality evaluation (Table 2).
    pub latent: Tensor,
    pub timing: RequestTiming,
    pub mask_ratio: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_construction() {
        let m = MaskSpec::new(vec![0, 1], 16);
        let r = EditRequest::new(1, "tpl", m, 99);
        assert_eq!(r.template_id, "tpl");
        assert_eq!(r.mask.masked_count(), 2);
    }
}
