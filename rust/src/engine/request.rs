//! Request/response types, structured errors, and per-request lifecycle
//! timing.
//!
//! The request lifecycle is handle-based: `Cluster::submit` returns an
//! `EditTicket` (see [`crate::cluster::lifecycle`]) fulfilled by the
//! collector with either an [`EditResponse`] or a typed [`EditError`].
//! Workers report progress to the collector as [`WorkerEvent`]s.

use std::time::{Duration, Instant};

use crate::model::MaskSpec;
use crate::qos::Priority;
use crate::util::rng::Pcg;
use crate::util::tensor::Tensor;

/// RNG stream tag for synthesized masks (shared by CLI + HTTP frontends
/// so a given `prompt_seed` always derives the same mask).
pub const MASK_STREAM: u64 = 0x6d61_736b; // "mask"

/// An image-editing request (paper §2.1: template + mask + conditions).
#[derive(Debug, Clone)]
pub struct EditRequest {
    pub id: u64,
    /// Image template to edit; its activations may already be cached.
    pub template_id: String,
    /// The edit mask (token ids to regenerate).
    pub mask: MaskSpec,
    /// Seed deriving the conditioning vector (the "prompt").
    pub prompt_seed: u64,
    /// Arrival time at the system boundary.
    pub arrival: Instant,
    /// Request class: orders worker queues and drives preemption.
    pub priority: Priority,
    /// Optional completion deadline. Expires the request while it is
    /// still queued ([`EditError::DeadlineExceeded`]) and gates admission
    /// ([`EditError::DeadlineInfeasible`]); running members are never
    /// killed by it.
    pub deadline: Option<Instant>,
    /// The interactive editing session this request is a round of, if
    /// any. Session rounds route with sticky affinity (the owner's tiers
    /// are warm) and publish step-progress events; plain requests carry
    /// `None` and behave exactly as before.
    pub session: Option<u64>,
}

impl EditRequest {
    pub fn new(id: u64, template_id: impl Into<String>, mask: MaskSpec, prompt_seed: u64) -> Self {
        EditRequest {
            id,
            template_id: template_id.into(),
            mask,
            prompt_seed,
            arrival: Instant::now(),
            priority: Priority::default(),
            deadline: None,
            session: None,
        }
    }

    /// The deadline as milliseconds after arrival (as the client asked
    /// for it; status endpoints echo this).
    pub fn deadline_ms(&self) -> Option<u64> {
        self.deadline
            .map(|d| d.saturating_duration_since(self.arrival).as_millis() as u64)
    }
}

/// Lifecycle timing of one served request (all in seconds).
#[derive(Debug, Clone, Default)]
pub struct RequestTiming {
    /// arrival -> joined the running batch (paper's queuing time).
    pub queue: f64,
    /// joined -> last denoise step done (model inference latency).
    pub inference: f64,
    /// arrival -> response ready (end-to-end latency, Fig. 12's metric).
    pub e2e: f64,
    /// Times the member's denoising was interrupted by CPU-bound
    /// pre/post-processing on the engine thread (§6.4 microbenchmark).
    pub interruptions: u32,
    /// Denoise steps executed (TeaCache skips reduce this).
    pub steps_computed: u32,
}

/// The served result.
#[derive(Debug, Clone)]
pub struct EditResponse {
    pub id: u64,
    pub template_id: String,
    /// Decoded "image": (L, C) patch tensor.
    pub image: Tensor,
    /// Final latent (L, H) — kept for quality evaluation (Table 2).
    pub latent: Tensor,
    pub timing: RequestTiming,
    pub mask_ratio: f64,
    /// The request's class (per-class latency accounting).
    pub priority: Priority,
}

/// Why a request did not produce an [`EditResponse`]. Threaded from the
/// worker through the collector into the ticket, and mapped onto HTTP
/// status codes by the frontend.
#[derive(Debug, Clone, PartialEq, Eq, thiserror::Error)]
pub enum EditError {
    #[error("unknown template {0:?}")]
    UnknownTemplate(String),
    /// The template was retired (`DELETE /v1/templates/{{id}}`); in-flight
    /// edits drain, new ones are rejected until it is re-registered.
    #[error("template {0:?} is retired")]
    TemplateRetired(String),
    #[error("invalid mask: {0}")]
    InvalidMask(String),
    #[error("request cancelled")]
    Cancelled,
    #[error("timed out waiting for completion")]
    Timeout,
    /// Admission control shed the request: the cluster is over capacity
    /// for its class. Retry after the estimated drain time (the HTTP
    /// frontend maps this onto `429` + `Retry-After`).
    #[error("overloaded, retry after {retry_after_ms} ms")]
    Overloaded { retry_after_ms: u64 },
    /// The requested deadline cannot be met even on the best worker
    /// (estimated completion exceeds it), so the request is refused
    /// instead of admitted-to-fail.
    #[error("deadline infeasible: {0}")]
    DeadlineInfeasible(String),
    /// The deadline expired while the request was still queued; it is
    /// dropped without wasting denoise steps.
    #[error("deadline exceeded while queued")]
    DeadlineExceeded,
    #[error("worker shut down before completing the request")]
    WorkerShutdown,
    /// The worker holding the request left the cluster (crashed, was
    /// killed, or missed enough heartbeats to be declared dead) and the
    /// request could not be failed over to a peer. Distinct from
    /// `WorkerShutdown`: the cluster is still up, one member is gone.
    #[error("worker lost while holding the request")]
    WorkerLost,
    /// Engine-side fault (artifact IO, cache failure) — a server error,
    /// not a client one.
    #[error("internal error: {0}")]
    Internal(String),
}

impl EditError {
    /// HTTP status the frontend returns for this failure.
    pub fn http_status(&self) -> u16 {
        match self {
            EditError::UnknownTemplate(_) => 404,
            EditError::TemplateRetired(_) => 410,
            EditError::InvalidMask(_) => 400,
            EditError::Cancelled => 409,
            EditError::Timeout => 504,
            EditError::Overloaded { .. } => 429,
            EditError::DeadlineInfeasible(_) => 422,
            EditError::DeadlineExceeded => 504,
            EditError::WorkerShutdown => 503,
            EditError::WorkerLost => 503,
            EditError::Internal(_) => 500,
        }
    }

    /// Stable machine-readable tag (the `error_kind` JSON field).
    pub fn kind(&self) -> &'static str {
        match self {
            EditError::UnknownTemplate(_) => "unknown_template",
            EditError::TemplateRetired(_) => "template_retired",
            EditError::InvalidMask(_) => "invalid_mask",
            EditError::Cancelled => "cancelled",
            EditError::Timeout => "timeout",
            EditError::Overloaded { .. } => "overloaded",
            EditError::DeadlineInfeasible(_) => "deadline_infeasible",
            EditError::DeadlineExceeded => "deadline_exceeded",
            EditError::WorkerShutdown => "worker_shutdown",
            EditError::WorkerLost => "worker_lost",
            EditError::Internal(_) => "internal",
        }
    }
}

/// Progress report from a worker engine to the cluster collector.
#[derive(Debug)]
pub enum WorkerEvent {
    /// The request joined the running batch (queued -> running).
    Started { id: u64, worker: usize },
    /// The request left the engine, successfully or not.
    Finished { id: u64, worker: usize, result: Result<EditResponse, EditError> },
}

impl WorkerEvent {
    pub fn id(&self) -> u64 {
        match self {
            WorkerEvent::Started { id, .. } | WorkerEvent::Finished { id, .. } => *id,
        }
    }

    /// Unwrap a successful completion (convenience for single-worker
    /// drivers that only care about responses).
    pub fn into_response(self) -> Option<EditResponse> {
        match self {
            WorkerEvent::Finished { result: Ok(resp), .. } => Some(resp),
            _ => None,
        }
    }
}

/// Validating builder for [`EditRequest`] — the only construction path the
/// frontends use, so malformed requests are rejected *before* they reach a
/// worker queue.
#[derive(Debug, Clone)]
pub struct EditRequestBuilder {
    id: u64,
    template_id: String,
    mask: Option<MaskSpec>,
    prompt_seed: u64,
    expect_tokens: Option<usize>,
    priority: Priority,
    deadline_ms: Option<u64>,
    session: Option<u64>,
}

impl EditRequestBuilder {
    pub fn new(id: u64) -> EditRequestBuilder {
        EditRequestBuilder {
            id,
            template_id: String::new(),
            mask: None,
            prompt_seed: 0,
            expect_tokens: None,
            priority: Priority::default(),
            deadline_ms: None,
            session: None,
        }
    }

    pub fn template(mut self, template_id: impl Into<String>) -> Self {
        self.template_id = template_id.into();
        self
    }

    pub fn mask(mut self, mask: MaskSpec) -> Self {
        self.mask = Some(mask);
        self
    }

    pub fn prompt_seed(mut self, seed: u64) -> Self {
        self.prompt_seed = seed;
        self
    }

    /// Require the mask to cover exactly `tokens` latent tokens (the
    /// serving model's L); mismatches fail `build()` with `InvalidMask`.
    pub fn expect_tokens(mut self, tokens: usize) -> Self {
        self.expect_tokens = Some(tokens);
        self
    }

    /// Request class (defaults to `Standard`).
    pub fn priority(mut self, priority: Priority) -> Self {
        self.priority = priority;
        self
    }

    /// Completion deadline, milliseconds after submission. Zero is
    /// rejected at `build()` with `DeadlineInfeasible`.
    pub fn deadline_ms(mut self, ms: u64) -> Self {
        self.deadline_ms = Some(ms);
        self
    }

    /// Stamp the request as a round of session `id` (sticky routing +
    /// progress events).
    pub fn session(mut self, id: u64) -> Self {
        self.session = Some(id);
        self
    }

    /// Synthesize a contiguous blob mask of `ratio * hw^2` tokens, seeded
    /// from the prompt seed (set the seed first). Rejects ratios outside
    /// `(0, 1]` instead of silently clamping.
    pub fn synth_mask(self, hw: usize, ratio: f64) -> Result<Self, EditError> {
        if !(ratio > 0.0 && ratio <= 1.0) {
            return Err(EditError::InvalidMask(format!(
                "mask_ratio {ratio} outside (0, 1]"
            )));
        }
        let mut rng = Pcg::with_stream(self.prompt_seed, MASK_STREAM);
        let mask = MaskSpec::synth(hw, ratio, &mut rng);
        Ok(self.mask(mask))
    }

    /// Validate and construct the request (arrival stamped at build time).
    pub fn build(self) -> Result<EditRequest, EditError> {
        if self.template_id.is_empty() {
            return Err(EditError::UnknownTemplate(String::new()));
        }
        let mask = self
            .mask
            .ok_or_else(|| EditError::InvalidMask("mask is required".into()))?;
        if mask.masked_count() == 0 {
            return Err(EditError::InvalidMask("mask selects no tokens".into()));
        }
        if let Some(l) = self.expect_tokens {
            if mask.tokens() != l {
                return Err(EditError::InvalidMask(format!(
                    "mask covers {} tokens but the model serves {l}",
                    mask.tokens()
                )));
            }
        }
        if self.deadline_ms == Some(0) {
            return Err(EditError::DeadlineInfeasible(
                "deadline_ms must be positive".into(),
            ));
        }
        let mut req = EditRequest::new(self.id, self.template_id, mask, self.prompt_seed);
        req.priority = self.priority;
        req.deadline = self
            .deadline_ms
            .map(|ms| req.arrival + Duration::from_millis(ms));
        req.session = self.session;
        Ok(req)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_construction() {
        let m = MaskSpec::new(vec![0, 1], 16);
        let r = EditRequest::new(1, "tpl", m, 99);
        assert_eq!(r.template_id, "tpl");
        assert_eq!(r.mask.masked_count(), 2);
    }

    #[test]
    fn builder_valid_request() {
        let r = EditRequestBuilder::new(7)
            .template("tpl-0")
            .prompt_seed(3)
            .mask(MaskSpec::new(vec![0, 1, 2], 16))
            .expect_tokens(16)
            .build()
            .expect("valid");
        assert_eq!(r.id, 7);
        assert_eq!(r.template_id, "tpl-0");
        assert_eq!(r.prompt_seed, 3);
        assert_eq!(r.mask.masked_count(), 3);
    }

    #[test]
    fn builder_rejects_missing_template() {
        let err = EditRequestBuilder::new(1)
            .mask(MaskSpec::new(vec![0], 16))
            .build()
            .unwrap_err();
        assert!(matches!(err, EditError::UnknownTemplate(_)));
    }

    #[test]
    fn builder_rejects_missing_mask() {
        let err = EditRequestBuilder::new(1).template("t").build().unwrap_err();
        assert!(matches!(err, EditError::InvalidMask(_)));
    }

    #[test]
    fn builder_rejects_token_mismatch() {
        let err = EditRequestBuilder::new(1)
            .template("t")
            .mask(MaskSpec::new(vec![0], 16))
            .expect_tokens(64)
            .build()
            .unwrap_err();
        assert!(matches!(err, EditError::InvalidMask(_)));
    }

    #[test]
    fn builder_rejects_out_of_range_ratio() {
        for ratio in [0.0, -0.5, 1.5] {
            let err = EditRequestBuilder::new(1)
                .template("t")
                .synth_mask(8, ratio)
                .unwrap_err();
            assert!(matches!(err, EditError::InvalidMask(_)), "ratio {ratio}");
        }
        // in-range ratio synthesizes deterministically from the seed
        let a = EditRequestBuilder::new(1)
            .template("t")
            .prompt_seed(9)
            .synth_mask(8, 0.2)
            .unwrap()
            .build()
            .unwrap();
        let b = EditRequestBuilder::new(1)
            .template("t")
            .prompt_seed(9)
            .synth_mask(8, 0.2)
            .unwrap()
            .build()
            .unwrap();
        assert_eq!(a.mask, b.mask);
    }

    #[test]
    fn builder_carries_priority_and_deadline() {
        let r = EditRequestBuilder::new(3)
            .template("t")
            .mask(MaskSpec::new(vec![0], 16))
            .priority(Priority::Interactive)
            .deadline_ms(2_500)
            .build()
            .expect("valid");
        assert_eq!(r.priority, Priority::Interactive);
        assert_eq!(r.deadline_ms(), Some(2_500));
        assert!(r.deadline.unwrap() > r.arrival);
        // defaults: standard class, no deadline
        let d = EditRequestBuilder::new(4)
            .template("t")
            .mask(MaskSpec::new(vec![0], 16))
            .build()
            .unwrap();
        assert_eq!(d.priority, Priority::Standard);
        assert_eq!(d.deadline_ms(), None);
    }

    #[test]
    fn builder_carries_session() {
        let r = EditRequestBuilder::new(8)
            .template("t")
            .mask(MaskSpec::new(vec![0], 16))
            .session(42)
            .build()
            .expect("valid");
        assert_eq!(r.session, Some(42));
        let d = EditRequestBuilder::new(9)
            .template("t")
            .mask(MaskSpec::new(vec![0], 16))
            .build()
            .unwrap();
        assert_eq!(d.session, None);
    }

    #[test]
    fn builder_rejects_zero_deadline() {
        let err = EditRequestBuilder::new(5)
            .template("t")
            .mask(MaskSpec::new(vec![0], 16))
            .deadline_ms(0)
            .build()
            .unwrap_err();
        assert!(matches!(err, EditError::DeadlineInfeasible(_)));
    }

    #[test]
    fn edit_error_http_mapping() {
        assert_eq!(EditError::UnknownTemplate("x".into()).http_status(), 404);
        assert_eq!(EditError::TemplateRetired("x".into()).http_status(), 410);
        assert_eq!(EditError::TemplateRetired("x".into()).kind(), "template_retired");
        assert_eq!(EditError::InvalidMask("m".into()).http_status(), 400);
        assert_eq!(EditError::Cancelled.http_status(), 409);
        assert_eq!(EditError::Timeout.http_status(), 504);
        assert_eq!(EditError::Overloaded { retry_after_ms: 1500 }.http_status(), 429);
        assert_eq!(EditError::Overloaded { retry_after_ms: 1500 }.kind(), "overloaded");
        assert_eq!(EditError::DeadlineInfeasible("x".into()).http_status(), 422);
        assert_eq!(EditError::DeadlineInfeasible("x".into()).kind(), "deadline_infeasible");
        assert_eq!(EditError::DeadlineExceeded.http_status(), 504);
        assert_eq!(EditError::DeadlineExceeded.kind(), "deadline_exceeded");
        assert_eq!(EditError::WorkerShutdown.http_status(), 503);
        assert_eq!(EditError::WorkerLost.http_status(), 503);
        assert_eq!(EditError::WorkerLost.kind(), "worker_lost");
        assert_eq!(EditError::Internal("io".into()).http_status(), 500);
        assert_eq!(EditError::Cancelled.kind(), "cancelled");
        assert_eq!(EditError::Timeout.kind(), "timeout");
        assert_eq!(EditError::Internal("io".into()).kind(), "internal");
    }

    #[test]
    fn worker_event_accessors() {
        let ev = WorkerEvent::Started { id: 4, worker: 0 };
        assert_eq!(ev.id(), 4);
        assert!(ev.into_response().is_none());
        let ev = WorkerEvent::Finished {
            id: 5,
            worker: 0,
            result: Err(EditError::Cancelled),
        };
        assert_eq!(ev.id(), 5);
        assert!(ev.into_response().is_none());
    }
}
