//! TeaCache baseline — timestep-embedding-gated step skipping.
//!
//! TeaCache [39] observes that consecutive denoise steps with similar
//! timestep embeddings produce similar model outputs, and skips the model
//! call by replaying the previous eps when the embedding moved less than
//! a threshold. It trades image quality for latency (paper §6.2 shows
//! degraded FID/SSIM); no mask awareness, no continuous batching.

/// Per-request skip gate.
#[derive(Debug, Clone)]
pub struct TeaCacheGate {
    threshold: f64,
    /// Accumulated relative embedding distance since the last computed step.
    accumulated: f64,
    last_emb: Option<Vec<f32>>,
}

impl TeaCacheGate {
    pub fn new(threshold: f64) -> TeaCacheGate {
        TeaCacheGate { threshold, accumulated: 0.0, last_emb: None }
    }

    /// Decide for the step with embedding `emb`: `true` = skip the model
    /// call and reuse the previous eps. The first step always computes.
    pub fn should_skip(&mut self, emb: &[f32]) -> bool {
        match &self.last_emb {
            None => {
                self.last_emb = Some(emb.to_vec());
                self.accumulated = 0.0;
                false
            }
            Some(prev) => {
                let dist: f64 = prev
                    .iter()
                    .zip(emb)
                    .map(|(a, b)| (a - b).abs() as f64)
                    .sum::<f64>()
                    / emb.len() as f64;
                self.accumulated += dist;
                if self.accumulated < self.threshold {
                    true // close enough: replay previous eps
                } else {
                    self.accumulated = 0.0;
                    self.last_emb = Some(emb.to_vec());
                    false
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_step_always_computes() {
        let mut g = TeaCacheGate::new(1.0);
        assert!(!g.should_skip(&[0.0, 0.0]));
    }

    #[test]
    fn skips_similar_steps_until_drift_accumulates() {
        let mut g = TeaCacheGate::new(0.25);
        assert!(!g.should_skip(&[0.0, 0.0])); // first step computes
        assert!(g.should_skip(&[0.1, 0.1])); // acc 0.1 < 0.25 -> skip
        assert!(!g.should_skip(&[0.2, 0.2])); // acc 0.1+0.2 >= 0.25 -> compute
        // after recompute the accumulator resets, so a nearby step skips
        assert!(g.should_skip(&[0.25, 0.25]));
    }

    #[test]
    fn zero_threshold_never_skips_after_motion() {
        let mut g = TeaCacheGate::new(0.0);
        assert!(!g.should_skip(&[0.0]));
        assert!(!g.should_skip(&[0.5]));
        assert!(!g.should_skip(&[1.0]));
    }
}
