//! Pre/post-processing — the CPU-intensive image work of §4.3.
//!
//! Preprocessing: mask -> masked-first permutation, prompt -> conditioning
//! vector, plus the serialization/deserialization CPU burn the paper
//! measures (0.36 s average per interruption on their stack; scaled here
//! via `prepost_cpu_us` to stay proportional to our step latency).
//! Postprocessing: latent -> decoded "image" (host matmul through the
//! VAE-analogue decoder) + serialization burn.
//!
//! These functions are *where* they run matters: inline on the engine
//! thread (strawman continuous batching, Fig. 10-Top) or on the
//! disaggregated pool (InstGenIE, Fig. 10-Bottom).

use std::sync::Arc;

use crate::engine::request::EditRequest;
use crate::model::Permutation;
use crate::util::rng::Pcg;
use crate::util::tensor::Tensor;

/// A request after preprocessing, ready to join a batch.
pub struct PreparedRequest {
    pub request: EditRequest,
    pub perm: Arc<Permutation>,
    /// Per-request conditioning vector (H,), added to the *masked* rows of
    /// the denoiser input each step (DESIGN.md: unmasked rows follow the
    /// template trajectory exactly).
    pub conditioning: Vec<f32>,
    /// Ids of the genuinely masked tokens (prefix of the permutation).
    pub masked_count: usize,
}

/// Burn `us` microseconds of real CPU (models image serialization; the
/// work must be genuine so inline execution visibly blocks the step loop).
pub fn cpu_burn_us(us: u64) {
    let t0 = std::time::Instant::now();
    let mut acc = 0u64;
    while (t0.elapsed().as_micros() as u64) < us {
        // branchy integer mix the optimizer cannot elide
        for i in 0..256u64 {
            acc = acc.wrapping_mul(6364136223846793005).wrapping_add(i);
        }
        std::hint::black_box(acc);
    }
}

/// Preprocess a request (CPU-intensive, paper Fig. 10 "Pre.").
pub fn preprocess(req: EditRequest, hidden: usize, cpu_us: u64) -> PreparedRequest {
    // real serialization work: round-trip the mask through a byte buffer
    let ids = req.mask.masked_ids();
    let mut buf = Vec::with_capacity(ids.len() * 4);
    for &id in ids {
        buf.extend_from_slice(&(id as u32).to_le_bytes());
    }
    let decoded: Vec<usize> = buf
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]) as usize)
        .collect();
    debug_assert_eq!(&decoded, ids);
    cpu_burn_us(cpu_us);

    let perm = Arc::new(Permutation::masked_first(&req.mask));
    let mut rng = Pcg::new(req.prompt_seed);
    let mut conditioning = vec![0f32; hidden];
    rng.fill_normal_f32(&mut conditioning, 0.5);
    let masked_count = req.mask.masked_count();
    PreparedRequest { request: req, perm, conditioning, masked_count }
}

/// Postprocess a finished latent (paper Fig. 10 "Post."): decode to the
/// image space and burn serialization CPU.
pub fn postprocess(latent: &Tensor, decoder: &Tensor, cpu_us: u64) -> Tensor {
    let mut img = latent.matmul(decoder).expect("decoder shape");
    img.map_inplace(|v| v.tanh());
    // serialization burn proportional to image size + fixed cost
    cpu_burn_us(cpu_us);
    img
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::MaskSpec;

    #[test]
    fn preprocess_builds_masked_first_perm() {
        let mask = MaskSpec::new(vec![5, 2], 16);
        let req = EditRequest::new(1, "t", mask, 7);
        let p = preprocess(req, 8, 0);
        assert_eq!(p.masked_count, 2);
        assert_eq!(&p.perm.compute_ids(2), &[2, 5]);
        assert_eq!(p.conditioning.len(), 8);
    }

    #[test]
    fn conditioning_is_prompt_deterministic() {
        let mk = |seed| {
            let mask = MaskSpec::new(vec![0], 4);
            preprocess(EditRequest::new(1, "t", mask, seed), 4, 0).conditioning
        };
        assert_eq!(mk(1), mk(1));
        assert_ne!(mk(1), mk(2));
    }

    #[test]
    fn cpu_burn_takes_time() {
        let t0 = std::time::Instant::now();
        cpu_burn_us(3_000);
        assert!(t0.elapsed().as_micros() >= 3_000);
    }

    #[test]
    fn postprocess_decodes_shape() {
        let latent = Tensor::from_vec(&[4, 3], vec![0.1; 12]).unwrap();
        let dec = Tensor::from_vec(&[3, 2], vec![0.5; 6]).unwrap();
        let img = postprocess(&latent, &dec, 0);
        assert_eq!(img.shape(), &[4, 2]);
        assert!(img.data().iter().all(|v| v.abs() <= 1.0)); // tanh range
    }
}
