//! Worker request queue + submission handle.
//!
//! Two lanes: `raw` requests await preprocessing on the engine thread
//! (static / strawman-continuous policies), `ready` requests were
//! preprocessed on the disaggregated pool (InstGenIE policy). The paper's
//! disaggregation (§4.3) is exactly the difference between these lanes.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::engine::prepost::{preprocess, PreparedRequest};
use crate::engine::request::EditRequest;
use crate::util::pool::ThreadPool;

#[derive(Default)]
struct Inner {
    raw: VecDeque<EditRequest>,
    ready: VecDeque<PreparedRequest>,
    preprocessing: usize,
    closed: bool,
}

/// Shared queue between submitters and the engine thread.
pub struct WorkerQueue {
    inner: Mutex<Inner>,
    cv: Condvar,
}

impl WorkerQueue {
    pub fn new() -> Arc<WorkerQueue> {
        Arc::new(WorkerQueue { inner: Mutex::new(Inner::default()), cv: Condvar::new() })
    }

    pub fn push_raw(&self, req: EditRequest) {
        let mut g = self.inner.lock().unwrap();
        g.raw.push_back(req);
        self.cv.notify_all();
    }

    pub fn push_ready(&self, prep: PreparedRequest) {
        let mut g = self.inner.lock().unwrap();
        g.ready.push_back(prep);
        g.preprocessing = g.preprocessing.saturating_sub(1);
        self.cv.notify_all();
    }

    fn note_preprocessing(&self) {
        self.inner.lock().unwrap().preprocessing += 1;
    }

    pub fn pop_raw(&self) -> Option<EditRequest> {
        self.inner.lock().unwrap().raw.pop_front()
    }

    pub fn pop_ready(&self) -> Option<PreparedRequest> {
        self.inner.lock().unwrap().ready.pop_front()
    }

    /// Pop the front raw request only if it satisfies `pred` (bucket-aware
    /// admission: FIFO, no reordering, hence no starvation).
    pub fn pop_raw_if(&self, pred: impl Fn(&EditRequest) -> bool) -> Option<EditRequest> {
        let mut g = self.inner.lock().unwrap();
        if g.raw.front().map(&pred).unwrap_or(false) {
            g.raw.pop_front()
        } else {
            None
        }
    }

    /// Pop the front prepared request only if it satisfies `pred`.
    pub fn pop_ready_if(
        &self,
        pred: impl Fn(&PreparedRequest) -> bool,
    ) -> Option<PreparedRequest> {
        let mut g = self.inner.lock().unwrap();
        if g.ready.front().map(&pred).unwrap_or(false) {
            g.ready.pop_front()
        } else {
            None
        }
    }

    /// Remove a queued request by id from either lane (cancellation).
    /// Returns `true` iff the request was still queued here; a request
    /// mid-preprocess or already admitted to the batch is not removable.
    pub fn remove(&self, id: u64) -> bool {
        let mut g = self.inner.lock().unwrap();
        if let Some(pos) = g.raw.iter().position(|r| r.id == id) {
            g.raw.remove(pos);
            return true;
        }
        if let Some(pos) = g.ready.iter().position(|p| p.request.id == id) {
            g.ready.remove(pos);
            return true;
        }
        false
    }

    /// Pending work (either lane + in-flight preprocessing).
    pub fn pending(&self) -> usize {
        let g = self.inner.lock().unwrap();
        g.raw.len() + g.ready.len() + g.preprocessing
    }

    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }

    /// Park the engine thread briefly when idle.
    pub fn wait_for_work(&self, timeout: Duration) {
        let g = self.inner.lock().unwrap();
        if g.raw.is_empty() && g.ready.is_empty() && !g.closed {
            let _ = self.cv.wait_timeout(g, timeout).unwrap();
        }
    }
}

/// Submission handle owned by the scheduler / HTTP frontend.
#[derive(Clone)]
pub struct Submitter {
    queue: Arc<WorkerQueue>,
    pool: Option<Arc<ThreadPool>>,
    hidden: usize,
    cpu_us: u64,
    /// Called with the template id at enqueue time so the worker can
    /// start promoting a spilled template before admission (§4.2: the
    /// promotion hides under queuing time).
    prefetch: Option<Arc<dyn Fn(&str) + Send + Sync>>,
}

impl Submitter {
    /// `pool: Some(...)` enables disaggregated preprocessing (InstGenIE);
    /// `None` leaves requests raw for the engine thread (baselines).
    pub fn new(
        queue: Arc<WorkerQueue>,
        pool: Option<Arc<ThreadPool>>,
        hidden: usize,
        cpu_us: u64,
    ) -> Submitter {
        Submitter { queue, pool, hidden, cpu_us, prefetch: None }
    }

    /// Attach an enqueue-time template prefetch hook (worker tier
    /// promotion on the low-priority pre/post lane).
    pub fn with_prefetch(mut self, hook: Arc<dyn Fn(&str) + Send + Sync>) -> Submitter {
        self.prefetch = Some(hook);
        self
    }

    pub fn submit(&self, req: EditRequest) {
        if let Some(hook) = &self.prefetch {
            hook(&req.template_id);
        }
        match &self.pool {
            Some(pool) => {
                self.queue.note_preprocessing();
                let queue = Arc::clone(&self.queue);
                let hidden = self.hidden;
                let cpu_us = self.cpu_us;
                pool.submit(move || {
                    let prep = preprocess(req, hidden, cpu_us);
                    queue.push_ready(prep);
                });
            }
            None => self.queue.push_raw(req),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::MaskSpec;

    fn req(id: u64) -> EditRequest {
        EditRequest::new(id, "t", MaskSpec::new(vec![0, 1], 16), id)
    }

    #[test]
    fn raw_lane_fifo() {
        let q = WorkerQueue::new();
        q.push_raw(req(1));
        q.push_raw(req(2));
        assert_eq!(q.pending(), 2);
        assert_eq!(q.pop_raw().unwrap().id, 1);
        assert_eq!(q.pop_raw().unwrap().id, 2);
        assert!(q.pop_raw().is_none());
    }

    #[test]
    fn disaggregated_submitter_preprocesses_off_thread() {
        let q = WorkerQueue::new();
        let pool = Arc::new(ThreadPool::new("pp", 2));
        let s = Submitter::new(Arc::clone(&q), Some(pool), 8, 0);
        s.submit(req(7));
        // pending counts the in-flight preprocess immediately
        assert!(q.pending() >= 1);
        let deadline = std::time::Instant::now() + Duration::from_secs(2);
        loop {
            if let Some(p) = q.pop_ready() {
                assert_eq!(p.request.id, 7);
                break;
            }
            assert!(std::time::Instant::now() < deadline, "preprocess never landed");
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(q.pending(), 0);
    }

    #[test]
    fn remove_cancels_queued_requests_in_both_lanes() {
        let q = WorkerQueue::new();
        q.push_raw(req(1));
        q.push_raw(req(2));
        assert!(q.remove(1));
        assert!(!q.remove(1), "already removed");
        assert_eq!(q.pending(), 1);
        assert_eq!(q.pop_raw().unwrap().id, 2);

        // ready lane: preprocess inline, then cancel before admission
        let prep = crate::engine::prepost::preprocess(req(9), 8, 0);
        q.push_ready(prep);
        assert!(q.remove(9));
        assert!(q.pop_ready().is_none());
        assert!(!q.remove(42), "unknown id");
    }

    #[test]
    fn prefetch_hook_fires_at_enqueue_time() {
        let q = WorkerQueue::new();
        let seen = Arc::new(Mutex::new(Vec::new()));
        let hook = {
            let seen = Arc::clone(&seen);
            Arc::new(move |tpl: &str| seen.lock().unwrap().push(tpl.to_string()))
        };
        let s = Submitter::new(Arc::clone(&q), None, 8, 0).with_prefetch(hook);
        s.submit(req(5));
        assert_eq!(*seen.lock().unwrap(), vec!["t".to_string()]);
        assert_eq!(q.pop_raw().unwrap().id, 5);
    }

    #[test]
    fn inline_submitter_keeps_raw() {
        let q = WorkerQueue::new();
        let s = Submitter::new(Arc::clone(&q), None, 8, 0);
        s.submit(req(3));
        assert!(q.pop_ready().is_none());
        assert_eq!(q.pop_raw().unwrap().id, 3);
    }
}
