//! Worker request queue + submission handle.
//!
//! Two lanes: `raw` requests await preprocessing on the engine thread
//! (static / strawman-continuous policies), `ready` requests were
//! preprocessed on the disaggregated pool (InstGenIE policy). The paper's
//! disaggregation (§4.3) is exactly the difference between these lanes.
//!
//! With QoS enabled ([`QueuePolicy::qos`]) both lanes pop in priority
//! order: strict class priority softened by an aging credit
//! ([`crate::qos::effective_rank`]) so a `Batch` request that has waited
//! long enough outranks fresh `Interactive` arrivals — strict priority
//! with starvation-freedom. Within a class (and with QoS off) order stays
//! FIFO. The queue also carries the cancel marks and the held-set
//! (parked / preempted ids) that let `DELETE /v1/edits/{id}` reach
//! requests the engine thread holds outside its lanes.

use std::collections::{HashSet, VecDeque};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::engine::prepost::{preprocess, PreparedRequest};
use crate::engine::request::{EditError, EditRequest};
use crate::qos::{effective_rank, ClassDepth, QosConfig, CLASS_COUNT};
use crate::util::pool::ThreadPool;

/// Queue ordering policy (derived from the engine's [`QosConfig`]).
/// The default (`qos: false`) is pure FIFO lanes.
#[derive(Debug, Clone, Copy, Default)]
pub struct QueuePolicy {
    /// Priority-ordered pops + deadline expiry; off = pure FIFO lanes.
    pub qos: bool,
    /// Aging credit quantum, ms (see [`effective_rank`]).
    pub aging_ms: u64,
}

impl QueuePolicy {
    pub fn from_qos(cfg: &QosConfig) -> QueuePolicy {
        QueuePolicy { qos: cfg.enabled, aging_ms: cfg.aging_ms }
    }
}

#[derive(Default)]
struct Inner {
    raw: VecDeque<EditRequest>,
    ready: VecDeque<PreparedRequest>,
    preprocessing: usize,
    closed: bool,
    /// Cancellation marks for requests the engine thread holds outside
    /// the lanes (mid-preprocess, parked, preempted); consumed by the
    /// worker at the next step boundary.
    cancels: HashSet<u64>,
    /// Ids the engine thread holds parked or preempted — cancellable via
    /// a mark even though they are in no lane.
    held: HashSet<u64>,
}

/// Index of the highest-priority entry (aging-adjusted class rank, then
/// arrival). With QoS off this is the front — plain FIFO.
fn best_index<T>(
    items: &VecDeque<T>,
    policy: QueuePolicy,
    now: Instant,
    key: impl Fn(&T) -> (usize, Instant),
) -> Option<usize> {
    if items.is_empty() {
        return None;
    }
    if !policy.qos {
        return Some(0);
    }
    let mut best = 0usize;
    let mut best_key: Option<(i64, Instant)> = None;
    for (i, item) in items.iter().enumerate() {
        let (rank, arrival) = key(item);
        let waited = now.saturating_duration_since(arrival);
        let k = (effective_rank(rank, waited, policy.aging_ms), arrival);
        if best_key.map(|b| k < b).unwrap_or(true) {
            best_key = Some(k);
            best = i;
        }
    }
    Some(best)
}

/// Shared queue between submitters and the engine thread.
pub struct WorkerQueue {
    inner: Mutex<Inner>,
    cv: Condvar,
    policy: QueuePolicy,
}

impl WorkerQueue {
    /// FIFO queue (baselines, tests).
    pub fn new() -> Arc<WorkerQueue> {
        WorkerQueue::with_policy(QueuePolicy::default())
    }

    pub fn with_policy(policy: QueuePolicy) -> Arc<WorkerQueue> {
        Arc::new(WorkerQueue { inner: Mutex::new(Inner::default()), cv: Condvar::new(), policy })
    }

    pub fn push_raw(&self, req: EditRequest) {
        let mut g = self.inner.lock().unwrap();
        g.raw.push_back(req);
        self.cv.notify_all();
    }

    pub fn push_ready(&self, prep: PreparedRequest) {
        let mut g = self.inner.lock().unwrap();
        g.ready.push_back(prep);
        g.preprocessing = g.preprocessing.saturating_sub(1);
        self.cv.notify_all();
    }

    fn note_preprocessing(&self) {
        self.inner.lock().unwrap().preprocessing += 1;
    }

    pub fn pop_raw(&self) -> Option<EditRequest> {
        let mut g = self.inner.lock().unwrap();
        let now = Instant::now();
        let idx = best_index(&g.raw, self.policy, now, |r| (r.priority.rank(), r.arrival))?;
        g.raw.remove(idx)
    }

    pub fn pop_ready(&self) -> Option<PreparedRequest> {
        let mut g = self.inner.lock().unwrap();
        let now = Instant::now();
        let idx = best_index(&g.ready, self.policy, now, |p| {
            (p.request.priority.rank(), p.request.arrival)
        })?;
        g.ready.remove(idx)
    }

    /// Pop the best-ordered raw request only if it satisfies `pred`
    /// (bucket-aware admission). The predicate is tested on the single
    /// best candidate only — deferral never reorders past it, so the
    /// FIFO front-check's no-starvation property carries over to the
    /// priority ordering.
    pub fn pop_raw_if(&self, pred: impl Fn(&EditRequest) -> bool) -> Option<EditRequest> {
        let mut g = self.inner.lock().unwrap();
        let now = Instant::now();
        let idx = best_index(&g.raw, self.policy, now, |r| (r.priority.rank(), r.arrival))?;
        if pred(&g.raw[idx]) {
            g.raw.remove(idx)
        } else {
            None
        }
    }

    /// Pop the best-ordered prepared request only if it satisfies `pred`.
    pub fn pop_ready_if(
        &self,
        pred: impl Fn(&PreparedRequest) -> bool,
    ) -> Option<PreparedRequest> {
        let mut g = self.inner.lock().unwrap();
        let now = Instant::now();
        let idx = best_index(&g.ready, self.policy, now, |p| {
            (p.request.priority.rank(), p.request.arrival)
        })?;
        if pred(&g.ready[idx]) {
            g.ready.remove(idx)
        } else {
            None
        }
    }

    /// Remove a queued request by id from either lane (cancellation).
    /// Returns `true` iff the request was still queued here; a request
    /// mid-preprocess, parked, preempted, or already admitted to the
    /// batch is not removable — use [`WorkerQueue::request_cancel`] for
    /// the held cases.
    pub fn remove(&self, id: u64) -> bool {
        let mut g = self.inner.lock().unwrap();
        if let Some(pos) = g.raw.iter().position(|r| r.id == id) {
            g.raw.remove(pos);
            return true;
        }
        if let Some(pos) = g.ready.iter().position(|p| p.request.id == id) {
            g.ready.remove(pos);
            return true;
        }
        false
    }

    /// Mark a request for cancellation: the engine thread resolves it at
    /// its next step boundary (covers mid-preprocess, parked, and
    /// preempted requests that [`WorkerQueue::remove`] cannot reach).
    pub fn request_cancel(&self, id: u64) {
        self.inner.lock().unwrap().cancels.insert(id);
        self.cv.notify_all();
    }

    /// Consume a cancel mark (engine thread, at admission / park / resume
    /// boundaries). Returns whether the id was marked.
    pub fn take_cancel(&self, id: u64) -> bool {
        self.inner.lock().unwrap().cancels.remove(&id)
    }

    /// Drop a stale cancel mark (request already resolved another way).
    pub fn clear_cancel(&self, id: u64) {
        let mut g = self.inner.lock().unwrap();
        g.cancels.remove(&id);
        g.held.remove(&id);
    }

    /// Whether the engine thread holds this id parked or preempted.
    pub fn is_held(&self, id: u64) -> bool {
        self.inner.lock().unwrap().held.contains(&id)
    }

    pub fn set_held(&self, id: u64, held: bool) {
        let mut g = self.inner.lock().unwrap();
        if held {
            g.held.insert(id);
        } else {
            g.held.remove(&id);
        }
    }

    /// Atomically post a cancel mark iff the id is currently held
    /// (parked / preempted). Pairs with [`WorkerQueue::release_held`] so
    /// a cancel can never slip between "observed held" and "mark posted"
    /// while the engine thread resumes the member.
    pub fn cancel_if_held(&self, id: u64) -> bool {
        let mut g = self.inner.lock().unwrap();
        if g.held.contains(&id) {
            g.cancels.insert(id);
            self.cv.notify_all();
            true
        } else {
            false
        }
    }

    /// Atomically release a held id for resume/admission. Returns `false`
    /// when a cancel mark was pending — the mark is consumed and the
    /// caller must resolve the request as `Cancelled` instead.
    pub fn release_held(&self, id: u64) -> bool {
        let mut g = self.inner.lock().unwrap();
        g.held.remove(&id);
        !g.cancels.remove(&id)
    }

    /// Sweep both lanes for defunct entries: cancel-marked requests
    /// (always) and, with QoS enabled, requests whose deadline expired
    /// while queued. Returns `(id, why)` per dropped entry so the engine
    /// thread can report them without spending denoise steps.
    pub fn drain_defunct(&self, now: Instant) -> Vec<(u64, EditError)> {
        let mut g = self.inner.lock().unwrap();
        let qos = self.policy.qos;
        let Inner { raw, ready, cancels, .. } = &mut *g;
        let mut out = Vec::new();
        raw.retain(|r| {
            if cancels.remove(&r.id) {
                out.push((r.id, EditError::Cancelled));
                return false;
            }
            if qos && matches!(r.deadline, Some(d) if now >= d) {
                out.push((r.id, EditError::DeadlineExceeded));
                return false;
            }
            true
        });
        ready.retain(|p| {
            let r = &p.request;
            if cancels.remove(&r.id) {
                out.push((r.id, EditError::Cancelled));
                return false;
            }
            if qos && matches!(r.deadline, Some(d) if now >= d) {
                out.push((r.id, EditError::DeadlineExceeded));
                return false;
            }
            true
        });
        out
    }

    /// Static class rank + masked-token count of the entry the next
    /// `pop_raw`/`pop_raw_if` would take (the preemption check: evict
    /// only when the next admission really is an `Interactive`, not e.g.
    /// an aged-up `Batch` request that would steal the freed slot).
    pub fn peek_best_raw(&self) -> Option<(usize, usize)> {
        let g = self.inner.lock().unwrap();
        let idx = best_index(&g.raw, self.policy, Instant::now(), |r| {
            (r.priority.rank(), r.arrival)
        })?;
        let r = &g.raw[idx];
        Some((r.priority.rank(), r.mask.masked_count()))
    }

    /// [`WorkerQueue::peek_best_raw`] for the prepared lane.
    pub fn peek_best_ready(&self) -> Option<(usize, usize)> {
        let g = self.inner.lock().unwrap();
        let idx = best_index(&g.ready, self.policy, Instant::now(), |p| {
            (p.request.priority.rank(), p.request.arrival)
        })?;
        let p = &g.ready[idx];
        Some((p.request.priority.rank(), p.masked_count))
    }

    /// Per-class depth + oldest-wait snapshot over both lanes.
    pub fn class_depths(&self, now: Instant) -> [ClassDepth; CLASS_COUNT] {
        let g = self.inner.lock().unwrap();
        let mut out = [ClassDepth::default(); CLASS_COUNT];
        let mut note = |rank: usize, arrival: Instant| {
            out[rank].queued += 1;
            let wait = now.saturating_duration_since(arrival).as_secs_f64();
            if wait > out[rank].oldest_wait_secs {
                out[rank].oldest_wait_secs = wait;
            }
        };
        for r in &g.raw {
            note(r.priority.rank(), r.arrival);
        }
        for p in &g.ready {
            note(p.request.priority.rank(), p.request.arrival);
        }
        out
    }

    /// Mask ratios of every queued request (both lanes) — the scheduler's
    /// Algo-2 cost model reads the queue's actual composition from these
    /// (plus the running batch's, via `WorkerShared`).
    pub fn queued_mask_ratios(&self) -> Vec<f64> {
        let g = self.inner.lock().unwrap();
        g.raw
            .iter()
            .map(|r| r.mask.ratio())
            .chain(g.ready.iter().map(|p| p.request.mask.ratio()))
            .collect()
    }

    /// Pending work (either lane + in-flight preprocessing).
    pub fn pending(&self) -> usize {
        let g = self.inner.lock().unwrap();
        g.raw.len() + g.ready.len() + g.preprocessing
    }

    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }

    /// Park the engine thread briefly when idle.
    pub fn wait_for_work(&self, timeout: Duration) {
        let g = self.inner.lock().unwrap();
        if g.raw.is_empty() && g.ready.is_empty() && !g.closed {
            let _ = self.cv.wait_timeout(g, timeout).unwrap();
        }
    }
}

/// Submission handle owned by the scheduler / HTTP frontend.
#[derive(Clone)]
pub struct Submitter {
    queue: Arc<WorkerQueue>,
    pool: Option<Arc<ThreadPool>>,
    hidden: usize,
    cpu_us: u64,
    /// Called with the template id at enqueue time so the worker can
    /// start promoting a spilled template before admission (§4.2: the
    /// promotion hides under queuing time).
    prefetch: Option<Arc<dyn Fn(&str) + Send + Sync>>,
}

impl Submitter {
    /// `pool: Some(...)` enables disaggregated preprocessing (InstGenIE);
    /// `None` leaves requests raw for the engine thread (baselines).
    pub fn new(
        queue: Arc<WorkerQueue>,
        pool: Option<Arc<ThreadPool>>,
        hidden: usize,
        cpu_us: u64,
    ) -> Submitter {
        Submitter { queue, pool, hidden, cpu_us, prefetch: None }
    }

    /// Attach an enqueue-time template prefetch hook (worker tier
    /// promotion on the low-priority pre/post lane).
    pub fn with_prefetch(mut self, hook: Arc<dyn Fn(&str) + Send + Sync>) -> Submitter {
        self.prefetch = Some(hook);
        self
    }

    pub fn submit(&self, req: EditRequest) {
        if let Some(hook) = &self.prefetch {
            hook(&req.template_id);
        }
        match &self.pool {
            Some(pool) => {
                self.queue.note_preprocessing();
                let queue = Arc::clone(&self.queue);
                let hidden = self.hidden;
                let cpu_us = self.cpu_us;
                pool.submit(move || {
                    let prep = preprocess(req, hidden, cpu_us);
                    queue.push_ready(prep);
                });
            }
            None => self.queue.push_raw(req),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::MaskSpec;
    use crate::qos::Priority;
    use crate::util::prop::prop_check;

    fn req(id: u64) -> EditRequest {
        EditRequest::new(id, "t", MaskSpec::new(vec![0, 1], 16), id)
    }

    fn req_class(id: u64, priority: Priority) -> EditRequest {
        let mut r = req(id);
        r.priority = priority;
        r
    }

    fn qos_queue(aging_ms: u64) -> Arc<WorkerQueue> {
        WorkerQueue::with_policy(QueuePolicy { qos: true, aging_ms })
    }

    #[test]
    fn raw_lane_fifo() {
        let q = WorkerQueue::new();
        q.push_raw(req(1));
        q.push_raw(req(2));
        assert_eq!(q.pending(), 2);
        assert_eq!(q.pop_raw().unwrap().id, 1);
        assert_eq!(q.pop_raw().unwrap().id, 2);
        assert!(q.pop_raw().is_none());
    }

    #[test]
    fn qos_pop_orders_by_class_then_arrival() {
        let q = qos_queue(60_000); // aging too slow to matter here
        q.push_raw(req_class(1, Priority::Batch));
        q.push_raw(req_class(2, Priority::Standard));
        q.push_raw(req_class(3, Priority::Interactive));
        q.push_raw(req_class(4, Priority::Interactive));
        let order: Vec<u64> = std::iter::from_fn(|| q.pop_raw().map(|r| r.id)).collect();
        assert_eq!(order, vec![3, 4, 2, 1], "class order, FIFO within class");
    }

    #[test]
    fn qos_pop_if_tests_only_the_best_candidate() {
        let q = qos_queue(60_000);
        q.push_raw(req_class(1, Priority::Interactive));
        q.push_raw(req_class(2, Priority::Batch));
        // predicate rejects the interactive front -> nothing pops (no
        // skipping past the best candidate; prevents reorder-starvation)
        assert!(q.pop_raw_if(|r| r.id != 1).is_none());
        assert!(q.pop_raw_if(|r| r.id == 1).is_some());
        assert_eq!(q.pop_raw_if(|_| true).unwrap().id, 2);
    }

    #[test]
    fn aging_credit_prevents_batch_starvation() {
        // property: under sustained interactive pressure (a fresh
        // interactive request pushed before every pop), an already-queued
        // batch request still pops within a bounded number of rounds.
        prop_check("aging credit is starvation-free", 4, |rng| {
            let aging_ms = 2 + rng.below(4) as u64; // 2..=5 ms
            let q = qos_queue(aging_ms);
            q.push_raw(req_class(9_999, Priority::Batch));
            let deadline = Instant::now() + Duration::from_secs(5);
            let mut fresh = 0u64;
            loop {
                if Instant::now() >= deadline {
                    return Err(format!(
                        "batch request starved (aging_ms={aging_ms}, {fresh} interactive pops)"
                    ));
                }
                // sustained pressure: 1-2 fresh interactive arrivals per round
                for _ in 0..1 + rng.below(2) {
                    fresh += 1;
                    q.push_raw(req_class(fresh, Priority::Interactive));
                }
                if q.pop_raw().expect("non-empty").id == 9_999 {
                    return Ok(());
                }
                std::thread::sleep(Duration::from_millis(1));
            }
        });
    }

    #[test]
    fn peek_best_reports_the_next_pop() {
        let q = qos_queue(5);
        q.push_raw(req_class(1, Priority::Batch));
        // a fresh interactive outranks the young batch request
        q.push_raw(req_class(2, Priority::Interactive));
        assert_eq!(q.peek_best_raw().map(|(rank, _)| rank), Some(0));
        assert_eq!(q.pop_raw().unwrap().id, 2);
        // once the batch request has aged to rank 0, it is the next pop
        // even with a fresh interactive behind it — and peek reports its
        // *static* class, so preemption will not fire for it
        std::thread::sleep(Duration::from_millis(12));
        q.push_raw(req_class(3, Priority::Interactive));
        assert_eq!(
            q.peek_best_raw().map(|(rank, _)| rank),
            Some(Priority::Batch.rank())
        );
        assert_eq!(q.pop_raw().unwrap().id, 1);
        assert!(q.peek_best_ready().is_none());
    }

    #[test]
    fn drain_defunct_expires_deadlines_only_under_qos() {
        let q = qos_queue(1_000);
        let mut r = req(1);
        r.deadline = Some(Instant::now() - Duration::from_millis(1));
        q.push_raw(r);
        q.push_raw(req(2)); // no deadline: survives
        let dropped = q.drain_defunct(Instant::now());
        assert_eq!(dropped, vec![(1, EditError::DeadlineExceeded)]);
        assert_eq!(q.pending(), 1);

        // FIFO baseline ignores deadlines entirely
        let fifo = WorkerQueue::new();
        let mut r = req(3);
        r.deadline = Some(Instant::now() - Duration::from_millis(1));
        fifo.push_raw(r);
        assert!(fifo.drain_defunct(Instant::now()).is_empty());
        assert_eq!(fifo.pending(), 1);
    }

    #[test]
    fn cancel_marks_sweep_lanes_and_track_held_ids() {
        let q = qos_queue(1_000);
        q.push_raw(req(1));
        let prep = crate::engine::prepost::preprocess(req(2), 8, 0);
        q.push_ready(prep);
        q.request_cancel(1);
        q.request_cancel(2);
        q.request_cancel(77); // not queued: mark persists for the worker
        let mut dropped = q.drain_defunct(Instant::now());
        dropped.sort_by_key(|(id, _)| *id);
        assert_eq!(
            dropped,
            vec![(1, EditError::Cancelled), (2, EditError::Cancelled)]
        );
        assert_eq!(q.pending(), 0);
        // the sweep consumed the lane marks, the parked mark survives
        assert!(!q.take_cancel(1));
        assert!(q.take_cancel(77));
        assert!(!q.take_cancel(77), "marks are consumed once");

        // held-set bookkeeping (parked / preempted visibility)
        assert!(!q.is_held(5));
        q.set_held(5, true);
        assert!(q.is_held(5));
        q.set_held(5, false);
        assert!(!q.is_held(5));
        q.set_held(6, true);
        q.request_cancel(6);
        q.clear_cancel(6);
        assert!(!q.take_cancel(6));
        assert!(!q.is_held(6), "clear_cancel drops the held entry too");
    }

    #[test]
    fn class_depths_report_per_class_waits() {
        let q = qos_queue(1_000);
        q.push_raw(req_class(1, Priority::Interactive));
        q.push_raw(req_class(2, Priority::Batch));
        q.push_raw(req_class(3, Priority::Batch));
        let d = q.class_depths(Instant::now() + Duration::from_millis(10));
        assert_eq!(d[Priority::Interactive.rank()].queued, 1);
        assert_eq!(d[Priority::Standard.rank()].queued, 0);
        assert_eq!(d[Priority::Batch.rank()].queued, 2);
        assert!(d[Priority::Batch.rank()].oldest_wait_secs >= 0.01);
        assert_eq!(q.peek_best_raw().map(|(rank, _)| rank), Some(0));
        q.pop_raw();
        assert_eq!(
            q.peek_best_raw().map(|(rank, _)| rank),
            Some(Priority::Batch.rank())
        );
    }

    #[test]
    fn disaggregated_submitter_preprocesses_off_thread() {
        let q = WorkerQueue::new();
        let pool = Arc::new(ThreadPool::new("pp", 2));
        let s = Submitter::new(Arc::clone(&q), Some(pool), 8, 0);
        s.submit(req(7));
        // pending counts the in-flight preprocess immediately
        assert!(q.pending() >= 1);
        let deadline = std::time::Instant::now() + Duration::from_secs(2);
        loop {
            if let Some(p) = q.pop_ready() {
                assert_eq!(p.request.id, 7);
                break;
            }
            assert!(std::time::Instant::now() < deadline, "preprocess never landed");
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(q.pending(), 0);
    }

    #[test]
    fn remove_cancels_queued_requests_in_both_lanes() {
        let q = WorkerQueue::new();
        q.push_raw(req(1));
        q.push_raw(req(2));
        assert!(q.remove(1));
        assert!(!q.remove(1), "already removed");
        assert_eq!(q.pending(), 1);
        assert_eq!(q.pop_raw().unwrap().id, 2);

        // ready lane: preprocess inline, then cancel before admission
        let prep = crate::engine::prepost::preprocess(req(9), 8, 0);
        q.push_ready(prep);
        assert!(q.remove(9));
        assert!(q.pop_ready().is_none());
        assert!(!q.remove(42), "unknown id");
    }

    #[test]
    fn queued_mask_ratios_cover_both_lanes() {
        let q = WorkerQueue::new();
        q.push_raw(req(1)); // 2/16 masked
        let mut r = req(2);
        r.mask = MaskSpec::new(vec![0, 1, 2, 3], 16); // 4/16 masked
        q.push_ready(crate::engine::prepost::preprocess(r, 8, 0));
        let mut ratios = q.queued_mask_ratios();
        ratios.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(ratios, vec![2.0 / 16.0, 4.0 / 16.0]);
    }

    #[test]
    fn prefetch_hook_fires_at_enqueue_time() {
        let q = WorkerQueue::new();
        let seen = Arc::new(Mutex::new(Vec::new()));
        let hook = {
            let seen = Arc::clone(&seen);
            Arc::new(move |tpl: &str| seen.lock().unwrap().push(tpl.to_string()))
        };
        let s = Submitter::new(Arc::clone(&q), None, 8, 0).with_prefetch(hook);
        s.submit(req(5));
        assert_eq!(*seen.lock().unwrap(), vec!["t".to_string()]);
        assert_eq!(q.pop_raw().unwrap().id, 5);
    }

    #[test]
    fn inline_submitter_keeps_raw() {
        let q = WorkerQueue::new();
        let s = Submitter::new(Arc::clone(&q), None, 8, 0);
        s.submit(req(3));
        assert!(q.pop_ready().is_none());
        assert_eq!(q.pop_raw().unwrap().id, 3);
    }
}
