//! The worker engine: denoising step loop with continuous batching,
//! mask-aware cached inference and the bubble-free load pipeline.
//!
//! One worker = one "GPU replica": an engine thread running the step loop,
//! a cache-loader thread (the copy stream), and — in disaggregated mode —
//! a small pre/post-processing pool. All four baselines of §6 are modes of
//! this engine (`SystemKind`), so the comparisons isolate exactly the
//! paper's design axes:
//!
//! - `InstGenIE`   mask-aware cached blocks + Algo-1 pipeline + step-level
//!                 continuous batching + disaggregated pre/post.
//! - `Diffusers`   full-image recompute, static batching.
//! - `FisEdit`     mask-aware compute with GPU-resident activations (free
//!                 loads) but batch = 1 and no continuous batching.
//! - `TeaCache`    full-image recompute with timestep-gated step skipping,
//!                 static batching.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::cache::loader::{CacheLoader, MemberGather, StagedBlock};
use crate::cache::pipeline::{self, BlockCosts, PipelinePlan};
use crate::cache::store::{register_template, TemplateActivations};
use crate::cache::tier::{Residency, TieredStore};
use crate::cache::LatencyModel;
use crate::config::{BatchingPolicy, CacheMode, EngineConfig, SystemKind};
use crate::engine::prepost::{postprocess, preprocess, PreparedRequest};
use crate::engine::queue::{QueuePolicy, Submitter, WorkerQueue};
use crate::engine::request::{EditError, EditResponse, RequestTiming, WorkerEvent};
use crate::engine::teacache::TeaCacheGate;
use crate::model::Latent;
use crate::qos::{ClassDepth, Priority, CLASS_COUNT};
use crate::templates::{TemplateRegistry, TemplateState};
use crate::util::pool::ThreadPool;
use crate::util::tensor::Tensor;

/// An in-flight batch member.
struct Member {
    prep: PreparedRequest,
    acts: Arc<TemplateActivations>,
    latent: Latent,
    step: usize,
    joined: Instant,
    interruptions: u32,
    steps_computed: u32,
    /// Cached compute-set ids Arc for loader jobs (avoids re-allocating
    /// the suffix id vector per block).
    cached_ids: Arc<Vec<usize>>,
    cached_bucket: usize,
    /// TeaCache: replayed eps (full (L, H)) + gate.
    last_eps: Option<Vec<f32>>,
    gate: Option<TeaCacheGate>,
    /// Times this member was preempted for an `Interactive` request (at
    /// most once, so preemption cannot thrash a member forever).
    preemptions: u32,
}

impl Member {
    fn rank(&self) -> usize {
        self.prep.request.priority.rank()
    }
}

/// A popped request whose template is still registering cluster-wide: it
/// waits here — off the queue, so other templates' requests flow past —
/// until the registry publishes the template or the deadline passes
/// (submit-during-registration queues until ready or times out).
struct Parked {
    prep: PreparedRequest,
    deadline: Instant,
}

/// Admission decision for a popped request's template.
enum TemplateGate {
    /// Resident (or cold-registrable): admit now.
    Ready,
    /// Registration in flight: park the request.
    Pending,
    /// Typed terminal refusal (retired / failed registration).
    Refused(EditError),
}

/// Live load/state snapshot for the cluster scheduler (§4.4).
#[derive(Debug, Clone, Default)]
pub struct WorkerSnapshot {
    pub worker_id: usize,
    pub queued: usize,
    pub running: usize,
    /// Sum over queued+running requests of masked-token counts.
    pub queued_masked_tokens: usize,
    /// Mask ratios of queued + running requests (scheduler cost model).
    pub mask_ratios: Vec<f64>,
    /// Per-class queue depth + oldest-wait age (QoS observability).
    pub class_depths: [ClassDepth; CLASS_COUNT],
}

/// Shared mutable state published by the engine thread.
#[derive(Default)]
pub struct WorkerShared {
    running: AtomicUsize,
    running_masked: AtomicUsize,
    steps_executed: AtomicUsize,
}

/// The worker engine. Construct, then call [`Worker::start`].
pub struct Worker {
    pub id: usize,
    cfg: EngineConfig,
    rt: crate::runtime::ModelRuntime,
    tiers: Arc<TieredStore>,
    loader: CacheLoader,
    lat_model: LatencyModel,
    queue: Arc<WorkerQueue>,
    prepost: Arc<ThreadPool>,
    events: Sender<WorkerEvent>,
    shared: Arc<WorkerShared>,
    stop: Arc<AtomicBool>,
    /// Cluster-wide template table (None for standalone engines, which
    /// keep the seed behaviour: cold-register on first use).
    registry: Option<Arc<TemplateRegistry>>,
}

impl Worker {
    pub fn new(
        id: usize,
        cfg: EngineConfig,
        rt: crate::runtime::ModelRuntime,
        tiers: Arc<TieredStore>,
        lat_model: LatencyModel,
        events: Sender<WorkerEvent>,
    ) -> Worker {
        // FISEdit keeps activations GPU-resident -> free loads.
        let bandwidth = if cfg.system == SystemKind::FisEdit { 0.0 } else { cfg.sim_bandwidth };
        let loader = CacheLoader::spawn(bandwidth);
        // The copy stream is bandwidth-paced by construction, so the DP's
        // load model is exact: seconds = bytes / bandwidth. (The compute
        // model stays calibrated from measurements.)
        let mut lat_model = lat_model;
        lat_model.load = crate::util::stats::LinearFit {
            slope: if bandwidth > 0.0 { 1.0 / bandwidth } else { 0.0 },
            intercept: 0.0,
            r2: 1.0,
        };
        let prepost = Arc::new(ThreadPool::new(
            &format!("prepost-{id}"),
            cfg.prepost_threads.max(1),
        ));
        let queue = WorkerQueue::with_policy(QueuePolicy::from_qos(&cfg.qos));
        Worker {
            id,
            cfg,
            rt,
            tiers,
            loader,
            lat_model,
            queue,
            prepost,
            events,
            shared: Arc::new(WorkerShared::default()),
            stop: Arc::new(AtomicBool::new(false)),
            registry: None,
        }
    }

    /// Attach the cluster's template registry: admission then gates on
    /// the cluster-wide lifecycle (park while registering, refuse
    /// retired) instead of cold-registering unknown templates.
    pub fn with_registry(mut self, registry: Arc<TemplateRegistry>) -> Worker {
        self.registry = Some(registry);
        self
    }

    /// This worker's cache tier (per-worker in cluster mode).
    pub fn tiers(&self) -> Arc<TieredStore> {
        Arc::clone(&self.tiers)
    }

    /// Submission handle (disaggregation decided by the batching policy).
    pub fn submitter(&self) -> Submitter {
        let pool = matches!(self.cfg.batching, BatchingPolicy::ContinuousDisaggregated)
            .then(|| Arc::clone(&self.prepost));
        let submitter = Submitter::new(
            Arc::clone(&self.queue),
            pool,
            self.rt.config.hidden,
            self.cfg.prepost_cpu_us,
        );
        // Enqueue-time promotion: when this worker's tier holds the
        // template only on disk, start promoting it on the low-priority
        // pre/post lane so the load hides under queuing time (§4.2).
        let tiers = Arc::clone(&self.tiers);
        let pool = Arc::clone(&self.prepost);
        let prefetch: Arc<dyn Fn(&str) + Send + Sync> = Arc::new(move |template_id: &str| {
            if tiers.residency(template_id) == Residency::Disk {
                let tiers = Arc::clone(&tiers);
                let template_id = template_id.to_string();
                pool.submit_low(move || {
                    let _ = tiers.get(&template_id);
                });
            }
        });
        submitter.with_prefetch(prefetch)
    }

    pub fn queue(&self) -> Arc<WorkerQueue> {
        Arc::clone(&self.queue)
    }

    pub fn shared(&self) -> Arc<WorkerShared> {
        Arc::clone(&self.shared)
    }

    pub fn stop_flag(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.stop)
    }

    /// Snapshot for the scheduler (running + queued composition).
    pub fn snapshot(&self) -> WorkerSnapshot {
        WorkerSnapshot {
            worker_id: self.id,
            queued: self.queue.pending(),
            running: self.shared.running.load(Ordering::Relaxed),
            queued_masked_tokens: self.shared.running_masked.load(Ordering::Relaxed),
            mask_ratios: Vec::new(),
            class_depths: self.queue.class_depths(Instant::now()),
        }
    }

    /// Run the engine loop on the current thread until stopped + drained.
    pub fn run(mut self) -> Result<()> {
        let mut members: Vec<Member> = Vec::new();
        let mut parked: Vec<Parked> = Vec::new();
        let mut preempted: Vec<Member> = Vec::new();
        loop {
            self.reap_defunct();
            self.admit(&mut members, &mut parked, &mut preempted)?;
            if members.is_empty() {
                if self.stop.load(Ordering::Relaxed)
                    && self.queue.pending() == 0
                    && preempted.is_empty()
                {
                    // parked requests will never see their registration
                    // from a stopping cluster; resolve their tickets
                    for p in parked.drain(..) {
                        self.resolve_unrun(p.prep.request.id, EditError::WorkerShutdown);
                    }
                    break;
                }
                self.queue.wait_for_work(Duration::from_millis(1));
                continue;
            }
            self.run_step(&mut members)?;
            self.complete_finished(&mut members);
            self.publish(&members);
        }
        Ok(())
    }

    /// Sweep the queue for cancel-marked or deadline-expired entries and
    /// resolve their tickets without spending denoise steps.
    fn reap_defunct(&self) {
        for (id, err) in self.queue.drain_defunct(Instant::now()) {
            self.resolve_unrun(id, err);
        }
    }

    /// Resolve a request this worker holds (parked, preempted, or just
    /// popped) without running it: clear its held flag and report the
    /// terminal error to the collector.
    fn resolve_unrun(&self, id: u64, err: EditError) {
        self.queue.set_held(id, false);
        let _ = self.events.send(WorkerEvent::Finished {
            id,
            worker: self.id,
            result: Err(err),
        });
    }

    /// Spawn the engine loop on its own thread.
    pub fn start(self) -> std::thread::JoinHandle<Result<()>> {
        std::thread::Builder::new()
            .name(format!("worker-{}", self.id))
            .spawn(move || self.run())
            .expect("spawn worker")
    }

    // -- admission -----------------------------------------------------------

    fn admit(
        &mut self,
        members: &mut Vec<Member>,
        parked: &mut Vec<Parked>,
        preempted: &mut Vec<Member>,
    ) -> Result<()> {
        let cap = self.cfg.max_batch.min(self.rt.max_batch_bucket());
        // whether the batch was drained *before* parked admissions, so a
        // resumed parked request doesn't make static batching skip the
        // queue-fill below and run an underfilled batch
        let drained_batch = members.is_empty();
        self.service_parked(members, parked, cap);
        self.service_preempted(members, preempted, cap);
        match self.cfg.batching {
            BatchingPolicy::Static => {
                // join only when the running batch has fully drained
                if !drained_batch {
                    return Ok(());
                }
                while members.len() < cap {
                    // don't pop requests we could only park when the
                    // parked set is full — they stay queued (visible in
                    // queue depths, still cancellable)
                    let park_room = parked.len() < cap;
                    let admit = |tpl: &str, _k: usize| {
                        park_room
                            || !matches!(self.template_gate(tpl), TemplateGate::Pending)
                    };
                    let Some(prep) = self.take_prepared_if(members, &admit) else { break };
                    self.gate_or_admit(prep, members, parked);
                }
            }
            BatchingPolicy::ContinuousInline | BatchingPolicy::ContinuousDisaggregated => {
                // QoS: when the batch is full and an Interactive request
                // waits, park the lowest-class member at this step
                // boundary so the fill loop below can admit the
                // interactive one (the step-level analogue of the
                // paper's one-step join).
                self.preempt_for_interactive(members, preempted, cap);
                // Step-level join (the paper's continuous batching, §4.3),
                // bucket-aware: a joining request must not inflate the
                // running batch's token bucket unless the batch is nearly
                // empty (<= 1 member). Ordered on the best queue
                // candidate only (priority order under QoS, FIFO
                // otherwise), so deferred large-mask requests cannot
                // starve. This is the shape-bucketed analogue of the
                // paper's heterogeneous-mask batching (their kernels
                // handle per-member token counts; XLA programs are
                // shape-static).
                loop {
                    if members.len() >= cap {
                        break;
                    }
                    // a preempted member whose bucket no longer fits the
                    // running batch blocks new admissions (the same
                    // no-skip rule the queue front gets): the batch
                    // drains, the member rejoins, then filling resumes
                    if preempted
                        .iter()
                        .any(|m| !self.bucket_fits(members, m.prep.masked_count))
                    {
                        break;
                    }
                    let batch_bucket = members
                        .iter()
                        .map(|m| m.cached_bucket)
                        .max()
                        .unwrap_or(usize::MAX);
                    let admit_any = members.len() <= 1;
                    let park_room = parked.len() < cap;
                    let admit = |tpl: &str, k: usize| {
                        let fits = admit_any
                            || !self.mask_aware()
                            || self.rt.config.bucket_for(k) <= batch_bucket;
                        // registering-template requests are only popped
                        // while the (cap-bounded) parked set has room
                        fits
                            && (park_room
                                || !matches!(self.template_gate(tpl), TemplateGate::Pending))
                    };
                    let Some(prep) = self.take_prepared_if(members, &admit) else { break };
                    self.gate_or_admit(prep, members, parked);
                }
            }
        }
        Ok(())
    }

    /// Whether a request with `masked_count` tokens may join the running
    /// batch without inflating its token bucket (the same rule the admit
    /// loop applies to queued requests).
    fn bucket_fits(&self, members: &[Member], masked_count: usize) -> bool {
        if members.len() <= 1 || !self.mask_aware() {
            return true;
        }
        let batch_bucket = members
            .iter()
            .map(|m| m.cached_bucket)
            .max()
            .unwrap_or(usize::MAX);
        self.rt.config.bucket_for(masked_count) <= batch_bucket
    }

    /// Re-check parked requests: resolve cancel marks first, then admit
    /// the ones whose template became ready (bucket rules permitting),
    /// refuse the ones whose template retired or failed, and time out the
    /// ones that waited past their deadline (only while still pending — a
    /// ready request that merely awaits a compatible batch bucket is
    /// never timed out here).
    fn service_parked(&self, members: &mut Vec<Member>, parked: &mut Vec<Parked>, cap: usize) {
        let join_ok = match self.cfg.batching {
            // static batching only joins a drained batch
            BatchingPolicy::Static => members.is_empty(),
            _ => true,
        };
        let mut i = 0;
        while i < parked.len() {
            let id = parked[i].prep.request.id;
            if self.queue.take_cancel(id) {
                let _ = parked.swap_remove(i);
                self.resolve_unrun(id, EditError::Cancelled);
                continue;
            }
            // a deadline that lapsed while parked counts as expired-in-
            // queue: drop it before it can burn denoise steps
            let expired = self.cfg.qos.enabled
                && matches!(parked[i].prep.request.deadline, Some(d) if Instant::now() >= d);
            if expired {
                let _ = parked.swap_remove(i);
                self.resolve_unrun(id, EditError::DeadlineExceeded);
                continue;
            }
            match self.template_gate(&parked[i].prep.request.template_id) {
                TemplateGate::Ready
                    if join_ok
                        && members.len() < cap
                        && self.bucket_fits(members, parked[i].prep.masked_count) =>
                {
                    let p = parked.swap_remove(i);
                    // atomic un-park: a cancel that raced in wins
                    if self.queue.release_held(id) {
                        self.admit_member(p.prep, members);
                    } else {
                        self.resolve_unrun(id, EditError::Cancelled);
                    }
                }
                TemplateGate::Refused(err) => {
                    let _ = parked.swap_remove(i);
                    self.resolve_unrun(id, err);
                }
                TemplateGate::Pending if Instant::now() >= parked[i].deadline => {
                    let _ = parked.swap_remove(i);
                    self.resolve_unrun(id, EditError::Timeout);
                }
                _ => i += 1,
            }
        }
    }

    /// Re-admit preempted members: cancel marks resolve first (the
    /// satellite fix — `DELETE` reaches preempted members, which release
    /// their slot promptly), then each member rejoins as soon as a slot
    /// is free and its bucket fits. No `Started` event — the request
    /// never left the `Running` state; its latent resumes exactly where
    /// it parked.
    fn service_preempted(
        &self,
        members: &mut Vec<Member>,
        preempted: &mut Vec<Member>,
        cap: usize,
    ) {
        let join_ok = match self.cfg.batching {
            BatchingPolicy::Static => members.is_empty(),
            _ => true,
        };
        let mut i = 0;
        while i < preempted.len() {
            let id = preempted[i].prep.request.id;
            if self.queue.take_cancel(id) {
                let _ = preempted.swap_remove(i);
                self.resolve_unrun(id, EditError::Cancelled);
                continue;
            }
            if join_ok
                && members.len() < cap
                && self.bucket_fits(members, preempted[i].prep.masked_count)
            {
                let m = preempted.swap_remove(i);
                // atomic resume: a cancel that raced in wins instead of
                // silently re-running a request the client cancelled
                if self.queue.release_held(id) {
                    members.push(m);
                } else {
                    self.resolve_unrun(id, EditError::Cancelled);
                }
                continue;
            }
            i += 1;
        }
    }

    /// QoS preemption (tentpole part 2): with the batch full and an
    /// `Interactive` request waiting, park the lowest-class member at
    /// this step boundary — its latent and step counter move to the
    /// preempted set and rejoin later, bit-identical to an uninterrupted
    /// run. Each member is preempted at most once, and at most one member
    /// per engine iteration, so preemption cannot thrash.
    fn preempt_for_interactive(
        &self,
        members: &mut Vec<Member>,
        preempted: &mut Vec<Member>,
        cap: usize,
    ) {
        if !self.cfg.qos.enabled || members.len() < cap {
            return;
        }
        // the *next pop* must be a genuinely Interactive request — if an
        // aged-up lower class outranks it, that one gets the next natural
        // slot and evicting a member for it would invert the intent
        let peek = match self.cfg.batching {
            BatchingPolicy::ContinuousDisaggregated => self.queue.peek_best_ready(),
            _ => self.queue.peek_best_raw(),
        };
        let Some((rank, masked)) = peek else { return };
        if rank != Priority::Interactive.rank() {
            return;
        }
        let victim = members
            .iter()
            .enumerate()
            .filter(|(_, m)| m.rank() > Priority::Interactive.rank() && m.preemptions == 0)
            // lowest class first; among those, the least-progressed
            // member (most remaining steps), so a nearly-done member is
            // not held up at the finish line
            .max_by_key(|(_, m)| (m.rank(), std::cmp::Reverse(m.step)))
            .map(|(i, _)| i);
        let Some(i) = victim else { return };
        // only evict when (a) the interactive request could actually take
        // the freed slot under the bucket rule — otherwise the slot would
        // sit idle for the rest of the batch's lifetime — and (b) the
        // victim's own bucket still fits the remaining batch, so it is
        // never parked behind a batch it can no longer rejoin
        let remaining = members.len() - 1;
        let fits = if remaining <= 1 || !self.mask_aware() {
            true
        } else {
            let batch_bucket = members
                .iter()
                .enumerate()
                .filter(|(j, _)| *j != i)
                .map(|(_, m)| m.cached_bucket)
                .max()
                .unwrap_or(usize::MAX);
            self.rt.config.bucket_for(masked) <= batch_bucket
                && members[i].cached_bucket <= batch_bucket
        };
        if !fits {
            return;
        }
        let mut m = members.swap_remove(i);
        m.preemptions += 1;
        m.interruptions += 1;
        self.queue.set_held(m.prep.request.id, true);
        preempted.push(m);
    }

    /// Where a popped request's template stands right now.
    fn template_gate(&self, template_id: &str) -> TemplateGate {
        if self.tiers.is_host_resident(template_id) {
            return TemplateGate::Ready;
        }
        let Some(registry) = &self.registry else {
            return TemplateGate::Ready; // standalone: cold-register path
        };
        match registry.state(template_id) {
            // ready (tier promotes/cold-fills in make_member) or direct
            // submission the registry adopted without a trace
            Some(TemplateState::Ready) | None => TemplateGate::Ready,
            Some(TemplateState::Registering) => TemplateGate::Pending,
            Some(TemplateState::Retired) => {
                TemplateGate::Refused(EditError::TemplateRetired(template_id.to_string()))
            }
            Some(TemplateState::Failed(reason)) => TemplateGate::Refused(EditError::Internal(
                format!("template {template_id:?} failed registration: {reason}"),
            )),
        }
    }

    /// Admit a popped request, park it, or refuse it, per its template's
    /// lifecycle state. Cancel marks and expired deadlines resolve here
    /// too — the last check before a request joins the batch.
    fn gate_or_admit(
        &self,
        prep: PreparedRequest,
        members: &mut Vec<Member>,
        parked: &mut Vec<Parked>,
    ) {
        let id = prep.request.id;
        if self.queue.take_cancel(id) {
            self.resolve_unrun(id, EditError::Cancelled);
            return;
        }
        let expired = matches!(prep.request.deadline, Some(d) if Instant::now() >= d);
        if self.cfg.qos.enabled && expired {
            self.resolve_unrun(id, EditError::DeadlineExceeded);
            return;
        }
        match self.template_gate(&prep.request.template_id) {
            TemplateGate::Ready => self.admit_member(prep, members),
            TemplateGate::Pending => {
                self.queue.set_held(id, true);
                parked.push(Parked {
                    deadline: Instant::now()
                        + Duration::from_millis(self.cfg.registration_wait_ms),
                    prep,
                });
            }
            TemplateGate::Refused(err) => self.resolve_unrun(id, err),
        }
    }

    /// Turn a prepared request into a batch member, reporting the
    /// queued -> running transition to the collector. Registration
    /// failures become per-request errors instead of killing the engine.
    fn admit_member(&self, prep: PreparedRequest, members: &mut Vec<Member>) {
        let id = prep.request.id;
        let template = prep.request.template_id.clone();
        match self.make_member(prep) {
            Ok(m) => {
                let _ = self.events.send(WorkerEvent::Started { id, worker: self.id });
                members.push(m);
            }
            Err(e) => {
                // typed lifecycle refusals pass through; other
                // registration/cache faults are server errors (template
                // existence was the frontend's check, not ours)
                let result = match e.downcast::<EditError>() {
                    Ok(typed) => Err(typed),
                    Err(e) => Err(EditError::Internal(format!(
                        "admitting {template:?}: {e:#}"
                    ))),
                };
                let _ = self.events.send(WorkerEvent::Finished {
                    id,
                    worker: self.id,
                    result,
                });
            }
        }
    }

    /// Pull one prepared request if the queue front satisfies `admit`
    /// (called with its template id + masked-token count), preprocessing
    /// inline when the policy demands it (counting interruptions for
    /// current members — the §6.4 microbenchmark's metric).
    fn take_prepared_if(
        &self,
        members: &mut [Member],
        admit: &dyn Fn(&str, usize) -> bool,
    ) -> Option<PreparedRequest> {
        match self.cfg.batching {
            BatchingPolicy::ContinuousDisaggregated => self
                .queue
                .pop_ready_if(|p| admit(&p.request.template_id, p.masked_count)),
            _ => {
                let req = self
                    .queue
                    .pop_raw_if(|r| admit(&r.template_id, r.mask.masked_count()))?;
                if !members.is_empty() {
                    for m in members.iter_mut() {
                        m.interruptions += 1;
                    }
                }
                Some(preprocess(req, self.rt.config.hidden, self.cfg.prepost_cpu_us))
            }
        }
    }

    fn make_member(&self, prep: PreparedRequest) -> Result<Member> {
        let acts = self.ensure_registered(&prep.request.template_id)?;
        let latent = acts.initial_latent();
        let cfg = &self.rt.config;
        let bucket = cfg.bucket_for(prep.masked_count);
        let cached_ids = Arc::new(prep.perm.cached_ids(bucket).to_vec());
        let gate = (self.cfg.system == SystemKind::TeaCache)
            .then(|| TeaCacheGate::new(self.cfg.teacache_threshold));
        Ok(Member {
            prep,
            acts,
            latent,
            step: 0,
            joined: Instant::now(),
            interruptions: 0,
            steps_computed: 0,
            cached_ids,
            cached_bucket: bucket,
            last_eps: None,
            gate,
            preemptions: 0,
        })
    }

    /// Fetch (and on cold miss, register) a template's activations. In
    /// cluster mode a registration that is already in flight elsewhere is
    /// awaited instead of duplicated on the engine thread.
    pub fn ensure_registered(&self, template_id: &str) -> Result<Arc<TemplateActivations>> {
        if let Some(acts) = self.tiers.get(template_id)? {
            return Ok(acts);
        }
        if let Some(registry) = &self.registry {
            match registry.state(template_id) {
                Some(TemplateState::Registering) => {
                    registry
                        .wait_ready(
                            template_id,
                            Duration::from_millis(self.cfg.registration_wait_ms),
                        )
                        .map_err(anyhow::Error::new)?;
                    if let Some(acts) = self.tiers.get(template_id)? {
                        return Ok(acts);
                    }
                }
                // never resurrect a retired template's bytes via the
                // cold-register fallback (admission raced a purge)
                Some(TemplateState::Retired) => {
                    return Err(anyhow::Error::new(EditError::TemplateRetired(
                        template_id.to_string(),
                    )))
                }
                _ => {}
            }
        }
        let (acts, _) = register_template(&self.rt, template_id, self.cfg.cache_mode)
            .context("template registration")?;
        self.tiers.insert(Arc::clone(&acts))?;
        Ok(acts)
    }

    // -- step execution -------------------------------------------------------

    fn mask_aware(&self) -> bool {
        matches!(self.cfg.system, SystemKind::InstGenIE | SystemKind::FisEdit)
    }

    fn run_step(&mut self, members: &mut [Member]) -> Result<()> {
        if self.mask_aware() {
            let n = members
                .iter()
                .map(|m| m.cached_bucket)
                .max()
                .unwrap_or(self.rt.config.tokens);
            if n >= self.rt.config.tokens {
                self.step_full(members)
            } else {
                self.step_masked(members, n)
            }
        } else {
            self.step_full(members)
        }
        .map(|_| self.shared.steps_executed.fetch_add(1, Ordering::Relaxed))
        .map(|_| ())
    }

    /// Build a member's denoiser input h = x + temb(t) (+ conditioning on
    /// the genuinely masked rows).
    fn build_hidden(&self, m: &Member) -> Vec<f32> {
        let cfg = &self.rt.config;
        let h = cfg.hidden;
        let temb = self.rt.weights().temb_row(m.step);
        let mut out = m.latent.data().to_vec();
        for (i, v) in out.iter_mut().enumerate() {
            *v += temb[i % h];
        }
        for &id in m.prep.perm.compute_ids(m.prep.masked_count) {
            let row = &mut out[id * h..(id + 1) * h];
            for (v, c) in row.iter_mut().zip(&m.prep.conditioning) {
                *v += c;
            }
        }
        out
    }

    /// Full-sequence step (Diffusers / TeaCache / mask saturating bucket).
    fn step_full(&mut self, members: &mut [Member]) -> Result<()> {
        let cfg = self.rt.config.clone();
        let (l, h) = (cfg.tokens, cfg.hidden);
        let b = members.len();
        let bb = self.rt.batch_bucket_for(b);

        // TeaCache: gate each member; if everyone skips, replay without
        // touching the device.
        let mut compute_mask: Vec<bool> = vec![true; b];
        if self.cfg.system == SystemKind::TeaCache {
            for (i, m) in members.iter_mut().enumerate() {
                let temb = self.rt.weights().temb_row(m.step).to_vec();
                let gate = m.gate.as_mut().expect("teacache gate");
                compute_mask[i] = !(gate.should_skip(&temb) && m.last_eps.is_some());
            }
        }

        let any_compute = compute_mask.iter().any(|&c| c);
        let mut eps_rows: Vec<Vec<f32>> = Vec::with_capacity(b);
        if any_compute {
            // pack (bb, L, H); padding slots replicate member 0
            let mut x = vec![0f32; bb * l * h];
            for i in 0..bb {
                let m = &members[i.min(b - 1)];
                let src = self.build_hidden(m);
                x[i * l * h..(i + 1) * l * h].copy_from_slice(&src);
            }
            let mut cur = x;
            for blk in 0..cfg.blocks {
                cur = self.rt.run_block_y(blk, l, bb, &cur)?;
            }
            for (i, m) in members.iter().enumerate() {
                let _ = m;
                eps_rows.push(cur[i * l * h..(i + 1) * l * h].to_vec());
            }
        }

        // per-member latent update
        for (i, m) in members.iter_mut().enumerate() {
            let eps: Vec<f32> = if compute_mask[i] {
                let e = eps_rows[i].clone();
                m.last_eps = Some(e.clone());
                m.steps_computed += 1;
                e
            } else {
                m.last_eps.clone().expect("replayed eps")
            };
            let sched = self.rt.schedule();
            // masked rows follow the computed eps...
            let masked: Vec<usize> =
                m.prep.perm.compute_ids(m.prep.masked_count).to_vec();
            let mut eps_masked = vec![0f32; masked.len() * h];
            for (r, &id) in masked.iter().enumerate() {
                eps_masked[r * h..(r + 1) * h].copy_from_slice(&eps[id * h..(id + 1) * h]);
            }
            sched.update_rows(m.step, m.latent.data_mut(), h, &masked, &eps_masked);
            // ...unmasked rows are pinned to the template trajectory
            // (standard diffusion inpainting: regenerate only the mask).
            let unmasked: Vec<usize> = m.prep.perm.cached_ids(m.prep.masked_count).to_vec();
            let teps = m.acts.eps(m.step);
            let mut eps_unm = vec![0f32; unmasked.len() * h];
            for (r, &id) in unmasked.iter().enumerate() {
                eps_unm[r * h..(r + 1) * h].copy_from_slice(&teps[id * h..(id + 1) * h]);
            }
            sched.update_rows(m.step, m.latent.data_mut(), h, &unmasked, &eps_unm);
            m.step += 1;
        }
        Ok(())
    }

    /// Mask-aware step at token bucket `n` with the Algo-1 pipeline.
    fn step_masked(&mut self, members: &mut [Member], n: usize) -> Result<()> {
        let cfg = self.rt.config.clone();
        let (l, h) = (cfg.tokens, cfg.hidden);
        let b = members.len();
        let bb = self.rt.batch_bucket_for(b);
        let mode = self.cfg.cache_mode;

        // -- plan (Algo 1) ---------------------------------------------------
        let costs: Vec<BlockCosts> = self.lat_model.step_costs(&cfg, n, b, mode);
        let plan: PipelinePlan = if self.cfg.force_all_cached || self.cfg.naive_loading {
            PipelinePlan { use_cache: vec![true; cfg.blocks], latency: 0.0 }
        } else {
            pipeline::plan(&costs)
        };

        // cached-row id sets at this bucket (may exceed a member's own
        // bucket; the permutation prefix property makes this safe)
        let cached_ids: Vec<Arc<Vec<usize>>> = members
            .iter()
            .map(|m| {
                if m.cached_bucket == n {
                    Arc::clone(&m.cached_ids)
                } else {
                    Arc::new(m.prep.perm.cached_ids(n).to_vec())
                }
            })
            .collect();

        // -- submit loads (pipeline order) ------------------------------------
        let mut staged_rx: Vec<Option<Receiver<StagedBlock>>> = (0..cfg.blocks).map(|_| None).collect();
        let mut staged_now: Vec<Option<StagedBlock>> = (0..cfg.blocks).map(|_| None).collect();
        let gathers = |step_of: &dyn Fn(usize) -> usize| -> Vec<MemberGather> {
            members
                .iter()
                .enumerate()
                .map(|(i, m)| MemberGather {
                    store: Arc::clone(&m.acts),
                    step: step_of(i),
                    ids: Arc::clone(&cached_ids[i]),
                })
                .collect()
        };
        let steps: Vec<usize> = members.iter().map(|m| m.step).collect();
        if self.cfg.naive_loading {
            // Fig. 9-Top: the compute stream performs all loads up front.
            for blk in 0..cfg.blocks {
                if plan.use_cache[blk] {
                    let g = gathers(&|i| steps[i]);
                    staged_now[blk] = Some(self.loader.gather_sync(blk, g, mode));
                }
            }
        } else {
            for blk in 0..cfg.blocks {
                if plan.use_cache[blk] {
                    let g = gathers(&|i| steps[i]);
                    staged_rx[blk] = Some(self.loader.submit(blk, g, mode));
                }
            }
        }

        // -- hidden state: one full (L, H) buffer per member -----------------
        let mut hidden: Vec<Vec<f32>> = members.iter().map(|m| self.build_hidden(m)).collect();

        // reusable packed buffers (hot loop: no per-block allocation)
        let mut packed = vec![0f32; bb * n * h];
        let mut full = Vec::new();
        let mut kc = Vec::new();
        let mut vc = Vec::new();

        for blk in 0..cfg.blocks {
            if plan.use_cache[blk] {
                // wait for the copy stream (a bubble iff the DP mispredicts)
                let staged = match staged_now[blk].take() {
                    Some(s) => s,
                    None => staged_rx[blk]
                        .take()
                        .expect("staged rx")
                        .recv()
                        .expect("loader alive"),
                };
                // pack compute rows
                for i in 0..bb {
                    let mi = i.min(b - 1);
                    let ids = members[mi].prep.perm.compute_ids(n);
                    gather_rows(&hidden[mi], h, ids, &mut packed[i * n * h..(i + 1) * n * h]);
                }
                let out = match mode {
                    CacheMode::CacheY => self.rt.run_block_y(blk, n, bb, &packed)?,
                    CacheMode::CacheKV => {
                        let kvs = staged.kv.as_ref().expect("kv staged");
                        let rows = l - n;
                        kc.resize(bb * rows * h, 0.0);
                        vc.resize(bb * rows * h, 0.0);
                        for i in 0..bb {
                            let (k, v) = &kvs[i.min(b - 1)];
                            kc[i * rows * h..(i + 1) * rows * h].copy_from_slice(k);
                            vc[i * rows * h..(i + 1) * rows * h].copy_from_slice(v);
                        }
                        self.rt.run_block_kv(blk, n, bb, &packed, &kc, &vc)?
                    }
                };
                // scatter computed rows + replenish cached rows (Fig. 5)
                for (i, m) in members.iter().enumerate() {
                    let ids = m.prep.perm.compute_ids(n);
                    scatter_rows(&mut hidden[i], h, ids, &out[i * n * h..(i + 1) * n * h]);
                    scatter_rows(&mut hidden[i], h, &cached_ids[i], &staged.y[i]);
                }
            } else {
                // full block: all L tokens, no load
                full.resize(bb * l * h, 0.0);
                for i in 0..bb {
                    let mi = i.min(b - 1);
                    full[i * l * h..(i + 1) * l * h].copy_from_slice(&hidden[mi]);
                }
                let out = self.rt.run_block_y(blk, l, bb, &full)?;
                for (i, hbuf) in hidden.iter_mut().enumerate() {
                    hbuf.copy_from_slice(&out[i * l * h..(i + 1) * l * h]);
                }
            }
        }

        // -- latent update ----------------------------------------------------
        for (i, m) in members.iter_mut().enumerate() {
            let sched = self.rt.schedule();
            let masked: Vec<usize> = m.prep.perm.compute_ids(m.prep.masked_count).to_vec();
            let mut eps_masked = vec![0f32; masked.len() * h];
            for (r, &id) in masked.iter().enumerate() {
                eps_masked[r * h..(r + 1) * h]
                    .copy_from_slice(&hidden[i][id * h..(id + 1) * h]);
            }
            sched.update_rows(m.step, m.latent.data_mut(), h, &masked, &eps_masked);
            let unmasked: Vec<usize> = m.prep.perm.cached_ids(m.prep.masked_count).to_vec();
            let teps = m.acts.eps(m.step);
            let mut eps_unm = vec![0f32; unmasked.len() * h];
            for (r, &id) in unmasked.iter().enumerate() {
                eps_unm[r * h..(r + 1) * h].copy_from_slice(&teps[id * h..(id + 1) * h]);
            }
            sched.update_rows(m.step, m.latent.data_mut(), h, &unmasked, &eps_unm);
            m.step += 1;
            m.steps_computed += 1;
        }
        Ok(())
    }

    // -- completion -----------------------------------------------------------

    fn complete_finished(&mut self, members: &mut Vec<Member>) {
        let total_steps = self.rt.config.steps;
        let mut i = 0;
        while i < members.len() {
            if members[i].step >= total_steps {
                let m = members.swap_remove(i);
                let remaining = members.len();
                self.finish_member(m, remaining, members);
            } else {
                i += 1;
            }
        }
    }

    fn finish_member(&self, m: Member, _remaining: usize, others: &mut [Member]) {
        let cfg = &self.rt.config;
        let latent = Tensor::from_vec(
            &[cfg.tokens, cfg.hidden],
            m.latent.data().to_vec(),
        )
        .expect("latent tensor");
        let decoder = self.rt.weights().decoder.clone();
        let mut timing = RequestTiming {
            queue: (m.joined - m.prep.request.arrival).as_secs_f64(),
            inference: m.joined.elapsed().as_secs_f64(),
            e2e: 0.0,
            interruptions: m.interruptions,
            steps_computed: m.steps_computed,
        };
        let arrival = m.prep.request.arrival;
        let id = m.prep.request.id;
        let template_id = m.prep.request.template_id.clone();
        let ratio = m.prep.request.mask.ratio();
        let priority = m.prep.request.priority;
        let events = self.events.clone();
        let worker = self.id;
        let cpu_us = self.cfg.prepost_cpu_us;

        let work = move || {
            let image = postprocess(&latent, &decoder, cpu_us);
            timing.e2e = arrival.elapsed().as_secs_f64();
            let _ = events.send(WorkerEvent::Finished {
                id,
                worker,
                result: Ok(EditResponse {
                    id,
                    template_id,
                    image,
                    latent,
                    timing,
                    mask_ratio: ratio,
                    priority,
                }),
            });
        };

        match self.cfg.batching {
            BatchingPolicy::ContinuousDisaggregated => self.prepost.submit(work),
            _ => {
                // inline postprocess interrupts every remaining member
                for o in others.iter_mut() {
                    o.interruptions += 1;
                }
                work();
            }
        }
    }

    fn publish(&self, members: &[Member]) {
        self.shared.running.store(members.len(), Ordering::Relaxed);
        let masked: usize = members.iter().map(|m| m.prep.masked_count).sum();
        self.shared.running_masked.store(masked, Ordering::Relaxed);
    }
}

fn gather_rows(src: &[f32], h: usize, ids: &[usize], out: &mut [f32]) {
    for (i, &id) in ids.iter().enumerate() {
        out[i * h..(i + 1) * h].copy_from_slice(&src[id * h..(id + 1) * h]);
    }
}

fn scatter_rows(dst: &mut [f32], h: usize, ids: &[usize], src: &[f32]) {
    for (i, &id) in ids.iter().enumerate() {
        dst[id * h..(id + 1) * h].copy_from_slice(&src[i * h..(i + 1) * h]);
    }
}
